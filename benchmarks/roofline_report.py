"""Assemble the roofline table (EXPERIMENTS.md §Roofline) from dry-run
artifacts.

Per (arch x shape), single-pod mesh (per the brief):

  compute_s    = HLO_FLOPs_per_device / 197e12      (bf16 peak, v5e)
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = collective_bytes_per_device / 50e9

HLO terms come from the two reduced-depth UNROLLED variants (1 and 2
pattern groups) extrapolated linearly to full depth — XLA counts a scan
(`while`) body once, so the full-model cost_analysis undercounts by
~n_layers (docs/architecture.md §6).  Chunked-attention inner loops are likewise
counted once even in the unrolled variants; an ANALYTIC attention
correction (flops + flash-style bytes) is added per attention layer and
reported in its own columns for transparency.

Also reports MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill)
/ 2*N_active*B (decode) and the usefulness ratio MODEL_FLOPS/HLO_FLOPs.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch import roofline as RL
from repro.models import SHAPES

DRYRUN = Path("artifacts/dryrun")
CHIPS = 256  # single-pod mesh (16 x 16)


def _attn_layers(cfg) -> int:
    return cfg._block_counts().get("attn", 0) + cfg.encoder_layers \
        + (cfg.n_layers if cfg.encoder_layers else 0)  # cross-attn blocks


def attention_correction(cfg, cell) -> tuple[float, float]:
    """(flops, bytes) per device hidden inside chunked-attention loops.

    Only train/prefill full-sequence attention runs the chunked (looped)
    path; decode uses the unlooped naive path and needs no correction.
    Causal halves the score pairs; sliding windows clip them.
    """
    if cell.kind == "decode":
        return 0.0, 0.0
    n_attn = _attn_layers(cfg)
    if n_attn == 0:
        return 0.0, 0.0
    B, S = cell.global_batch, cell.seq_len
    H, Kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    pairs = S * (S + 1) / 2 if not cfg.local_window else \
        min(S * cfg.local_window, S * (S + 1) / 2)
    # QK^T and PV: 2 matmuls x 2 FLOP/MAC; x3 for train (bwd ~ 2x fwd)
    mult = 3.0 if cell.kind == "train" else 1.0
    flops = mult * 4.0 * B * H * dh * pairs
    # flash-style HBM bytes: Q,K,V read + O write + K/V re-read per q-block
    q_block = 512
    nq = max(S // q_block, 1)
    elt = 2  # bf16
    bytes_ = B * S * dh * elt * (2 * H + 2 * Kv + 2 * Kv * nq) * mult
    return flops / CHIPS, bytes_ / CHIPS


def cell_roofline(arch: str, shape: str, opt: bool = False) -> dict | None:
    suffix = "__opt" if opt else ""
    f = DRYRUN / f"{arch}__{shape}__data16_model16{suffix}.json"
    if not f.exists():
        return None
    rec = json.loads(f.read_text())
    if rec.get("status") == "skipped":
        return {"arch": arch, "shape": shape, "status": "skipped",
                "variant": "opt" if opt else "baseline",
                "reason": rec.get("reason", "")}
    if rec.get("status") != "ok" or not rec.get("variants"):
        return {"arch": arch, "shape": shape, "status": "missing",
                "variant": "opt" if opt else "baseline"}

    cfg = get_config(arch)
    if opt:
        from repro.launch import perf as PERF
        cfg = PERF.optimize(cfg)
    cell = SHAPES[shape]
    v1, v2 = rec["variants"][0], rec["variants"][1]
    L1, L2, Lf = v1["n_layers"], v2["n_layers"], cfg.n_layers

    def extrap(key):
        a = v1["cost_analysis"].get(key, 0.0)
        b = v2["cost_analysis"].get(key, 0.0)
        return max(RL.linear_extrapolate(a, b, L1, L2, Lf), 0.0)

    flops = extrap("flops")
    bytes_ = extrap("bytes accessed")
    coll = max(RL.linear_extrapolate(
        v1["collective_bytes"], v2["collective_bytes"], L1, L2, Lf), 0.0)
    aflops, abytes = attention_correction(cfg, cell)

    terms = RL.roofline_terms(flops + aflops, bytes_ + abytes, coll)
    mf = RL.analytic_model_flops(cfg, cell, rec["active_params"]) / CHIPS
    out = {
        "arch": arch, "shape": shape, "status": "ok", "kind": cell.kind,
        "variant": "opt" if opt else "baseline",
        "params": rec["params"], "active_params": rec["active_params"],
        "hlo_flops": flops, "attn_corr_flops": aflops,
        "hlo_bytes": bytes_, "attn_corr_bytes": abytes,
        "collective_bytes": coll,
        "collectives_by_kind": rec["collectives"]["bytes_by_kind"],
        "model_flops": mf,
        "useful_ratio": mf / max(flops + aflops, 1.0),
        "temp_bytes_per_dev": rec["memory_analysis"].get(
            "temp_size_in_bytes"),
        "arg_bytes_per_dev": rec["memory_analysis"].get(
            "argument_size_in_bytes"),
        "compile_s": rec.get("compile_s"),
        **terms,
    }
    out["advice"] = _advice(out)
    return out


def _advice(row: dict) -> str:
    d = row["dominant"]
    if d == "memory":
        return ("memory-bound: cut HBM traffic — microbatch the step, "
                "bf16 weight streaming (FSDP-style gather), fuse the "
                "fp32 logit/CE chain")
    if d == "collective":
        return ("collective-bound: reduce-scatter gradients instead of "
                "all-reduce, overlap layer all-gathers with compute, "
                "shrink TP degree for this shape")
    return ("compute-bound: near roofline — raise MXU utilization "
            "(tile alignment) and trim non-matmul flops (remat policy)")


def build_table() -> list:
    rows = []
    archs = sorted({p.name.split("__")[0] for p in DRYRUN.glob("*.json")})
    for arch in archs:
        for shape in SHAPES:
            r = cell_roofline(arch, shape)
            if r is not None:
                rows.append(r)
            ro = cell_roofline(arch, shape, opt=True)
            if ro is not None:
                rows.append(ro)
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | variant | compute_s | memory_s | collective_s "
           "| bound | roofline_frac | useful_ratio |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        var = r.get("variant", "baseline")
        if r["status"] != "ok":
            if var == "opt":
                continue  # no opt artifact for this cell
            lines.append(f"| {r['arch']} | {r['shape']} | {var} | — | — | — "
                         f"| {r['status']} | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {var} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/roofline.json")
    args = ap.parse_args(argv)
    rows = build_table()
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(rows, indent=1))
    md = markdown_table(rows)
    Path("artifacts/roofline.md").write_text(md + "\n")
    print(md)
    ok_rows = [r for r in rows if r["status"] == "ok"]
    print(f"\n{len(ok_rows)} ok cells, "
          f"{sum(1 for r in rows if r['status'] == 'skipped')} skipped")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
