"""Decoder cost vs k (paper Sec. 2: one-step is O(nnz) and streaming;
optimal is a least-squares solve — poly and memory-hungry).

Measures wall-time per decode for numpy (master-side) and the Pallas
kernels (interpret mode timing is NOT meaningful on CPU — we report it
for completeness but the scaling claims use the numpy path)."""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import codes, decoding
from repro.core.engine import DecodeEngine
from repro.core.simulate import sample_straggler_masks
from .common import save_csv, save_json


def _time(fn, reps: int = 5) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(ks=(64, 128, 256, 512, 1024, 2048), delta: float = 0.3,
        seed: int = 0, iters: int = 4, batch: int = 256):
    rng = np.random.default_rng(seed)
    rows = []
    for k in ks:
        s = max(2, int(np.ceil(2 * np.log(k))))
        code = codes.bgc(k=k, n=k, s=s, rng=rng)
        mask = np.ones(k, bool)
        mask[rng.choice(k, int(delta * k), replace=False)] = False
        r = int(mask.sum())
        rho = decoding.default_rho(k, r, s)
        t_one = _time(lambda: decoding.onestep_weights(code.G, mask, rho))
        t_opt = _time(lambda: decoding.optimal_weights(code.G, mask))
        t_alg = _time(lambda: decoding.algorithmic_weights(code.G, mask,
                                                           iters=iters))
        # amortized per-mask cost of one batched engine decode
        eng = DecodeEngine(code, iters=iters)
        masks = sample_straggler_masks(k, int(delta * k), batch, rng)
        t_b1 = _time(lambda: eng.decode_batch(masks, "onestep"),
                     reps=3) / batch
        rows.append({"k": k, "s": s, "r": r,
                     "onestep_us": t_one, "optimal_us": t_opt,
                     f"algorithmic{iters}_us": t_alg,
                     "onestep_batched_us_per_mask": t_b1,
                     "batched_amortization": t_one / max(t_b1, 1e-9),
                     "opt_over_onestep": t_opt / max(t_one, 1e-9)})
    save_csv("decoding_cost", rows)
    save_json("decoding_cost", rows)

    # scaling claims: one-step stays micro-scale; optimal grows superlinearly
    t1 = [r["onestep_us"] for r in rows]
    to = [r["optimal_us"] for r in rows]
    checks = {
        "onestep_linear_ish": bool(
            t1[-1] / t1[0] < 8 * (ks[-1] / ks[0])),
        "optimal_superlinear": bool(
            to[-1] / max(to[0], 1e-9) > (ks[-1] / ks[0])),
        "onestep_much_cheaper_at_scale": bool(to[-1] / t1[-1] > 10),
    }
    return {"rows": rows, "checks": checks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=4)
    args = ap.parse_args(argv)
    rep = run(iters=args.iters)
    for r in rep["rows"]:
        print({k: (round(v, 1) if isinstance(v, float) else v)
               for k, v in r.items()})
    ok = all(rep["checks"].values())
    print("decoding cost checks:", rep["checks"])
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
