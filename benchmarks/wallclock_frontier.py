"""E11: ClusterSim wall-clock × accuracy frontier (the paper's headline
trade-off, measured end to end).

Two parts:

  1. Frontier grid — one shared Pareto-tail latency trace, swept over
     schemes × sync policies (and the one-step vs optimal decoders at
     the grid corners): each cell is one ClusterSim run = one batched
     decode, contributing a (wall-clock, decode-error) point.  The
     Pareto front of those points IS the runtime-vs-accuracy frontier.

  2. Throughput gate — at n = 256, S = 2000 steps, the ClusterSim path
     (policy over the whole trace + ONE batched decode) must beat the
     per-step decode loop (slice + scalar decode every step, the
     pre-ClusterSim dataflow) by >= 10x.

  3. Clustered-straggler trace — the block-correlated slow-episode
     regime (sim.traces 'clustered'), aligned with the SBM code's
     worker clusters, swept over every registry scheme.

  4. Device validation — frontier corner cells re-run through
     ClusterSim.run_distributed(): the same masks decoded by the REAL
     shard_map coded all-reduce (docs/architecture.md §9) with basis
     task gradients, whose on-device errors must match the analytic
     ones.  Run with REPRO_HOST_DEVICES=8 (repro.platform)
     for a true multi-device mesh; one device still validates the path.

  5. Adaptive policy column — the AdaptiveCoder closed loop
     (docs/adaptive.md) at n = 256 on the bimodal and clustered traces,
     against the full static (policy x decoder) grid at the same
     reference replication.  The gate: the adaptive cell's
     time-to-target beats EVERY static (policy, decoder) cell's on both
     traces, tracked as the `adaptive_advantage` baseline ratios.  The
     hindsight-optimal static cell over the full (s, policy, decoder)
     axis — an offline pick that requires full-trace knowledge — is
     reported informationally as the controller's online regret, not
     gated.

  6. Staleness pipelining — the decode-overlap column (training loop
     staleness=1: step t applies weights decoded from step t-1's mask
     while the decode overlaps backprop).  ClusterSim models the same
     schedule at n = 256 on the bimodal trace with a MEASURED decode
     cost (one batched optimal decode of the mask ensemble, amortized
     per step): synchronous runs pay it as a per-step barrier,
     pipelined runs only floor the step time at it.  The decode is
     ridge-regularized (ridge=0.01) — exact LS interpolation at
     r = n has unbounded weights whose re-masked stale form is worse
     than decoding nothing; the ridge bounds them at unchanged
     steady-state error, making stale reuse safe.  Gate: the
     staleness=1 time-to-target is no worse than synchronous.

Artifacts: artifacts/bench/wallclock_frontier.{json,csv}.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import decoding, registry
from repro.core.engine import DecodeEngine
from repro.sim import (ClusterSim, make_policy, make_trace, pareto_front,
                       sweep_adaptive, sweep_frontier, time_to_target_error)
from .common import ascii_curves, best_of, save_csv, save_json

# the frontier sweep covers the paper trio plus the follow-up families
# (SBM clustered codes, Glasgow-Wootters regular/expander codes) — every
# name resolves through the registry, which also supplies the decoder
# compatibilities per scheme
SCHEMES = ("frc", "bgc", "rbgc", "sbm", "expander")
NEW_FAMILIES = ("sbm", "expander")
POLICY_GRID = ("sync", "deadline", "backup", "adaptive")


def _per_step_loop(code, trace, policy):
    """The pre-ClusterSim dataflow: one policy step + one scalar decode
    per step."""
    G, k, s = code.G, code.k, code.s
    S = trace.steps
    times = np.empty(S)
    errs = np.empty(S)
    state = None
    for t in range(S):
        mask, times[t], state = policy.step(trace.latencies[t], state)
        A = G[:, mask]
        r = int(mask.sum())
        errs[t] = decoding.err1(A, decoding.default_rho(k, r, s)) / k
    return times, errs


def run(n: int = 64, steps: int = 400, s: int = 8, seed: int = 0,
        gate_n: int = 256, gate_steps: int = 2000,
        adaptive_n: int = 256, error_budget: float = 0.1):
    for scheme in SCHEMES:          # fail fast on unregistered schemes
        registry.get(scheme)
    trace = make_trace("pareto", steps=steps, n=n, deadline=1.5,
                       tail_scale=0.4, seed=seed)

    # ---- 1. the frontier grid ----
    points = sweep_frontier(SCHEMES, POLICY_GRID, trace, s=s, seed=seed,
                            decoders=("onestep", "optimal"))
    rows = [p.as_dict() for p in points]
    front = pareto_front(points)
    series = {}
    for scheme in SCHEMES:
        ys = [p.mean_error for p in points
              if p.scheme == scheme and p.decoder == "onestep"]
        series[scheme] = ys
    xs = [p.mean_step_time for p in points
          if p.scheme == SCHEMES[0] and p.decoder == "onestep"]
    print(ascii_curves("decode err/k by policy (x: policy index)",
                       list(range(len(xs))), series))
    print("\npareto front (mean_step_time, mean_err/k):")
    for p in front:
        print(f"  {p.scheme:>5} / {p.policy:<8} / {p.decoder:<8} "
              f"t={p.mean_step_time:7.3f}s  err={p.mean_error:.4f}  "
              f"t_target={p.time_to_target:8.1f}s")

    # ---- 1b. gap to the fundamental limit (Wang et al., informational)
    # every grid cell carries measured_err / fundamental_lower_bound at
    # its realized straggler fraction (sim.frontier.gap_to_optimal_frac);
    # the per-family column reports each family's best cell — 1.0 means
    # on the limit (FRC + optimal decoding sits there by Theorem 6).
    # check_regression tracks these informationally (never gating:
    # they're theory ratios, not machine throughput).
    gap_col = {}
    for scheme in SCHEMES:
        cells = [p for p in points
                 if p.scheme == scheme and p.gap_to_optimal is not None]
        if cells:
            b = min(cells, key=lambda p: p.gap_to_optimal)
            gap_col[scheme] = {
                "gap": float(b.gap_to_optimal), "policy": b.policy,
                "decoder": b.decoder, "mean_error": b.mean_error}
    print("\ngap to fundamental limit (best cell per family, "
          "err / Wang-et-al LB):")
    for scheme, g in gap_col.items():
        print(f"  {scheme:>8}: {g['gap']:8.2f}x  "
              f"({g['policy']}/{g['decoder']}, err={g['mean_error']:.4f})")

    # ---- 2. throughput gate: batched ClusterSim vs per-step loop ----
    gate_trace = make_trace("pareto", steps=gate_steps, n=gate_n,
                            deadline=1.5, tail_scale=0.4, seed=seed)
    gcode = registry.make("bgc", k=gate_n, n=gate_n, s=12, seed=seed)
    policy = make_policy("deadline")
    sim = ClusterSim(gcode, gate_trace, policy, decoder="onestep", s=12)

    # the millisecond-scale batched path needs best-of-5 to escape
    # allocator/scheduler noise; the seconds-scale deterministic loop
    # gets warmup + one timed run (reps=1).  Warmup results are reused.
    t_batched, res = best_of(sim.run, reps=5)
    # the one-decode-per-run invariant, read from a fresh engine (the
    # timing repeats pollute sim's counter) over a short trace window —
    # the invariant is S-independent
    fresh = ClusterSim(gcode, gate_trace.window(0, 50), policy,
                       decoder="onestep", s=12)
    fresh.run()
    batch_calls = fresh.engine.batch_calls

    t_loop, (loop_times, loop_errs) = best_of(
        lambda: _per_step_loop(gcode, gate_trace, policy), reps=1)

    speedup = t_loop / max(t_batched, 1e-12)
    err_dev = float(np.abs(res.errors - loop_errs).max())
    time_dev = float(np.abs(res.step_times - loop_times).max())
    print(f"\nthroughput gate n={gate_n} S={gate_steps}: "
          f"loop={t_loop:.3f}s  batched={t_batched:.3f}s  "
          f"speedup={speedup:.1f}x  (decode calls: {batch_calls}, "
          f"max err dev {err_dev:.2e})")

    # ---- 3. clustered-straggler trace: the SBM regime ----
    # whole worker blocks go slow together, aligned with the SBM code's
    # clusters (core.codes.block_ids) — the scenario the clustered
    # family exists for; one-step decode errors per scheme under a
    # deadline policy
    ctrace = make_trace("clustered", steps=min(steps, 200), n=n,
                        blocks=4, p_block=0.25, episode=8, seed=seed)
    clustered_rows = []
    # the sbm intra knob is the point of this section: intra-heavy
    # replication dies with its own block, cross-cluster replication
    # (low intra) survives whole-block loss
    cells = [(scheme, {}) for scheme in SCHEMES]
    cells.append(("sbm_cross", {"intra": 0.1}))
    for label, params in cells:
        fam = registry.get(label.split("_")[0])
        code = fam.make(k=n, n=n, s=s, seed=seed, **params)
        cres = ClusterSim(code, ctrace, "deadline", decoder="onestep",
                          s=s).run()
        clustered_rows.append({"scheme": label, "trace": "clustered",
                               "policy": "deadline", "decoder": "onestep",
                               "mean_error": cres.mean_error,
                               "mean_step_time": cres.mean_step_time})
    by_label = {r["scheme"]: r["mean_error"] for r in clustered_rows}
    print("\nclustered-straggler trace (deadline, onestep, err/k): "
          + "  ".join(f"{r['scheme']}={r['mean_error']:.4f}"
                      for r in clustered_rows))

    # ---- 4. device validation: run_distributed vs the analytic path ----
    vcode = registry.make("frc", k=n, n=n, s=s, seed=seed)
    vtrace = trace.window(0, min(steps, 100))
    dist_devs = {}
    for decoder in ("onestep", "optimal"):
        vsim = ClusterSim(vcode, vtrace, "deadline", decoder=decoder, s=s)
        vres = vsim.run_distributed()
        dev = float(np.abs(vres.errors
                           - vres.extras["analytic_errors"]).max())
        dist_devs[decoder] = dev
        n_dev = vres.extras["n_devices"]
    print(f"device validation (frc, deadline, {n_dev} device(s)): "
          + "  ".join(f"{d}: max dev {v:.2e}" for d, v in dist_devs.items()))

    # ---- 5. adaptive policy column (AdaptiveCoder, n = 256) ----
    # the closed loop against the FULL static grid on the two traces
    # where offline tuning hurts most: persistent slow nodes (bimodal)
    # and block-correlated episodes (clustered).  Every static cell
    # shares the adaptive run's reference s, so step times compare 1:1.
    adaptive_rows = []
    adaptive_ok = {}
    for tname, tkw in (("bimodal", {}),
                       ("clustered", dict(blocks=4, p_block=0.25,
                                          episode=8))):
        atrace = make_trace(tname, steps=steps, n=adaptive_n, seed=seed,
                            **tkw)
        static = sweep_frontier(("bgc",), POLICY_GRID, atrace, s=s,
                                seed=seed, decoders=("onestep", "optimal"))
        apt = sweep_adaptive(("bgc",), atrace, s=s,
                             error_budget=error_budget, seed=seed)[0]
        best_static = min(static, key=lambda p: p.time_to_target)
        adaptive_ok[tname] = all(
            apt.time_to_target < p.time_to_target for p in static)
        advantage = best_static.time_to_target / apt.time_to_target
        adaptive_rows += [dict(p.as_dict(), trace=tname)
                          for p in static + [apt]]
        print(f"\nadaptive column ({tname}, n={adaptive_n}, budget "
              f"{error_budget}): t={apt.mean_step_time:.3f}s "
              f"err={apt.mean_error:.4f} "
              f"t_target={apt.time_to_target:,.1f}s  vs best static "
              f"{best_static.policy}/{best_static.decoder} "
              f"t_target={best_static.time_to_target:,.1f}s  "
              f"-> advantage {advantage:.2f}x")
        adaptive_ok[f"advantage_{tname}"] = advantage

        # INFORMATIONAL (not gated): the hindsight-optimal static cell
        # with the s axis included — each (s', policy, decoder) cell's
        # modelled time charged s'/s for compute (the controller's own
        # model) and filtered to the error budget.  An offline pick with
        # full-trace knowledge beats a prefix-learning controller by the
        # usual online regret; this reports that gap honestly instead of
        # letting the fixed-s gate imply "better than any offline pick".
        hindsight = []
        for s_static in (2, 4, 8, 16):
            for p in sweep_frontier(("bgc",), POLICY_GRID, atrace,
                                    s=s_static, seed=seed,
                                    decoders=("onestep", "optimal")):
                if p.mean_error <= error_budget:
                    hindsight.append(
                        (p.time_to_target * s_static / s, s_static, p))
        if hindsight:
            h_ttt, h_s, h_p = min(hindsight, key=lambda r: r[0])
            regret = apt.time_to_target / h_ttt
            adaptive_ok[f"hindsight_regret_{tname}"] = regret
            print(f"  hindsight-optimal static (s axis, budget-feasible): "
                  f"s={h_s} {h_p.policy}/{h_p.decoder} "
                  f"t_target={h_ttt:,.1f}s -> online regret {regret:.2f}x")

    # ---- 6. staleness pipelining: convergence vs overlap (E11) ----
    # masks come from the same deadline policy the sim applies, so the
    # timed decode covers exactly the per-step ensemble the synchronous
    # path would decode behind its barrier.  The horizon is fixed (the
    # warm-start penalty is one step regardless of S, so a longer run
    # amortizes it while every step keeps paying the barrier).  The
    # decode uses ridge=0.01: at r = n = 256 the exact LS interpolation
    # of the ill-conditioned bgc Gram has unbounded +-5 weights whose
    # re-masked stale form decodes WORSE than w = 0 — the ridge bounds
    # the weights (the paper's own ill-conditioning caveat) at an
    # unchanged steady-state error, which is what makes stale reuse
    # safe (docs/architecture.md §10).
    stale_steps = 1000
    btrace = make_trace("bimodal", steps=stale_steps, n=adaptive_n,
                        seed=seed)
    scode = registry.make("bgc", k=adaptive_n, n=adaptive_n, s=12,
                          seed=seed)
    seng = DecodeEngine(scode, s=12, ridge=0.01)
    bpolicy = make_policy("deadline")
    bmasks = np.empty((stale_steps, adaptive_n), dtype=bool)
    bstate = None
    for t in range(stale_steps):
        bmasks[t], _, bstate = bpolicy.step(btrace.latencies[t], bstate)
    t_dec, _ = best_of(lambda: seng.decode_batch(bmasks, "optimal"),
                       reps=1)
    decode_cost = t_dec / stale_steps
    staleness_rows = []
    tts = {}
    for st in (0, 1, 2):
        sres = ClusterSim(scode, btrace, "deadline", decoder="optimal",
                          s=12, staleness=st, decode_cost=decode_cost,
                          engine=DecodeEngine(scode, s=12, ridge=0.01)
                          ).run()
        tts[st] = time_to_target_error(sres)
        staleness_rows.append({
            "trace": "bimodal", "scheme": "bgc", "staleness": st,
            "decode_cost": decode_cost, "mean_error": sres.mean_error,
            "total_time": sres.total_time, "time_to_target": tts[st]})
    print(f"\nstaleness pipelining (bimodal, n={adaptive_n}, "
          f"S={stale_steps}, decode_cost {decode_cost * 1e3:.3f}ms/step): "
          + "  ".join(f"st={r['staleness']}: err={r['mean_error']:.4f} "
                      f"T={r['total_time']:,.1f}s "
                      f"tt={r['time_to_target']:,.1f}s"
                      for r in staleness_rows))

    n_cells = len({(r["scheme"], r["policy"]) for r in rows})
    # the new families must reach the frontier with BOTH decoders (the
    # registry acceptance: no more hardcoded {frc, bgc, cyclic} walls)
    emitted = {(r["scheme"], r["decoder"]) for r in rows}
    new_family_cells = all((f, d) in emitted for f in NEW_FAMILIES
                           for d in ("onestep", "optimal"))
    checks = {
        "grid_ge_3x3": bool(len(set(SCHEMES)) >= 3
                            and len(set(POLICY_GRID)) >= 3
                            and n_cells >= 9),
        "sbm_expander_on_frontier_grid": bool(new_family_cells),
        # cross-cluster replication beats intra-heavy replication when
        # whole blocks fail together (the SBM family's reason to exist)
        "sbm_cross_cluster_beats_intra_on_clustered_trace": bool(
            by_label["sbm_cross"] <= by_label["sbm"]),
        "one_batched_decode_per_cell": bool(batch_calls == 1),
        "speedup_ge_10x": bool(speedup >= 10.0),
        "errors_match_loop_1e-9": bool(err_dev <= 1e-9),
        "times_match_loop_1e-9": bool(time_dev <= 1e-9),
        # fp32 on-device vs fp64 analytic: 1e-4 absorbs the cast only
        "dist_errors_match_analytic_1e-4": bool(
            max(dist_devs.values()) <= 1e-4),
        # the adaptive controller beats EVERY static (policy, decoder)
        # cell on time-to-target, both traces — the closed loop finds a
        # better operating point than any offline pick
        "adaptive_dominates_static_bimodal": bool(adaptive_ok["bimodal"]),
        "adaptive_dominates_static_clustered": bool(
            adaptive_ok["clustered"]),
        # overlapping the decode with backprop must not cost wall-clock
        # convergence: the one-step-stale run reaches the target no
        # later than the synchronous barrier run
        "staleness1_tt_le_sync": bool(tts[1] <= tts[0]),
        # every registry family on the grid reports a finite gap to the
        # fundamental limit (the VALUES are informational; presence is
        # the gate — a missing family means the bound or the sweep broke)
        "gap_to_optimal_all_families": bool(
            all(scheme in gap_col
                and np.isfinite(gap_col[scheme]["gap"])
                for scheme in SCHEMES)),
    }
    payload = {
        "trace": {"source": trace.source, "steps": steps, "n": n},
        "rows": rows,
        "gap_to_optimal": gap_col,
        "pareto_front": [p.as_dict() for p in front],
        "gate": {"n": gate_n, "steps": gate_steps, "loop_s": t_loop,
                 "batched_s": t_batched, "speedup": speedup,
                 "max_err_dev": err_dev},
        "clustered_trace": clustered_rows,
        "dist_validation": {"n_devices": int(n_dev),
                            "max_dev_by_decoder": dist_devs},
        "adaptive": {"n": adaptive_n, "error_budget": error_budget,
                     "rows": adaptive_rows,
                     "advantage_bimodal": adaptive_ok["advantage_bimodal"],
                     "advantage_clustered":
                         adaptive_ok["advantage_clustered"],
                     "hindsight_regret_bimodal":
                         adaptive_ok.get("hindsight_regret_bimodal"),
                     "hindsight_regret_clustered":
                         adaptive_ok.get("hindsight_regret_clustered")},
        "staleness": {"n": adaptive_n, "steps": stale_steps,
                      "trace": "bimodal", "ridge": 0.01,
                      "decode_cost": decode_cost, "rows": staleness_rows},
        "checks": checks,
    }
    save_json("wallclock_frontier", payload)
    save_csv("wallclock_frontier", rows)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--gate-n", type=int, default=256)
    ap.add_argument("--gate-steps", type=int, default=2000)
    ap.add_argument("--adaptive-n", type=int, default=256)
    ap.add_argument("--error-budget", type=float, default=0.1)
    args = ap.parse_args(argv)
    rep = run(n=args.n, steps=args.steps, s=args.s, gate_n=args.gate_n,
              gate_steps=args.gate_steps, adaptive_n=args.adaptive_n,
              error_budget=args.error_budget)
    print("wallclock frontier checks:", rep["checks"])
    ok = all(rep["checks"].values())
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
