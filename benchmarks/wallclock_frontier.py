"""E11: ClusterSim wall-clock × accuracy frontier (the paper's headline
trade-off, measured end to end).

Two parts:

  1. Frontier grid — one shared Pareto-tail latency trace, swept over
     schemes × sync policies (and the one-step vs optimal decoders at
     the grid corners): each cell is one ClusterSim run = one batched
     decode, contributing a (wall-clock, decode-error) point.  The
     Pareto front of those points IS the runtime-vs-accuracy frontier.

  2. Throughput gate — at n = 256, S = 1000 steps, the ClusterSim path
     (policy over the whole trace + ONE batched decode) must beat the
     per-step decode loop (slice + scalar decode every step, the
     pre-ClusterSim dataflow) by >= 10x.

  3. Device validation — frontier corner cells re-run through
     ClusterSim.run_distributed(): the same masks decoded by the REAL
     shard_map coded all-reduce (DESIGN.md §9) with basis task
     gradients, whose on-device errors must match the analytic ones.
     Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 for
     a true multi-device mesh; one device still validates the path.

Artifacts: artifacts/bench/wallclock_frontier.{json,csv}.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import codes, decoding
from repro.sim import (ClusterSim, make_policy, make_trace, pareto_front,
                       sweep_frontier)
from .common import ascii_curves, save_csv, save_json

SCHEMES = ("frc", "bgc", "rbgc")
POLICY_GRID = ("sync", "deadline", "backup", "adaptive")


def _per_step_loop(code, trace, policy):
    """The pre-ClusterSim dataflow: one policy step + one scalar decode
    per step."""
    G, k, s = code.G, code.k, code.s
    S = trace.steps
    times = np.empty(S)
    errs = np.empty(S)
    state = None
    for t in range(S):
        mask, times[t], state = policy.step(trace.latencies[t], state)
        A = G[:, mask]
        r = int(mask.sum())
        errs[t] = decoding.err1(A, decoding.default_rho(k, r, s)) / k
    return times, errs


def run(n: int = 64, steps: int = 400, s: int = 8, seed: int = 0,
        gate_n: int = 256, gate_steps: int = 1000):
    trace = make_trace("pareto", steps=steps, n=n, deadline=1.5,
                       tail_scale=0.4, seed=seed)

    # ---- 1. the frontier grid ----
    points = sweep_frontier(SCHEMES, POLICY_GRID, trace, s=s, seed=seed,
                            decoders=("onestep", "optimal"))
    rows = [p.as_dict() for p in points]
    front = pareto_front(points)
    series = {}
    for scheme in SCHEMES:
        ys = [p.mean_error for p in points
              if p.scheme == scheme and p.decoder == "onestep"]
        series[scheme] = ys
    xs = [p.mean_step_time for p in points
          if p.scheme == SCHEMES[0] and p.decoder == "onestep"]
    print(ascii_curves("decode err/k by policy (x: policy index)",
                       list(range(len(xs))), series))
    print("\npareto front (mean_step_time, mean_err/k):")
    for p in front:
        print(f"  {p.scheme:>5} / {p.policy:<8} / {p.decoder:<8} "
              f"t={p.mean_step_time:7.3f}s  err={p.mean_error:.4f}  "
              f"t_target={p.time_to_target:8.1f}s")

    # ---- 2. throughput gate: batched ClusterSim vs per-step loop ----
    gate_trace = make_trace("pareto", steps=gate_steps, n=gate_n,
                            deadline=1.5, tail_scale=0.4, seed=seed)
    gcode = codes.make_code("bgc", k=gate_n, n=gate_n, s=12,
                            rng=np.random.default_rng(seed))
    policy = make_policy("deadline")
    sim = ClusterSim(gcode, gate_trace, policy, decoder="onestep", s=12)

    t0 = time.perf_counter()
    res = sim.run()
    t_batched = time.perf_counter() - t0
    batch_calls = sim.engine.batch_calls

    t0 = time.perf_counter()
    loop_times, loop_errs = _per_step_loop(gcode, gate_trace, policy)
    t_loop = time.perf_counter() - t0

    speedup = t_loop / max(t_batched, 1e-12)
    err_dev = float(np.abs(res.errors - loop_errs).max())
    time_dev = float(np.abs(res.step_times - loop_times).max())
    print(f"\nthroughput gate n={gate_n} S={gate_steps}: "
          f"loop={t_loop:.3f}s  batched={t_batched:.3f}s  "
          f"speedup={speedup:.1f}x  (decode calls: {batch_calls}, "
          f"max err dev {err_dev:.2e})")

    # ---- 3. device validation: run_distributed vs the analytic path ----
    vcode = codes.make_code("frc", k=n, n=n, s=s,
                            rng=np.random.default_rng(seed))
    vtrace = trace.window(0, min(steps, 100))
    dist_devs = {}
    for decoder in ("onestep", "optimal"):
        vsim = ClusterSim(vcode, vtrace, "deadline", decoder=decoder, s=s)
        vres = vsim.run_distributed()
        dev = float(np.abs(vres.errors
                           - vres.extras["analytic_errors"]).max())
        dist_devs[decoder] = dev
        n_dev = vres.extras["n_devices"]
    print(f"device validation (frc, deadline, {n_dev} device(s)): "
          + "  ".join(f"{d}: max dev {v:.2e}" for d, v in dist_devs.items()))

    n_cells = len({(r["scheme"], r["policy"]) for r in rows})
    checks = {
        "grid_ge_3x3": bool(len(set(SCHEMES)) >= 3
                            and len(set(POLICY_GRID)) >= 3
                            and n_cells >= 9),
        "one_batched_decode_per_cell": bool(batch_calls == 1),
        "speedup_ge_10x": bool(speedup >= 10.0),
        "errors_match_loop_1e-9": bool(err_dev <= 1e-9),
        "times_match_loop_1e-9": bool(time_dev <= 1e-9),
        # fp32 on-device vs fp64 analytic: 1e-4 absorbs the cast only
        "dist_errors_match_analytic_1e-4": bool(
            max(dist_devs.values()) <= 1e-4),
    }
    payload = {
        "trace": {"source": trace.source, "steps": steps, "n": n},
        "rows": rows,
        "pareto_front": [p.as_dict() for p in front],
        "gate": {"n": gate_n, "steps": gate_steps, "loop_s": t_loop,
                 "batched_s": t_batched, "speedup": speedup,
                 "max_err_dev": err_dev},
        "dist_validation": {"n_devices": int(n_dev),
                            "max_dev_by_decoder": dist_devs},
        "checks": checks,
    }
    save_json("wallclock_frontier", payload)
    save_csv("wallclock_frontier", rows)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--gate-n", type=int, default=256)
    ap.add_argument("--gate-steps", type=int, default=1000)
    args = ap.parse_args(argv)
    rep = run(n=args.n, steps=args.steps, s=args.s, gate_n=args.gate_n,
              gate_steps=args.gate_steps)
    print("wallclock frontier checks:", rep["checks"])
    ok = all(rep["checks"].values())
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
