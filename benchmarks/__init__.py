"""Benchmark harness package.

Importing this package (``python -m benchmarks.<bench>``) applies the
REPRO_* device-world env (platform / host devices / x64) through
``repro.platform.configure_from_env()`` BEFORE any benchmark module
imports jax — the same bootstrap tests get from tests/conftest.py, and
the way the CI bench lane exports its world (``REPRO_PLATFORM: cpu``)
without hand-rolled jax env strings.  Pre-set env still wins verbatim.

``check_regression`` runs without PYTHONPATH=src (it never imports
repro), so a missing repro package is silently fine here.
"""

try:  # pragma: no cover - repro needs PYTHONPATH=src or a pip install
    from repro.platform import configure_from_env
except ImportError:  # pragma: no cover
    pass
else:
    configure_from_env()
