"""End-to-end coded training (the paper's motivating application).

Trains a reduced-config LM with the CodedTrainer under straggler models
and compares:

    oracle          uncoded, no stragglers (upper bound on quality)
    sync            uncoded, wait-for-all  (same quality, worst wall-clock)
    ignore          drop straggler gradients, rescale (no coding)
    frc+onestep     the paper's FRC under Algorithm-1 decoding
    frc+optimal     FRC under Algorithm-2 decoding
    bgc+onestep     Bernoulli code, Algorithm 1
    bgc+optimal     Bernoulli code, Algorithm 2

Quality = final train loss (deterministic synthetic LM task); wall-clock
comes from the analytic latency model (this box is CPU-only): coded runs
use the 'deadline' policy (stragglers -> decode error, step time capped),
sync waits for the slowest worker.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import DeadlineStragglers, FixedFractionStragglers, \
    NoStragglers
from repro.sim import trace_from_model, wallclock_summary
from repro.training import CodedTrainConfig, CodedTrainer
from .common import save_csv, save_json

VARIANTS = (
    # name, code, decoder, stragglers?, grad compression
    ("oracle", "uncoded", "onestep", False, "none"),
    ("sync", "uncoded", "onestep", False, "none"),
    ("ignore", "uncoded", "ignore", True, "none"),
    ("frc+onestep", "frc", "onestep", True, "none"),
    ("frc+optimal", "frc", "optimal", True, "none"),
    ("bgc+onestep", "bgc", "onestep", True, "none"),
    ("bgc+optimal", "bgc", "optimal", True, "none"),
    # coding composes with int8 gradient compression (decode is linear)
    ("bgc+onestep+int8", "bgc", "onestep", True, "int8"),
)


def run(steps: int = 40, n_workers: int = 8, s: int = 2, delta: float = 0.25,
        seq_len: int = 64, seed: int = 0, arch: str = "minicpm-2b"):
    if n_workers % s:
        raise ValueError("FRC variants need s | n_workers")
    cfg = get_config(arch, smoke=True)
    rows = []
    for name, code, decoder, stragglers, compress in VARIANTS:
        model = build_model(cfg)
        straggler_model = (
            FixedFractionStragglers(delta=delta, seed=seed) if stragglers
            else NoStragglers())
        tcfg = CodedTrainConfig(
            code=code, n_workers=n_workers, s=s if code != "uncoded" else 1,
            decoder=decoder, seq_len=seq_len, steps=steps, seed=seed,
            opt=OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                          clip_norm=1.0, compress=compress),
            log_every=max(steps // 10, 1))
        trainer = CodedTrainer(model, tcfg, straggler_model=straggler_model)
        out = trainer.run()
        hist = out["history"]
        final = float(np.mean([h["mean_ce"] for h in hist[-3:]]))
        mean_decode_err = float(np.mean([h["decode_err"] for h in hist]))
        # modelled wall-clock: coded -> deadline policy; sync -> wait-all.
        # compute_scale=1: the s assigned tasks run on s cores per machine
        # (the paper's Fig-1 multi-core worker) so per-worker latency is
        # dominated by the machine's speed, not the task count.
        lat_model = DeadlineStragglers(deadline=1.5, tail_scale=0.4, seed=seed)
        policy = "sync" if name in ("oracle", "sync") else "deadline"
        wc = wallclock_summary(trace_from_model(lat_model, steps, n_workers),
                               policy=policy, compute_scale=1.0)
        rows.append({
            "variant": name, "code": code, "decoder": decoder,
            "delta": delta if stragglers else 0.0,
            "final_ce": final, "mean_decode_err": mean_decode_err,
            "modelled_step_time_s": wc["mean_step_time"],
            "loss_curve": [h["mean_ce"] for h in hist],
        })
        print(f"[{name:>12}] final_ce={final:.4f} "
              f"decode_err/k={mean_decode_err:.4f} "
              f"step_time={wc['mean_step_time']:.3f}s")

    by = {r["variant"]: r for r in rows}
    oracle = by["oracle"]["final_ce"]
    checks = {
        # coded training converges close to the no-straggler oracle
        "frc_onestep_near_oracle":
            by["frc+onestep"]["final_ce"] < oracle * 1.15 + 0.05,
        "bgc_onestep_near_oracle":
            by["bgc+onestep"]["final_ce"] < oracle * 1.25 + 0.08,
        # optimal decoding >= one-step quality (lower decode error)
        "optimal_decode_err_lower":
            by["frc+optimal"]["mean_decode_err"]
            <= by["frc+onestep"]["mean_decode_err"] + 1e-6,
        # the paper's headline: the deadline policy's step time is capped
        # (stragglers become decode error) while wait-for-all pays the tail
        "coded_step_time_capped":
            by["frc+onestep"]["modelled_step_time_s"] <= 1.5 + 1e-9,
        "sync_pays_the_tail":
            by["sync"]["modelled_step_time_s"]
            > by["frc+onestep"]["modelled_step_time_s"],
        # int8 gradient compression composes with coding (decode linear)
        "int8_composes_with_coding":
            by["bgc+onestep+int8"]["final_ce"]
            < by["bgc+onestep"]["final_ce"] * 1.1 + 0.1,
        # everything still trains (sanity)
        "all_losses_finite": all(np.isfinite(r["final_ce"]) for r in rows),
    }
    save_csv("e2e_convergence",
             [{k: v for k, v in r.items() if k != "loss_curve"} for r in rows])
    save_json("e2e_convergence", {"rows": rows, "checks": checks})
    return {"rows": rows, "checks": checks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--delta", type=float, default=0.25)
    args = ap.parse_args(argv)
    rep = run(steps=args.steps, n_workers=args.workers, delta=args.delta)
    ok = all(bool(v) for v in rep["checks"].values())
    print("e2e checks:", {k: bool(v) for k, v in rep["checks"].items()})
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
