"""E13: time-to-target through membership churn (docs/benchmarks.md).

Drives a preemption/scale-out storm (``sim.traces.make_churn_scenario``)
through :func:`repro.sim.simulate_churn` under the three recovery modes
and gates their modelled time-to-target ordering:

    elastic  <=  restart  <=  oblivious

* ``elastic``  — re-code the fleet at every membership epoch (the
  paper's O(n s) cheap-construction property makes the re-code ~free);
* ``restart``  — gang-scheduling semantics: any membership change
  restores the last checkpoint and redoes the lost steps plus a
  scheduler penalty;
* ``oblivious`` — the code ignores churn, departed workers become
  permanent erasures and decode error accumulates (time-to-target
  inflates toward the canonical 100x clip).

Two further sections make the gate end-to-end honest:

* **external replay** — a committed sample in the public Google
  ``clusterdata-2011`` ``machine_events`` schema is ingested
  (``ingest_machine_events``), round-tripped through the ChurnScenario
  JSON path, and replayed through all three modes, so the arrival/
  departure process of a real-format cluster trace flows through the
  same machinery CI gates;
* **trainer recovery** — a tiny CodedTrainer is run through the same
  scenario twice: uninterrupted, and killed-then-restarted (a fresh
  trainer resuming via checkpoint metadata).  The resumed run's
  per-step mean_ce and final params must equal the uninterrupted one's
  bitwise — checkpoints carry enough state (code family/params/s/n/
  decoder, build counter, churn cursor, live ids, controller state)
  that recovery is exact, not approximate.

Usage:
    PYTHONPATH=src python -m benchmarks.elastic_churn [--steps N]
        [--seeds 7,17,27] [--skip-trainer]
"""

from __future__ import annotations

import argparse
from pathlib import Path

import numpy as np

from repro.sim import (
    ChurnScenario,
    RECOVERY_MODES,
    ingest_machine_events,
    make_churn_scenario,
    simulate_churn,
    time_to_target_error,
)

from .common import ART, save_csv, save_json

DATA = Path(__file__).resolve().parent / "data"
SAMPLE_CSV = DATA / "machine_events_sample.csv"

# the storm: heavy spot preemption + block kills + scale-outs over a
# 32-worker fleet (capacity headroom for arrivals), heterogeneous
# per-worker speeds
STORM = dict(
    n0=32,
    preempt_rate=0.08,
    preempt_max=3,
    block_rate=0.02,
    scaleup_rate=0.03,
    speed_sigma=0.3,
    min_workers=8,
)
SCHEME = "bgc"  # frc needs s | k and n == k: churn sizes are arbitrary
S = 6
CKPT_EVERY = 10
RESTART_PENALTY = 10.0


def _modes(scenario: ChurnScenario, *, s: int = S,
           ckpt_every: int = CKPT_EVERY) -> dict:
    """time-to-target (and raw time/error) per recovery mode."""
    out = {}
    for recovery in RECOVERY_MODES:
        res = simulate_churn(SCHEME, scenario, "deadline", decoder="onestep",
                             s=s, recovery=recovery, ckpt_every=ckpt_every,
                             restart_penalty=RESTART_PENALTY)
        out[recovery] = {
            "total_time": res.total_time,
            "mean_error": res.mean_error,
            "time_to_target": time_to_target_error(res),
            "epochs": res.extras["epochs"],
            "decode_calls": res.extras["decode_calls"],
            "redo_time": res.extras.get("redo_time", 0.0),
        }
    return out


def _trainer_recovery_check(steps: int = 30) -> dict:
    """Killed-then-restarted CodedTrainer == uninterrupted, bitwise."""
    import tempfile

    import jax

    from repro import configs as CFG
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.training import CodedTrainConfig, CodedTrainer

    model = build_model(CFG.get_config("minicpm-2b", smoke=True))
    scn = make_churn_scenario("bimodal", steps=steps, n0=8,
                              preempt_rate=0.12, scaleup_rate=0.06,
                              min_workers=3, seed=11)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=max(steps, 50))

    def cfg(d):
        return CodedTrainConfig(code=SCHEME, n_workers=8, s=2, steps=steps,
                                seq_len=8, seed=0, opt=opt, log_every=1,
                                ckpt_dir=d, ckpt_every=max(steps // 4, 1))

    with tempfile.TemporaryDirectory() as d_ref:
        ref = CodedTrainer(model, cfg(d_ref), churn=scn, recovery="elastic")
        out_ref = ref.run()
    ce_ref = {r["step"]: r["mean_ce"] for r in out_ref["history"]}

    kill_at = (2 * steps) // 3  # past the first checkpoint, mid-run
    with tempfile.TemporaryDirectory() as d:
        first = CodedTrainer(model, cfg(d), churn=scn, recovery="elastic")
        first.run(steps=kill_at)  # "killed" here: process ends, dir stays
        resumed = CodedTrainer(model, cfg(d), churn=scn, recovery="elastic")
        out_res = resumed.run()  # fresh process restores + finishes the job

        ce_match = all(ce_ref[r["step"]] == r["mean_ce"]
                       for r in out_res["history"])
        leaves_ref = jax.tree_util.tree_leaves(out_ref["state"]["params"])
        leaves_res = jax.tree_util.tree_leaves(out_res["state"]["params"])
        params_match = all(np.array_equal(np.asarray(a), np.asarray(b))
                           for a, b in zip(leaves_ref, leaves_res))
    return {
        "resumed_from": out_res["history"][0]["step"],
        "kill_at": kill_at,
        "mean_ce_bitwise_match": bool(ce_match),
        "params_bitwise_match": bool(params_match),
        "churn_events_trained_through": len(out_ref["history"]) and
        len(resumed.churn_log) + len(first.churn_log),
    }


def run(steps: int = 300, seeds=(7, 17, 27), trainer: bool = True) -> dict:
    # ---- generated storm, three recovery modes, several seeds ----
    rows = []
    agg: dict = {m: [] for m in RECOVERY_MODES}
    per_seed_ok = []
    for seed in seeds:
        scn = make_churn_scenario("bimodal", steps=steps, seed=seed, **STORM)
        modes = _modes(scn)
        for mode, r in modes.items():
            rows.append(dict(section="storm", seed=seed, recovery=mode,
                             n_events=len(scn.events), **r))
            agg[mode].append(r["time_to_target"])
        tts = {m: modes[m]["time_to_target"] for m in RECOVERY_MODES}
        per_seed_ok.append(tts["elastic"] <= tts["restart"]
                           <= tts["oblivious"])
    mean_tt = {m: float(np.mean(v)) for m, v in agg.items()}

    # ---- external trace: ingest -> JSON round trip -> replay ----
    ext = ingest_machine_events(SAMPLE_CSV, bin_seconds=300.0, seed=0)
    ART.mkdir(parents=True, exist_ok=True)
    replay_path = ART / "churn_external_replay.json"
    ext.save(replay_path)
    ext2 = ChurnScenario.load(replay_path)  # the JSON-replay path
    roundtrip_ok = (ext2.events == ext.events and ext2.n0 == ext.n0
                    and np.array_equal(ext2.trace.latencies,
                                       ext.trace.latencies)
                    and np.array_equal(ext2.speed, ext.speed))
    ext_modes = _modes(ext2, s=4, ckpt_every=5)
    for mode, r in ext_modes.items():
        rows.append(dict(section="external", seed=0, recovery=mode,
                         n_events=len(ext2.events), **r))

    # ---- trainer restart recovery (the checkpoint metadata contract) ----
    trainer_res = _trainer_recovery_check() if trainer else None

    checks = {
        # the E13 gate: through the storm, elastic beats restart beats
        # churn-oblivious on mean modelled time-to-target, every seed
        "storm_ordering_each_seed": all(per_seed_ok),
        "storm_ordering_mean": (mean_tt["elastic"] <= mean_tt["restart"]
                                <= mean_tt["oblivious"]),
        # external-format trace flows end to end and re-coding never
        # loses to redoing work from checkpoints on it either
        "external_roundtrip": bool(roundtrip_ok),
        "external_elastic_le_restart": (
            ext_modes["elastic"]["time_to_target"]
            <= ext_modes["restart"]["time_to_target"]),
        # one batched decode per membership epoch (ClusterSim invariant)
        "decode_calls_match_epochs": all(
            r["decode_calls"] == r["epochs"] for r in rows
            if r["recovery"] != "oblivious"),
    }
    if trainer_res is not None:
        checks["restart_equals_uninterrupted"] = (
            trainer_res["mean_ce_bitwise_match"]
            and trainer_res["params_bitwise_match"])

    payload = {
        "benchmark": "elastic_churn",
        "storm": dict(STORM, steps=steps, seeds=list(seeds), scheme=SCHEME,
                      s=S, ckpt_every=CKPT_EVERY,
                      restart_penalty=RESTART_PENALTY),
        "mean_time_to_target": mean_tt,
        # machine-free modelled ratios tracked by check_regression
        "advantage": {
            "churn_advantage": mean_tt["restart"] / mean_tt["elastic"],
            "oblivious_penalty": mean_tt["oblivious"] / mean_tt["elastic"],
        },
        "external": {"source": SAMPLE_CSV.name, "n0": ext.n0,
                     "n_max": ext.n_max, "steps": ext.steps,
                     "n_events": len(ext.events), "modes": ext_modes},
        "trainer_recovery": trainer_res,
        "rows": rows,
        "checks": checks,
    }
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300,
                    help="storm length in steps (default 300)")
    ap.add_argument("--seeds", default="7,17,27",
                    help="comma list of storm seeds")
    ap.add_argument("--skip-trainer", action="store_true",
                    help="skip the (jitted) trainer recovery check")
    args = ap.parse_args(argv)
    seeds = tuple(int(x) for x in args.seeds.split(","))

    payload = run(steps=args.steps, seeds=seeds,
                  trainer=not args.skip_trainer)
    save_json("elastic_churn", payload)
    save_csv("elastic_churn", payload["rows"])

    print(f"storm mean time-to-target over seeds {list(seeds)}:")
    for mode, tt in payload["mean_time_to_target"].items():
        print(f"  {mode:<10} {tt:10.1f}")
    adv = payload["advantage"]
    print(f"churn advantage (restart/elastic):    {adv['churn_advantage']:.2f}x")
    print(f"oblivious penalty (oblivious/elastic): "
          f"{adv['oblivious_penalty']:.2f}x")
    ext = payload["external"]
    print(f"external replay: {ext['source']} n0={ext['n0']} "
          f"steps={ext['steps']} events={ext['n_events']}")
    if payload["trainer_recovery"] is not None:
        tr = payload["trainer_recovery"]
        print(f"trainer recovery: killed at {tr['kill_at']}, resumed from "
              f"{tr['resumed_from']}, bitwise match="
              f"{tr['mean_ce_bitwise_match'] and tr['params_bitwise_match']}")

    ok = all(payload["checks"].values())
    for name, passed in payload["checks"].items():
        print(f"  {'PASS' if passed else 'MISMATCH'}  {name}")
    print("E13", "PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
