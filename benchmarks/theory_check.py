"""Closed-form theory vs Monte Carlo (Theorems 5, 6, 7/8, 21 + exact BGC).

The paper's Thm 5/6 algebra contains two finite-k slips (documented in
EXPERIMENTS.md errata); we check BOTH the printed forms (loose at small k)
and the corrected exact forms (tight at all k)."""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import codes, decoding, simulate, theory
from .common import save_csv, save_json


def run(trials: int = 2000, seed: int = 0):
    rows = []
    checks = {}

    # ---- Thm 5 (FRC one-step) ----
    for (k, s, delta) in [(100, 5, 0.2), (100, 10, 0.4), (60, 6, 0.5)]:
        r = int(round((1 - delta) * k))
        mc = simulate.monte_carlo_error("frc", k=k, n=k, s=s, delta=delta,
                                        trials=trials, decoder="onestep",
                                        seed=seed).mean * k
        exact = theory.thm5_expected_err1_frc_exact(k, s, r)
        printed = theory.thm5_expected_err1_frc(k, s, delta)
        rows.append({"thm": "5", "k": k, "s": s, "delta": delta, "mc": mc,
                     "exact": exact, "printed": printed})
        checks[f"thm5_k{k}s{s}d{delta}"] = bool(
            abs(mc - exact) / max(exact, 1e-9) < 0.15)

    # ---- Thm 6 (FRC optimal) ----
    for (k, s, delta) in [(100, 5, 0.3), (100, 10, 0.5), (60, 6, 0.4)]:
        r = int(round((1 - delta) * k))
        mc = simulate.monte_carlo_error("frc", k=k, n=k, s=s, delta=delta,
                                        trials=trials, decoder="optimal",
                                        seed=seed).mean * k
        exact = theory.thm6_expected_err_frc(k, s, r)
        printed = theory.thm6_expected_err_frc_as_printed(k, s, r)
        rows.append({"thm": "6", "k": k, "s": s, "delta": delta, "mc": mc,
                     "exact": exact, "printed": printed})
        checks[f"thm6_k{k}s{s}d{delta}"] = bool(
            abs(mc - exact) <= max(0.2 * exact, 0.35))

    # ---- Thm 7/8 tails + Cor 9 zero-error threshold ----
    k, delta = 100, 0.3
    r = int(round((1 - delta) * k))
    s_min = int(np.ceil(theory.cor9_s_zero_error(k, delta)))
    s0 = next(s for s in range(s_min, k + 1) if k % s == 0)  # FRC needs s | k
    nz = 0
    for t in range(trials):
        code = codes.frc(k=k, n=k, s=s0)
        mask = simulate.sample_straggler_mask(
            k, k - r, np.random.default_rng(seed + t))
        if decoding.err(code.G[:, mask]) > 1e-9:
            nz += 1
    p_nz = nz / trials
    rows.append({"thm": "cor9", "k": k, "s": s0, "delta": delta,
                 "mc": p_nz, "exact": 1.0 / k, "printed": 1.0 / k})
    checks["cor9_zero_error_whp"] = bool(p_nz <= 1.0 / k + 3 *
                                         np.sqrt(1.0 / k / trials) + 5e-3)

    # Thm 7: tail bound holds at every alpha
    s = 10
    tail_ok = True
    errs = []
    for t in range(trials):
        code = codes.frc(k=k, n=k, s=s)
        mask = simulate.sample_straggler_mask(
            k, k - r, np.random.default_rng(seed + 10_000 + t))
        errs.append(decoding.err(code.G[:, mask]))
    errs = np.asarray(errs)
    for alpha in range(0, 5):
        emp = float((errs > alpha * s + 1e-9).mean())
        bound = theory.thm7_tail_frc(k, s, r, alpha)
        rows.append({"thm": "7", "k": k, "s": s, "delta": delta,
                     "mc": emp, "exact": bound, "printed": bound,
                     "alpha": alpha})
        tail_ok &= emp <= bound + 3 * np.sqrt(bound / trials) + 5e-3
    checks["thm7_tail_bound_holds"] = bool(tail_ok)

    # ---- BGC exact mean (one-step) + Thm 21 shape calibration ----
    cs = []
    for (k, s, delta) in [(100, 8, 0.2), (100, 12, 0.4), (200, 10, 0.3)]:
        r = int(round((1 - delta) * k))
        mc = simulate.monte_carlo_error("bgc", k=k, n=k, s=s, delta=delta,
                                        trials=trials, decoder="onestep",
                                        seed=seed).mean * k
        exact = theory.expected_err1_bgc_exact(k, s, r)
        rows.append({"thm": "bgc_exact", "k": k, "s": s, "delta": delta,
                     "mc": mc, "exact": exact, "printed": exact})
        checks[f"bgc_exact_k{k}s{s}"] = bool(
            abs(mc - exact) / max(exact, 1e-9) < 0.15)
        # calibrate Thm 21's constant: err1 <= C^2 k/((1-delta)s)
        cs.append(np.sqrt(mc * (1 - delta) * s / k))
    checks["thm21_constant_O1"] = bool(max(cs) < 3.0)  # C is a small O(1)
    rows.append({"thm": "21C", "k": 0, "s": 0, "delta": 0,
                 "mc": float(max(cs)), "exact": 3.0, "printed": 3.0})

    save_csv("theory_check", rows)
    save_json("theory_check", {"rows": rows, "checks": checks})
    return {"rows": rows, "checks": checks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=2000)
    args = ap.parse_args(argv)
    rep = run(trials=args.trials)
    for r in rep["rows"]:
        print(r)
    ok = all(rep["checks"].values())
    print("theory checks:", rep["checks"])
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
