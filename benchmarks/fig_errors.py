"""Figures 2-4 reproduction: decoding error vs straggler fraction.

Fig 2: mean err_1(A)/k (one-step decode), k=100, s in {5,10},
       schemes FRC / BGC / s-regular.
Fig 3: mean err(A)/k (optimal decode), same grid.
Fig 4: one-step vs optimal per scheme.

Paper claims validated here (EXPERIMENTS.md cites the numbers):
  * one-step: FRC ~= s-regular << ... with BGC a constant factor worse;
  * optimal: FRC >> others — near-zero error up to large delta
    (s=10: near-zero until delta ~ 0.5);
  * err_1 >= err always (one-step upper-bounds optimal).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import registry, simulate
from .common import ascii_curves, save_csv, save_json

# the paper's Fig. 2-4 trio; the claim checks below are specific to it.
# --schemes can extend the sweep to any registered family (the rows and
# artifacts include them; the checks still run on the trio).
SCHEMES = ("frc", "bgc", "sregular")
DELTAS = tuple(np.round(np.arange(0.05, 0.85, 0.05), 2))


def run(trials: int = 1000, k: int = 100, seed: int = 0,
        schemes=SCHEMES) -> dict:
    for scheme in schemes:          # fail fast on unregistered schemes
        registry.get(scheme)
    rows = []
    for s in (5, 10):
        for decoder in ("onestep", "optimal"):
            for res in simulate.sweep_delta(schemes, DELTAS, k=k, s=s,
                                            trials=trials, decoder=decoder,
                                            seed=seed):
                rows.append(dataclass_row(res))
    save_csv("fig2_3_4_errors", rows)
    save_json("fig2_3_4_errors", rows)

    report = {"rows": rows, "checks": {}}
    get = lambda s_, dec, sch: [r["mean"] for r in rows
                                if r["s"] == s_ and r["decoder"] == dec
                                and r["scheme"] == sch]
    # --- paper-claim checks ---
    for s in (5, 10):
        frc1 = np.array(get(s, "onestep", "frc"))
        sreg1 = np.array(get(s, "onestep", "sregular"))
        bgc1 = np.array(get(s, "onestep", "bgc"))
        frc_o = np.array(get(s, "optimal", "frc"))
        sreg_o = np.array(get(s, "optimal", "sregular"))
        bgc_o = np.array(get(s, "optimal", "bgc"))
        checks = {
            # Fig 2: FRC and s-regular comparable under one-step; BGC worse
            "onestep_frc_close_to_sregular":
                bool(np.allclose(frc1, sreg1, rtol=0.35, atol=0.02)),
            "onestep_bgc_worst":
                bool(np.mean(bgc1 - np.maximum(frc1, sreg1)) > 0),
            # Fig 3: FRC dominates under optimal decoding
            "optimal_frc_best":
                bool(np.all(frc_o <= np.minimum(sreg_o, bgc_o) + 1e-6)),
            # Fig 4 / Def 1-2: err1 >= err pointwise, every scheme
            "err1_ge_err": bool(
                np.all(frc1 >= frc_o - 1e-9) and np.all(bgc1 >= bgc_o - 1e-9)
                and np.all(sreg1 >= sreg_o - 1e-9)),
        }
        if s == 10:
            # s=10 FRC: near-zero optimal error at delta = 0.5 (paper Sec. 6)
            i = DELTAS.index(0.5)
            checks["frc_s10_near_zero_at_half"] = bool(frc_o[i] < 0.02)
        report["checks"][f"s={s}"] = checks

    for s in (5, 10):
        for dec, fig in (("onestep", "fig2"), ("optimal", "fig3")):
            print(ascii_curves(
                f"{fig}: mean err{'1' if dec == 'onestep' else ''}(A)/k, "
                f"k={k}, s={s}, {trials} trials",
                DELTAS, {sch: get(s, dec, sch) for sch in SCHEMES},
                logy=(dec == "optimal")))
            print()
    return report


def dataclass_row(res) -> dict:
    return {"scheme": res.scheme, "decoder": res.decoder, "k": res.k,
            "s": res.s, "delta": res.delta, "trials": res.trials,
            "mean": res.mean, "std": res.std, "q95": res.q95,
            "p_zero": res.p_zero}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=1000)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--schemes", default=",".join(SCHEMES),
                    help="comma list of registry families to sweep "
                         f"(registered: {', '.join(registry.names())})")
    args = ap.parse_args(argv)
    schemes = tuple(s.strip() for s in args.schemes.split(",") if s.strip())
    report = run(trials=args.trials, k=args.k,
                 schemes=tuple(dict.fromkeys(SCHEMES + schemes)))
    ok = all(v for c in report["checks"].values() for v in c.values())
    print("fig2-4 claim checks:", report["checks"])
    print("PASS" if ok else "MISMATCH (see checks)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
