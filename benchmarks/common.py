"""Shared helpers for the benchmark harness: artifact IO + ASCII plots."""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Dict, List, Sequence

ART = Path("artifacts/bench")


def bench_backend() -> str:
    """The backend key this run's numbers belong to ("cpu", "tpu-v5e"...).

    check_regression keys its committed baselines on this, so a TPU run
    never gates against CPU numbers.  Falls back to "cpu" when
    repro.platform is unavailable (e.g. a stripped artifact consumer).
    """
    try:
        from repro.platform import backend_key
    except ImportError:
        return "cpu"
    return backend_key()


def save_json(name: str, payload: Any) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    p = ART / f"{name}.json"
    if isinstance(payload, dict):
        payload.setdefault("backend", bench_backend())

    def default(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        if hasattr(o, "item"):
            return o.item()
        if hasattr(o, "tolist"):
            return o.tolist()
        return str(o)

    p.write_text(json.dumps(payload, indent=1, default=default))
    return p


def save_csv(name: str, rows: List[Dict[str, Any]]) -> Path:
    ART.mkdir(parents=True, exist_ok=True)
    p = ART / f"{name}.csv"
    if not rows:
        p.write_text("")
        return p
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r.get(c, "")) for c in cols))
    p.write_text("\n".join(lines) + "\n")
    return p


def ascii_curves(title: str, xs: Sequence[float],
                 series: Dict[str, Sequence[float]], width: int = 64,
                 height: int = 14, logy: bool = False) -> str:
    """Minimal multi-series ASCII line chart (artifact-friendly plots)."""
    import math
    vals = [v for ys in series.values() for v in ys if v is not None]
    if not vals:
        return f"{title}: (no data)"
    f = (lambda v: math.log10(max(v, 1e-12))) if logy else (lambda v: v)
    lo = min(f(v) for v in vals)
    hi = max(f(v) for v in vals)
    span = (hi - lo) or 1.0
    grid = [[" "] * width for _ in range(height)]
    marks = "ox+*#@%&"
    for si, (name, ys) in enumerate(series.items()):
        m = marks[si % len(marks)]
        for xi, y in enumerate(ys):
            if y is None:
                continue
            cx = int(xi / max(len(ys) - 1, 1) * (width - 1))
            cy = int((f(y) - lo) / span * (height - 1))
            grid[height - 1 - cy][cx] = m
    out = [title]
    ylab = f"{'log10 ' if logy else ''}[{lo:.3g}, {hi:.3g}]"
    out.append(f"  y: {ylab}   x: [{xs[0]:.3g}, {xs[-1]:.3g}]")
    out += ["  |" + "".join(row) for row in grid]
    out.append("  +" + "-" * width)
    legend = "   ".join(f"{marks[i % len(marks)]}={n}"
                        for i, n in enumerate(series))
    out.append("   " + legend)
    return "\n".join(out)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def best_of(fn, reps: int = 5):
    """(best seconds over `reps`, warmup result) after one warmup call.

    The shared timing helper for the gated benchmarks: millisecond-scale
    paths need reps to escape allocator/scheduler noise; seconds-scale
    deterministic paths should pass reps=1 (warmup + one timed run).
    The warmup's return value is kept so callers never re-execute a
    slow path just to read its output.
    """
    out = fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best, out
