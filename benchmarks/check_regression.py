"""Throughput-regression gate for the CI bench lane.

Compares freshly generated ``artifacts/bench/*.json`` against the
committed baselines in ``benchmarks/baselines/`` and fails (exit 1)
when any tracked throughput metric regresses by more than the
tolerance (default 25%).

Baselines are keyed PER BACKEND: each committed file stores
``{"metrics": {"cpu": {...}, "tpu-v5e": {...}}}`` and every artifact
carries the ``backend`` key of the machine that produced it
(``repro.platform.backend_key()``, injected by ``common.save_json``).
The gate only compares a run against its own backend's baselines; a
backend with no committed baselines is reported informationally and
NEVER fails the lane (pin it with ``--update`` on that machine to
start gating it).

Only MACHINE-NORMALIZED metrics are compared: every tracked metric is a
speedup ratio (batched path vs reference loop, measured in the same
process on the same machine), so a slower CI runner shifts both sides
equally and the gate tracks genuine code regressions, not runner
lottery.  Hard floors (the E10/E11 ">= 10x batched" acceptance) are
enforced by the benchmark modules themselves; this gate catches slower
drift that stays above those floors.

Baselines store ONLY the tracked metrics (not whole artifacts), so a
pinned file cannot drift out of sync with derived fields.  Because the
ratios still jitter run to run, the documented pin flow min-merges
several runs into a conservative floor:

    PYTHONPATH=src python -m benchmarks.mc_throughput --trials 300
    PYTHONPATH=src python -m benchmarks.wallclock_frontier --steps 100
    python -m benchmarks.check_regression --update          # first pin
    # ... re-run the benchmarks a couple more times, then after each:
    python -m benchmarks.check_regression --update --keep-min

``--update`` alone replaces the current backend's baselines with the
current run (other backends' pins are preserved); ``--keep-min`` keeps
the smaller of (baseline, current) per metric.  The CI check itself:

    python -m benchmarks.check_regression [--tolerance 0.25]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ARTIFACTS = Path("artifacts/bench")
BASELINES = Path(__file__).resolve().parent / "baselines"

# metrics whose baseline speedup sits below this are reference cells
# (e.g. batched pinv vs loop, ~1x by design) where run-to-run BLAS noise
# exceeds any real signal: report them, do not gate them
GATE_MIN_BASELINE = 2.0

# metrics that are tracked but NEVER gate, whatever their magnitude:
# theory ratios (distance to the Wang et al. fundamental limit) whose
# values legitimately move when a family's construction or decoder
# improves — drift is signal to read, not a build failure
INFO_PREFIXES = ("gap_to_optimal[",)


def _extract_mc_throughput(payload: dict) -> dict:
    rows = payload["rows"]
    return {"speedup[" + r["decoder"] + "]": float(r["speedup"]) for r in rows}


def _extract_wallclock_frontier(payload: dict) -> dict:
    out = {"speedup[gate]": float(payload["gate"]["speedup"])}
    # the adaptive-controller advantage ratios are modelled-time ratios
    # (deterministic given the seed, machine-free); baselines below the
    # 2x gate floor are reported informationally, while the hard >= 1x
    # dominance floor lives in the benchmark's own checks
    adaptive = payload.get("adaptive", {})
    for trace_name in ("bimodal", "clustered"):
        key = f"advantage_{trace_name}"
        if key in adaptive:
            out[f"adaptive_advantage[{trace_name}]"] = float(adaptive[key])
    # decode-overlap ratio: synchronous time-to-target over the
    # staleness=1 pipelined one (>= 1 means overlap pays for itself;
    # sits far below the 2x gate floor, so reported informationally —
    # the hard staleness1_tt_le_sync floor lives in the benchmark)
    staleness = payload.get("staleness", {})
    tt = {r["staleness"]: r["time_to_target"]
          for r in staleness.get("rows", ())}
    if 0 in tt and 1 in tt and tt[1] > 0:
        out["staleness_overlap[bimodal]"] = float(tt[0] / tt[1])
    # per-family distance to the fundamental limit (measured err over
    # the Wang et al. lower bound, best grid cell) — INFO_PREFIXES
    # metrics: tracked so drift shows up in the lane log, never gated
    for scheme, g in payload.get("gap_to_optimal", {}).items():
        out[f"gap_to_optimal[{scheme}]"] = float(g["gap"])
    return out


def _extract_elastic_churn(payload: dict) -> dict:
    # modelled time-to-target ratios through the churn storm —
    # deterministic given (seeds, storm config), machine-free.  The
    # churn advantage (restart/elastic) sits right at the gate floor;
    # the oblivious penalty rides the 100x inflation clip, so it is
    # pinned conservatively via --keep-min like everything else
    adv = payload["advantage"]
    return {
        "churn_advantage[storm]": float(adv["churn_advantage"]),
        "oblivious_penalty[storm]": float(adv["oblivious_penalty"]),
    }


def _extract_serving_tail(payload: dict) -> dict:
    # unhedged p99 / best hedged p99 within the 1.1x overhead budget —
    # a deterministic (seed, trace) ratio like the E11 advantages; it
    # sits below the 2x gate floor, so it is reported informationally
    # while the hard hedged-beats-unhedged gate lives in the benchmark
    return {"hedged_p99_advantage[bimodal]":
            float(payload["advantage"]["bimodal"])}


# (file stem, description, payload -> {metric: speedup}) per benchmark
TRACKED = (
    ("mc_throughput", "E10 batched decode speedups", _extract_mc_throughput),
    ("wallclock_frontier", "E11 ClusterSim speedup", _extract_wallclock_frontier),
    ("serving_tail", "E12 hedged-serving tail advantage", _extract_serving_tail),
    ("elastic_churn", "E13 churn time-to-target advantage",
     _extract_elastic_churn),
)


def _load(path: Path) -> dict:
    with path.open() as fh:
        return json.load(fh)


def _artifact_backend(payload: dict) -> str:
    # artifacts carry the backend_key() of the machine that produced
    # them (injected by common.save_json); pre-redesign artifacts are
    # CPU by construction
    return str(payload.get("backend", "cpu"))


def _load_baseline(stem: str) -> dict:
    """{backend: {metric: speedup}} for one benchmark's committed pin.

    Pre-redesign flat files ({metric: float}) are read as CPU pins so a
    stale checkout degrades gracefully.
    """
    metrics = _load(BASELINES / f"{stem}.json")["metrics"]
    if metrics and all(isinstance(v, (int, float))
                       for v in metrics.values()):
        return {"cpu": metrics}
    return metrics


def update_baselines(keep_min: bool) -> int:
    BASELINES.mkdir(parents=True, exist_ok=True)
    for stem, desc, extractor in TRACKED:
        src = ARTIFACTS / f"{stem}.json"
        if not src.exists():
            print(f"missing {src}; run the benchmark first", file=sys.stderr)
            return 1
        payload = _load(src)
        backend = _artifact_backend(payload)
        metrics = extractor(payload)
        dst = BASELINES / f"{stem}.json"
        by_backend = _load_baseline(stem) if dst.exists() else {}
        merged = keep_min and backend in by_backend
        if merged:
            old = by_backend[backend]
            for key in metrics:
                if key in old:
                    metrics[key] = min(metrics[key], old[key])
        by_backend[backend] = metrics
        out = {"benchmark": stem, "description": desc,
               "metrics": {k: by_backend[k] for k in sorted(by_backend)}}
        dst.write_text(json.dumps(out, indent=1) + "\n")
        print(f"{'min-merged' if merged else 'pinned'} {dst} [{backend}]")
    return 0


def _check_one(stem: str, desc: str, extractor, tolerance: float) -> list:
    current_path = ARTIFACTS / f"{stem}.json"
    baseline_path = BASELINES / f"{stem}.json"
    if not current_path.exists():
        return [f"{stem}: no current artifact at {current_path}"]
    if not baseline_path.exists():
        return [f"{stem}: no baseline at {baseline_path} (pin with --update)"]
    payload = _load(current_path)
    backend = _artifact_backend(payload)
    current = extractor(payload)
    by_backend = _load_baseline(stem)
    baseline = by_backend.get(backend)
    if baseline is None:
        print(f"{stem} ({desc}): no committed baselines for backend "
              f"{backend!r} (have {sorted(by_backend)}) — informational "
              f"only; pin with --update on this machine to start gating")
        return []
    failures = []
    print(f"{stem} ({desc}) [{backend}]:")
    for metric, base in sorted(baseline.items()):
        now = current.get(metric)
        if now is None:
            failures.append(f"{stem}: {metric} missing from current artifact")
            continue
        floor = base * (1.0 - tolerance)
        gated = base >= GATE_MIN_BASELINE and not metric.startswith(INFO_PREFIXES)
        if not gated:
            status = "info (not gated)"
        elif now >= floor:
            status = "ok"
        else:
            status = "REGRESSION"
        line = f"  {metric:<24} baseline={base:8.2f}x  current={now:8.2f}x"
        print(line + f"  floor={floor:8.2f}x  {status}")
        if gated and now < floor:
            detail = f"regressed to {now:.2f}x (baseline {base:.2f}x)"
            failures.append(f"{stem}: {metric} {detail}")
    return failures


def check(tolerance: float) -> int:
    failures = []
    for stem, desc, extractor in TRACKED:
        failures += _check_one(stem, desc, extractor, tolerance)
    if failures:
        print("\nTHROUGHPUT REGRESSIONS:", file=sys.stderr)
        for failure in failures:
            print(f"  {failure}", file=sys.stderr)
        return 1
    print(f"\nall tracked speedups within {tolerance:.0%} of baseline")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    help_tol = "allowed fractional slowdown vs baseline (default 0.25)"
    parser.add_argument("--tolerance", type=float, default=0.25, help=help_tol)
    help_update = "pin the current artifacts' tracked metrics as baselines"
    parser.add_argument("--update", action="store_true", help=help_update)
    help_min = "with --update: keep the smaller of (baseline, current)"
    parser.add_argument("--keep-min", action="store_true", help=help_min)
    args = parser.parse_args(argv)
    if args.update:
        return update_baselines(args.keep_min)
    return check(args.tolerance)


if __name__ == "__main__":
    raise SystemExit(main())
