"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--trials N]

Runs, in order (E-numbers from docs/architecture.md §4):
    E1-E3  fig_errors        Figs 2-4: err1/err vs delta per scheme
    E4     fig5_algorithmic  Fig 5: ||u_t||^2/k curves
    E5     theory_check      Thms 5/6/7/8/21 closed forms vs Monte Carlo
    E6     adversary_bench   Thm 10/11: adversaries + NP-hardness reduction
    E7     e2e_convergence   coded LM training vs baselines + wall-clock
    E8     decoding_cost     decoder microbenchmarks vs k
    E9     roofline_report   roofline table from the dry-run artifacts
    E10    mc_throughput     looped vs batched Monte-Carlo decode
    E11    wallclock_frontier  ClusterSim runtime-vs-accuracy frontier
    E12    serving_tail      hedged-serving p99/p999 vs compute overhead
    E13    elastic_churn     time-to-target through membership churn

Artifacts land in artifacts/bench/ (+ artifacts/roofline.{json,md});
each module prints PASS/MISMATCH against the paper's claims.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced trial counts (CI mode)")
    ap.add_argument("--trials", type=int, default=None,
                    help="Monte-Carlo trials (default 1000; paper used 5000)")
    ap.add_argument("--only", default=None,
                    help="comma list of module names to run")
    args = ap.parse_args(argv)

    trials = args.trials or (200 if args.quick else 1000)
    steps = 16 if args.quick else 40

    from . import adversary_bench, decoding_cost, e2e_convergence, \
        fig5_algorithmic, fig_errors, theory_check
    from . import elastic_churn, mc_throughput, roofline_report, \
        serving_tail, wallclock_frontier

    jobs = [
        ("fig_errors", lambda: fig_errors.main(["--trials", str(trials)])),
        ("fig5_algorithmic",
         lambda: fig5_algorithmic.main(["--trials", str(trials)])),
        ("theory_check",
         lambda: theory_check.main(["--trials", str(max(trials * 2, 400))])),
        ("adversary_bench", lambda: adversary_bench.main([])),
        ("e2e_convergence",
         lambda: e2e_convergence.main(["--steps", str(steps)])),
        ("decoding_cost", lambda: decoding_cost.main([])),
        ("mc_throughput",
         lambda: mc_throughput.main(["--trials", str(trials)])),
        ("wallclock_frontier",
         lambda: wallclock_frontier.main(
             ["--steps", str(max(trials // 2, 100))])),
        # E12 is vectorized numpy replay: the >= 1M-request gate stays
        # full-scale even under --quick (seconds, no device execution)
        ("serving_tail", lambda: serving_tail.main([])),
        # E13's storm is analytic (seconds); --quick skips only the
        # jitted trainer-recovery section, which the slow test lane
        # already covers
        ("elastic_churn", lambda: elastic_churn.main(
            ["--skip-trainer"] if args.quick else [])),
        ("roofline_report", lambda: roofline_report.main([])),
    ]
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        jobs = [j for j in jobs if j[0] in keep]

    failures = []
    for name, fn in jobs:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            rc = fn()
        except SystemExit as e:  # argparse in submodules
            rc = int(e.code or 0)
        except Exception as e:
            import traceback
            traceback.print_exc()
            rc = 2
        print(f"-- {name}: rc={rc} ({time.time() - t0:.1f}s)")
        if rc:
            failures.append(name)

    print(f"\n{'=' * 72}")
    if failures:
        print(f"BENCHMARKS WITH MISMATCHES/ERRORS: {failures}")
    else:
        print("ALL BENCHMARKS PASS")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
