"""E10: Monte-Carlo decode throughput — per-trial Python loop vs the
batched DecodeEngine (the tentpole claim of the batched decode stack).

Measures, at the paper-scale cell k = n = 256 with 1000 trials:

  * loop      : the pre-engine path — one `G[:, mask]` slice + scalar
                decode per trial (exactly what core.simulate used to do)
  * batched   : all masks sampled up front, one DecodeEngine
                `decode_batch` per cell

for the one-step decoder (acceptance: batched >= 10x loop, weights
equal to 1e-5), plus the same comparison for the algorithmic decoder
and the optimal decoder.  The optimal row measures the ENGINE DEFAULT
(optimal_impl='auto' == gram since the pipelining PR) against the
scalar pinv loop — gated speedup >= 1x with decode errors matching to
1e-4 — with an informational optimal_pinv row for the exact min-norm
opt-in and an optimal_gram row pitting gram against batched pinv on
the full ensemble.  A fused_apply row times the one-pass
DecodeEngine.decode_apply_batch (scale * mask folded into the message
contraction) against the weights-then-apply composition it replaces.
Emits BENCH json/csv artifacts under artifacts/bench/.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import codes, decoding
from repro.core.engine import DecodeEngine
from repro.core.simulate import sample_straggler_masks
from .common import best_of, save_csv, save_json


def _loop_onestep(G, masks, s):
    """The old per-trial path: slice A, scalar weights + err1."""
    k = G.shape[0]
    B = masks.shape[0]
    W = np.zeros((B, G.shape[1]))
    errs = np.empty(B)
    for b in range(B):
        mask = masks[b]
        A = G[:, mask]
        r = int(mask.sum())
        rho = decoding.default_rho(k, r, s)
        W[b] = decoding.onestep_weights(G, mask, rho=rho)
        errs[b] = decoding.err1(A, rho)
    return W, errs


def _loop_algorithmic(G, masks, iters):
    B = masks.shape[0]
    W = np.zeros((B, G.shape[1]))
    errs = np.empty(B)
    for b in range(B):
        W[b] = decoding.algorithmic_weights(G, masks[b], iters=iters)
        errs[b] = decoding.algorithmic_error_curve(
            G[:, masks[b]], iters)[-1]
    return W, errs


def _autotune_rows(code, masks, rng):
    """Time each kernel with the committed autotuned tiles (tiles=None,
    the ops-layer default) against the historical hardcoded tiles, in
    interpret mode on this host.  max_weight_dev is the EXACT output
    deviation — the gate requires 0.0 (bitwise).

    When the table pins nothing for this (backend, shape class) the two
    configs are identical, so the speedup is definitionally 1.0 and is
    reported as such rather than timing the same program twice.
    """
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.tiles import DEFAULT_TILES, resolve

    k, n = code.G.shape
    B = masks.shape[0]
    G = jnp.asarray(code.G.astype(np.float32))
    m = jnp.asarray(masks.astype(np.float32))
    r = jnp.asarray((rng.random(B) + 0.5).astype(np.float32))
    msgs = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
    impl = "pallas_interpret"   # the CPU kernel path; its table key

    cells = (
        ("autotune_onestep", "batched_onestep_decode",
         lambda tiles: ops.batched_onestep_decode(
             G, m, r, impl=impl, tiles=tiles)),
        ("autotune_fused", "fused_decode_apply",
         lambda tiles: ops.fused_decode_apply(
             msgs, m, r, impl=impl, tiles=tiles)),
    )
    rows = []
    for name, kernel, call in cells:
        default = DEFAULT_TILES[kernel]
        tuned_kw = resolve(kernel, None, backend="cpu", B=B)
        t_def, out_def = best_of(
            lambda: np.asarray(call(default).block_until_ready()))
        if tuned_kw == default.kwargs(kernel):
            t_tuned, out_tuned, same = t_def, out_def, True
        else:
            t_tuned, out_tuned = best_of(
                lambda: np.asarray(call(None).block_until_ready()))
            same = False
        dev = 0.0 if np.array_equal(out_def, out_tuned) else \
            float(np.abs(out_def - out_tuned).max())
        rows.append({
            "decoder": name, "k": k, "trials": B, "delta": float("nan"),
            "loop_s": t_def, "batched_s": t_tuned,
            "speedup": 1.0 if same else t_def / max(t_tuned, 1e-12),
            "trials_per_s_batched": B / max(t_tuned, 1e-12),
            "max_weight_dev": dev, "max_err_dev": float("nan"),
        })
    return rows


def run(k: int = 256, trials: int = 1000, delta: float = 0.3,
        s: int = 12, iters: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    code = codes.bgc(k=k, n=k, s=s, rng=rng)
    masks = sample_straggler_masks(k, int(delta * k), trials, rng)
    eng = DecodeEngine(code, iters=iters, s=s)

    rows = []

    # ---- one-step (the acceptance cell) ----
    # best_of keeps each warmup's result so no reference path (the slow
    # side by construction) re-executes just to read its output
    t_loop, (W_loop, e_loop) = best_of(
        lambda: _loop_onestep(code.G, masks, s))
    t_batch, res = best_of(lambda: eng.decode_batch(masks, "onestep"))
    w_dev = float(np.abs(res.weights - W_loop).max())
    e_dev = float(np.abs(res.errors - e_loop).max())
    rows.append({
        "decoder": "onestep", "k": k, "trials": trials, "delta": delta,
        "loop_s": t_loop, "batched_s": t_batch,
        "speedup": t_loop / max(t_batch, 1e-12),
        "trials_per_s_batched": trials / max(t_batch, 1e-12),
        "max_weight_dev": w_dev, "max_err_dev": e_dev,
    })

    # ---- algorithmic (dial midpoint) ----
    t_loop_a, (W_la, _) = best_of(
        lambda: _loop_algorithmic(code.G, masks, iters), reps=1)
    t_batch_a, res_a = best_of(
        lambda: eng.decode_batch(masks, "algorithmic", iters=iters), reps=1)
    rows.append({
        "decoder": f"algorithmic{iters}", "k": k, "trials": trials,
        "delta": delta, "loop_s": t_loop_a, "batched_s": t_batch_a,
        "speedup": t_loop_a / max(t_batch_a, 1e-12),
        "trials_per_s_batched": trials / max(t_batch_a, 1e-12),
        "max_weight_dev": float(np.abs(res_a.weights - W_la).max()),
        "max_err_dev": float("nan"),
    })

    # ---- optimal: the ENGINE DEFAULT (auto == gram) vs scalar loop ----
    # this is the speedup[optimal] row check_regression gates >= 1x:
    # flipping the default must never make "optimal" slower than the
    # old per-trial path.  gram weights may differ from the min-norm
    # pinv solution on ill-conditioned supports, so the parity check
    # lives on the decode ERRORS (the quantity the MC curves plot)
    sub = masks[: max(trials // 10, 10)]
    t_loop_o, W_lo = best_of(lambda: np.stack(
        [decoding.optimal_weights(code.G, m) for m in sub]), reps=1)
    e_lo = decoding.err_batch(code.G, W_lo)
    t_batch_o, res_o = best_of(
        lambda: eng.decode_batch(sub, "optimal"), reps=1)
    opt_err_dev = float(np.abs(res_o.errors - e_lo).max())
    rows.append({
        "decoder": "optimal", "k": k, "trials": len(sub), "delta": delta,
        "loop_s": t_loop_o, "batched_s": t_batch_o,
        "speedup": t_loop_o / max(t_batch_o, 1e-12),
        "trials_per_s_batched": len(sub) / max(t_batch_o, 1e-12),
        "max_weight_dev": float(np.abs(res_o.weights - W_lo).max()),
        "max_err_dev": opt_err_dev,
    })

    # ---- optimal_pinv: the exact min-norm opt-in (informational) ----
    eng_pinv = DecodeEngine(code, iters=iters, s=s, optimal_impl="pinv")
    t_batch_p, res_p = best_of(
        lambda: eng_pinv.decode_batch(sub, "optimal"), reps=1)
    rows.append({
        "decoder": "optimal_pinv", "k": k, "trials": len(sub),
        "delta": delta, "loop_s": t_loop_o, "batched_s": t_batch_p,
        "speedup": t_loop_o / max(t_batch_p, 1e-12),
        "trials_per_s_batched": len(sub) / max(t_batch_p, 1e-12),
        "max_weight_dev": float(np.abs(res_p.weights - W_lo).max()),
        "max_err_dev": float(np.abs(res_p.errors - e_lo).max()),
    })

    # ---- optimal via the masked-Gram normal equations ----
    # the least-squares fast path behind the sbm/expander frontiers:
    # one G^T G, O(n^2) per mask + a batched LAPACK solve, vs the
    # explicit batched-pinv opt-in on the FULL trial ensemble
    eng_gram = DecodeEngine(code, iters=iters, s=s, optimal_impl="gram")
    t_pinv_full, res_pinv = best_of(
        lambda: eng_pinv.decode_batch(masks, "optimal"), reps=1)
    t_gram_full, res_gram = best_of(
        lambda: eng_gram.decode_batch(masks, "optimal"), reps=1)
    gram_err_dev = float(np.abs(res_gram.errors - res_pinv.errors).max())
    rows.append({
        "decoder": "optimal_gram", "k": k, "trials": trials, "delta": delta,
        "loop_s": t_pinv_full, "batched_s": t_gram_full,
        "speedup": t_pinv_full / max(t_gram_full, 1e-12),
        "trials_per_s_batched": trials / max(t_gram_full, 1e-12),
        "max_weight_dev": float(np.abs(
            res_gram.weights - res_pinv.weights).max()),
        "max_err_dev": gram_err_dev,
    })

    # ---- fused decode-apply vs weights-then-apply ----
    # basis-sized messages (one column per task): the one-pass
    # decode_apply_batch (w = scale * mask folded into the contraction)
    # vs decoding the [B, n] weight ensemble and applying it after
    msgs = rng.standard_normal((k, k))
    t_wta, out_wta = best_of(
        lambda: eng.decode_batch(masks, "onestep").weights @ msgs, reps=1)
    t_fus, out_fus = best_of(
        lambda: eng.decode_apply_batch(masks, msgs), reps=1)
    fused_dev = float(np.abs(out_fus - out_wta).max())
    rows.append({
        "decoder": "fused_apply", "k": k, "trials": trials, "delta": delta,
        "loop_s": t_wta, "batched_s": t_fus,
        "speedup": t_wta / max(t_fus, 1e-12),
        "trials_per_s_batched": trials / max(t_fus, 1e-12),
        "max_weight_dev": fused_dev, "max_err_dev": float("nan"),
    })

    # ---- autotuned tiles vs the hardcoded defaults ----
    # the committed per-backend tile table (kernels/tile_tables.json,
    # re-pinned via `python -m repro.launch.autotune`) is what
    # tiles=None loads; these rows gate that it never loses to the old
    # hardcoded tile constants AND that the outputs are bitwise
    # identical (autotune only varies bitwise-safe parallel grid axes)
    rows += _autotune_rows(code, masks, rng)

    checks = {
        "onestep_speedup_ge_10x": bool(rows[0]["speedup"] >= 10.0),
        "onestep_weights_match_1e-5": bool(rows[0]["max_weight_dev"] <= 1e-5),
        "algorithmic_weights_match_1e-5": bool(
            rows[1]["max_weight_dev"] <= 1e-5),
        # the engine DEFAULT must never lose to the scalar loop and must
        # reproduce the exact-oracle decode errors
        "optimal_default_speedup_ge_1x": bool(rows[2]["speedup"] >= 1.0),
        "optimal_default_errors_match_1e-4": bool(opt_err_dev <= 1e-4),
        # the gram path must beat batched pinv and agree on the decode
        # errors (weights may differ on ill-conditioned supports — the
        # documented normal-equations tradeoff)
        "optimal_gram_speedup_ge_3x": bool(rows[4]["speedup"] >= 3.0),
        "optimal_gram_errors_match_1e-4": bool(gram_err_dev <= 1e-4),
        # fusing the decode into the apply must win (it skips the
        # weight materialization and the per-mask error reduction)
        "fused_apply_speedup_ge_1x": bool(rows[5]["speedup"] >= 1.0),
        "fused_apply_matches_1e-8": bool(fused_dev <= 1e-8),
        # the committed autotune table must never lose to the hardcoded
        # tiles, and tuned outputs must be BITWISE equal to default-tile
        # outputs (max_weight_dev is exact-zero, not a tolerance)
        "autotune_onestep_speedup_ge_1x": bool(rows[6]["speedup"] >= 1.0),
        "autotune_onestep_bitwise": bool(rows[6]["max_weight_dev"] == 0.0),
        "autotune_fused_speedup_ge_1x": bool(rows[7]["speedup"] >= 1.0),
        "autotune_fused_bitwise": bool(rows[7]["max_weight_dev"] == 0.0),
    }
    save_csv("mc_throughput", rows)
    save_json("mc_throughput", {"rows": rows, "checks": checks})
    return {"rows": rows, "checks": checks}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--trials", type=int, default=1000)
    ap.add_argument("--delta", type=float, default=0.3)
    ap.add_argument("--iters", type=int, default=4)
    args = ap.parse_args(argv)
    rep = run(k=args.k, trials=args.trials, delta=args.delta,
              iters=args.iters)
    for r in rep["rows"]:
        print({k: (f"{v:.3g}" if isinstance(v, float) else v)
               for k, v in r.items()})
    ok = all(rep["checks"].values())
    print("mc throughput checks:", rep["checks"])
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
