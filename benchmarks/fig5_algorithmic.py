"""Figure 5 reproduction: algorithmic decoding error ||u_t||^2/k vs t for
BGCs, delta in {0.1,...,0.8}, s in {5,10} (Lemma 12: monotone, converges
to mean err(A)/k)."""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import simulate
from .common import ascii_curves, save_csv, save_json

DELTAS = (0.1, 0.2, 0.3, 0.5, 0.8)


def run(trials: int = 1000, k: int = 100, iters: int = 12, seed: int = 0):
    rows = []
    curves = {}
    for s in (5, 10):
        for d in DELTAS:
            c = simulate.algorithmic_curve_mc("bgc", k=k, s=s, delta=d,
                                              trials=trials, iters=iters,
                                              seed=seed)
            curves[(s, d)] = c
            for t, v in enumerate(c):
                rows.append({"s": s, "delta": d, "t": t, "u_t_sq_over_k": v})
    save_csv("fig5_algorithmic", rows)
    save_json("fig5_algorithmic", rows)

    checks = {}
    for (s, d), c in curves.items():
        mono = bool(np.all(np.diff(c) <= 1e-9))
        # Lemma 12: ||u_t||^2/k is bounded BELOW by mean err(A)/k and
        # decreases toward it (convergence rate ~ (1 - sigma_min^2/nu)^t,
        # so 12 iterations need not reach it — the paper's Fig 5 likewise
        # shows flattening above the optimal line).
        opt = simulate.monte_carlo_error(
            "bgc", k=k, n=k, s=s, delta=d, trials=max(trials // 4, 100),
            decoder="optimal", seed=seed + 1).mean
        above = bool(c[-1] >= opt - 0.02)
        improves = bool(c[-1] <= c[1] + 1e-9)   # beats one-step (t=1)
        flattens = bool(c[-2] - c[-1] <= 0.25 * max(c[1] - c[2], 1e-9) + 1e-6)
        checks[f"s{s}_d{d}"] = {"monotone": mono, "above_optimal": above,
                                "improves_on_onestep": improves,
                                "flattens": flattens,
                                "u_final": float(c[-1]), "mc_optimal": opt}
    for s in (5, 10):
        print(ascii_curves(
            f"fig5: mean ||u_t||^2/k, BGC k={k} s={s} ({trials} trials)",
            list(range(iters + 1)),
            {f"d={d}": curves[(s, d)] for d in DELTAS}))
        print()
    return {"rows": rows, "checks": checks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=1000)
    ap.add_argument("--iters", type=int, default=12)
    args = ap.parse_args(argv)
    rep = run(trials=args.trials, iters=args.iters)
    ok = all(c["monotone"] and c["above_optimal"] and c["improves_on_onestep"]
             and c["flattens"] for c in rep["checks"].values())
    print({k: (c["u_final"], c["mc_optimal"]) for k, c in rep["checks"].items()})
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
