"""Adversarial stragglers (paper Sec. 4).

* Thm 10: the FRC worst case err = k - r is achieved by the linear-time
  block-killing adversary — and found in O(k)/O(k^2).
* Random codes (BGC/rBGC) vs the same polynomial-time adversaries
  (greedy + random search): the adversary's best-found error stays far
  below k - r, the paper's motivation for randomization (Sec. 4.2's
  NP-hardness means poly adversaries are all we need to beat).
* DkS reduction: objective identity of Thm 11 (Eq. 4.2/4.3) checked on a
  random regular graph.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import adversary, codes, decoding, registry
from .common import save_csv, save_json

def run(k: int = 100, s: int = 10, delta: float = 0.3, seed: int = 0,
        search_trials: int = 300):
    rng = np.random.default_rng(seed)
    r = int(round((1 - delta) * k))
    num_stragglers = k - r
    rows, checks = [], {}

    # every registered family that exposes redundancy to attack
    # (adversary profile != none) and constructs at the benchmark size —
    # derived from the registry, so new families join automatically
    schemes = [f.name for f in registry.families()
               if f.adversary != "none" and f.check(k, k, s) is None]
    for scheme in schemes:
        fam = registry.get(scheme)
        code = fam.make(k=k, n=k, s=s, rng=rng)
        # random baseline
        rand_errs = []
        for t in range(50):
            mask = np.ones(k, bool)
            mask[rng.choice(k, num_stragglers, replace=False)] = False
            rand_errs.append(decoding.err(code.G[:, mask]))
        # FRC analytic adversary (linear time)
        t0 = time.perf_counter()
        mask_frc = adversary.frc_adversarial_mask(code.G, num_stragglers)
        t_frc = time.perf_counter() - t0
        err_frc_adv = decoding.err(code.G[:, mask_frc])
        # greedy adversary (poly time, any code)
        t0 = time.perf_counter()
        m = adversary.greedy_adversarial_mask(code.G, num_stragglers)
        best_greedy = decoding.err(code.G[:, m])
        t_greedy = time.perf_counter() - t0
        # random search
        m = adversary.random_search_adversarial_mask(
            code.G, num_stragglers, trials=search_trials,
            rng=np.random.default_rng(seed))
        err_search = decoding.err(code.G[:, m])
        worst_found = max(err_frc_adv, best_greedy, err_search)
        rows.append({
            "scheme": scheme, "profile": fam.adversary,
            "k": k, "s": s, "delta": delta,
            "rand_mean": float(np.mean(rand_errs)),
            "err_block_adversary": float(err_frc_adv),
            "err_greedy": float(best_greedy),
            "err_random_search": float(err_search),
            "worst_found": float(worst_found),
            "thm10_bound": float(k - r),
            "t_block_adversary_s": t_frc, "t_greedy_s": t_greedy,
        })

    by = {r_["scheme"]: r_ for r_ in rows}
    checks["thm10_frc_worstcase_achieved"] = bool(
        abs(by["frc"]["err_block_adversary"] - (k - r)) < 1e-6)
    checks["frc_adversary_linear_time"] = bool(by["frc"]["t_block_adversary_s"]
                                               < 0.05)
    # RANDOMIZED codes resist the same poly-time adversaries — the
    # paper's Sec.-4 motivation for randomization.  Deterministic
    # structured codes (cyclic) are attackable and must NOT carry this
    # check: the greedy/block adversaries find large-error masks there.
    for scheme in schemes:
        fam = registry.get(scheme)
        if fam.randomized and fam.adversary == "greedy":
            checks[f"{scheme}_resists_poly_adversary"] = bool(
                by[scheme]["worst_found"] < 0.5 * (k - r))
    # ...at the cost of worse AVERAGE error than FRC (the paper's tradeoff)
    checks["frc_better_average"] = bool(
        by["frc"]["rand_mean"] <= by["bgc"]["rand_mean"] + 1e-9)

    # ---- Thm 11 reduction: Eq. 4.2/4.3 objective identity ----
    d_reg, n_g, kq = 4, 16, 6
    adj = codes.sregular(k=n_g, n=n_g, s=d_reg,
                         rng=np.random.default_rng(seed)).G
    red = adversary.build_dks_reduction(adj, kq=kq, rho=0.5)
    ident_ok = True
    for t in range(200):
        trng = np.random.default_rng(seed + t)
        y = np.zeros(n_g, bool)
        y[trng.choice(n_g, kq, replace=False)] = True
        # x = [y; z] with ||y||_0 + ||z||_0 = r
        z = np.zeros(red.ne - red.nv, bool)
        z[trng.choice(len(z), red.r - kq, replace=False)] = True
        x = np.concatenate([y, z]).astype(np.float64)
        e_s = int(adj[np.ix_(y, y)].sum() // 2)
        lhs = red.objective(x)                      # ||rho C x - 1||^2
        rhs = red.predicted_objective(e_s, kq)      # Eq. 4.2/4.3 closed form
        ident_ok &= abs(lhs - rhs) < 1e-8
    checks["thm11_eq42_eq43_identity"] = bool(ident_ok)
    # and the greedy DkS heuristic maps to a valid adversarial selection
    sub = adversary.densest_k_subgraph_greedy(adj, kq)
    checks["thm11_greedy_dks_valid"] = bool(len(sub) == kq)

    save_csv("adversary", rows)
    save_json("adversary", {"rows": rows, "checks": checks})
    return {"rows": rows, "checks": checks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--s", type=int, default=10)
    ap.add_argument("--delta", type=float, default=0.3)
    args = ap.parse_args(argv)
    rep = run(k=args.k, s=args.s, delta=args.delta)
    for r in rep["rows"]:
        print(r)
    ok = all(rep["checks"].values())
    print("adversary checks:", rep["checks"])
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
