"""E12: coded-hedged serving tail — p99/p999 vs compute overhead.

Replays the bimodal straggler trace (1 of 8 replicas ~3x slow, the
regime where the paper's training-side codes pay off) through the
vectorized multi-replica serving simulator at >= 1M requests, sweeping
the hedge quantile over {0.5, 0.75, 0.85, 0.95, 0.99} under uniform
routing, and reports the tail-latency-vs-compute-overhead frontier.

Acceptance (the serving analogue of "coded beats uncoded at bounded
redundancy"):

  * some hedge quantile achieves p99 <= unhedged p99 at <= 1.1x mean
    compute — the gate is evaluated on the BEST Pareto point among the
    rows within the overhead budget;
  * the frontier shape is the quantile subtlety the module pins: with
    1 of 8 replicas slow, P(fast primary) = 0.875, so q = 0.95 sits
    inside the slow mode and leaves p99 unchanged while q <= 0.85
    collapses it — hedging only helps when the deadline undercuts the
    straggler mass;
  * the whole replay is deterministic in (seed, trace): rerunning the
    best configuration reproduces its latency quantiles bitwise.

A power-of-two-choices row (tail-aware routing, no hedging) is reported
informationally: routing can dodge a *persistently* slow replica
entirely, which is why E12's gate is about hedging, the mechanism that
still works when slowness moves around.

Artifacts: artifacts/bench/serving_tail.{json,csv}; the pinned
``hedged_p99_advantage[bimodal]`` baseline lives in
benchmarks/baselines/serving_tail.json (see docs/benchmarks.md for the
re-pin flow).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.serving import HedgePolicy, pareto_front, simulate_serving
from repro.sim.traces import make_trace
from .common import save_csv, save_json

QUANTILES = (0.5, 0.75, 0.85, 0.95, 0.99)
MIN_REQUESTS = 1_000_000
OVERHEAD_BUDGET = 1.1


def run(requests: int = MIN_REQUESTS, n: int = 8, steps: int = 32_768,
        seed: int = 0):
    trace = make_trace("bimodal", steps=steps, n=n, seed=seed)
    front = pareto_front(trace, requests, quantiles=QUANTILES, seed=seed)
    unhedged = front["unhedged"]
    rows = front["rows"]

    within = [r for r in rows if r["overhead"] <= OVERHEAD_BUDGET]
    best = min(within, key=lambda r: r["p99"]) if within else None
    advantage = (unhedged["p99"] / best["p99"]) if best else 0.0

    # determinism: replay the best configuration; quantiles must be
    # bitwise identical (the hedge-cancellation outcome is a pure
    # function of (seed, trace))
    deterministic = False
    if best:
        again = simulate_serving(
            trace, requests, policy=HedgePolicy(quantile=best["quantile"]),
            seed=seed)
        deterministic = (again.p99 == best["p99"]
                         and again.p999 == best["p999"])

    # tail-aware routing without hedging (informational)
    p2c = simulate_serving(trace, requests, policy=None,
                           router_policy="p2c", seed=seed)

    checks = {
        "requests_ge_1M": bool(requests >= MIN_REQUESTS),
        "hedged_p99_beats_unhedged_at_le_1.1x": bool(
            best is not None and best["p99"] <= unhedged["p99"]),
        "best_overhead_le_1.1x": bool(
            best is not None and best["overhead"] <= OVERHEAD_BUDGET),
        "replay_deterministic": bool(deterministic),
        # the quantile subtlety: a deadline above the fast-mode mass
        # (q = 0.99 > P(fast) = 1 - 1/n) must NOT improve p99
        "q99_does_not_fire_on_slow_mode": bool(
            rows[-1]["p99"] >= 0.99 * unhedged["p99"]),
    }

    payload = {
        "n": n, "requests": requests, "steps": steps, "seed": seed,
        "unhedged": unhedged, "rows": rows,
        "best": best, "advantage": {"bimodal": advantage},
        "p2c_unhedged": {"p99": p2c.p99, "p999": p2c.p999,
                         "mean_compute": p2c.mean_compute},
        "checks": checks,
    }
    save_csv("serving_tail", rows)
    save_json("serving_tail", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=MIN_REQUESTS)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32_768)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rep = run(requests=args.requests, n=args.replicas, steps=args.steps,
              seed=args.seed)
    u = rep["unhedged"]
    print(f"unhedged: p50={u['p50']:.3f} p99={u['p99']:.3f} "
          f"p999={u['p999']:.3f}")
    for r in rep["rows"]:
        print(f"  q={r['quantile']:<5} p99={r['p99']:.3f} "
              f"p999={r['p999']:.3f} overhead={r['overhead']:.3f} "
              f"hedge_rate={r['hedge_rate']:.3f}")
    if rep["best"]:
        print(f"best: q={rep['best']['quantile']} "
              f"p99={rep['best']['p99']:.3f} "
              f"({rep['advantage']['bimodal']:.2f}x advantage at "
              f"{rep['best']['overhead']:.3f}x compute)")
    print(f"p2c routing (no hedge): p99={rep['p2c_unhedged']['p99']:.3f}")
    ok = all(rep["checks"].values())
    print("serving tail checks:", rep["checks"])
    print("PASS" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
