"""ClusterSim: co-simulate wall-clock and decoding over whole runs.

Dataflow (docs/architecture.md §8):

    LatencyTrace [S, n]
        --(sync policy)-->  masks [S, n]  +  step_times [S]
        --(DecodeEngine)->  per-step decode errors [S]   (ONE batched call)

The policy layer is vectorized: sync / deadline / backup map the whole
trace to masks and times with numpy reductions; the adaptive-deadline
controller is the one inherently sequential policy (its deadline at step
t depends on the straggler fraction it observed at t-1) and runs a cheap
O(S·n) python loop — but decoding stays a single ``decode_batch`` over
all S masks per (scheme, policy) cell, never a per-step decode loop.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import numpy as np

from ..core import decoding
from ..core import registry
from ..core.codes import GradientCode
from ..core.engine import DecodeEngine
from .traces import ChurnScenario, LatencyTrace

__all__ = [
    "SyncPolicy", "WaitForAll", "DeadlinePolicy", "BackupPolicy",
    "AdaptiveDeadline", "make_policy", "POLICIES",
    "ClusterRunResult", "ClusterSim", "wallclock_summary",
    "RECOVERY_MODES", "simulate_churn",
]


# --------------------------------------------------------------------------
# sync policies: trace -> (masks, step_times)
# --------------------------------------------------------------------------


class SyncPolicy:
    """Maps a latency row to (non-straggler mask, step time).

    ``apply`` consumes a whole [S, n] trace at once (vectorized where the
    policy allows); ``step`` is the incremental form the training loop
    uses, threading opaque controller state.
    """

    name = "base"

    def step(self, lat: np.ndarray, state=None
             ) -> Tuple[np.ndarray, float, object]:
        raise NotImplementedError

    def apply(self, lat: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray, Dict[str, np.ndarray]]:
        """[S, n] latencies -> (masks [S, n] bool, times [S], extras)."""
        S, n = lat.shape
        masks = np.empty((S, n), dtype=bool)
        times = np.empty(S)
        state = None
        for t in range(S):
            masks[t], times[t], state = self.step(lat[t], state)
        return masks, times, {}


@dataclasses.dataclass
class WaitForAll(SyncPolicy):
    """Uncoded baseline: wait for every worker; nobody straggles."""

    name = "sync"

    def step(self, lat, state=None):
        return np.ones(lat.shape[-1], dtype=bool), float(lat.max()), state

    def apply(self, lat):
        S, n = lat.shape
        return np.ones((S, n), dtype=bool), lat.max(axis=1), {}


@dataclasses.dataclass
class DeadlinePolicy(SyncPolicy):
    """Fixed deadline: workers past it are stragglers absorbed as decode
    error; the step ends at min(deadline, slowest worker)."""

    deadline: float = 1.5
    name = "deadline"

    def step(self, lat, state=None):
        return (lat <= self.deadline,
                float(min(self.deadline, lat.max())), state)

    def apply(self, lat):
        return (lat <= self.deadline,
                np.minimum(self.deadline, lat.max(axis=1)), {})


@dataclasses.dataclass
class BackupPolicy(SyncPolicy):
    """Dean-style backup tasks: the step ends when a `quantile` fraction
    of workers has reported; later arrivals are the stragglers."""

    quantile: float = 0.95
    name = "backup"

    # method='higher' picks the actual arrival time of the quantile
    # worker, so at least ceil(quantile * n) workers report every step
    def step(self, lat, state=None):
        cut = float(np.quantile(lat, self.quantile, method="higher"))
        return lat <= cut, cut, state

    def apply(self, lat):
        cuts = np.quantile(lat, self.quantile, axis=1, method="higher")
        return lat <= cuts[:, None], cuts, {}


@dataclasses.dataclass
class AdaptiveDeadline(SyncPolicy):
    """Online deadline controller: tune the deadline toward a target
    straggler fraction.

    Multiplicative-exponential update (always positive, scale-free):

        d_{t+1} = clip(d_t * exp(gain * (frac_t - target)), dmin, dmax)

    where frac_t is the straggler fraction observed under d_t.  Too many
    stragglers -> the deadline relaxes; too few -> it tightens, trading
    wall-clock back for decode accuracy until the cluster sits at the
    target point of the paper's frontier.
    """

    target: float = 0.1        # straggler fraction to steer toward
    gain: float = 0.5
    d0: float = 1.5            # initial deadline
    dmin: float = 1e-3
    dmax: float = 1e3
    name = "adaptive"

    def step(self, lat, state=None):
        d = self.d0 if state is None else float(state)
        mask = lat <= d
        time = float(min(d, lat.max()))
        frac = 1.0 - mask.mean()
        d_next = float(np.clip(d * np.exp(self.gain * (frac - self.target)),
                               self.dmin, self.dmax))
        return mask, time, d_next

    def apply(self, lat):
        S, n = lat.shape
        masks = np.empty((S, n), dtype=bool)
        times = np.empty(S)
        deadlines = np.empty(S)
        state = None
        for t in range(S):
            deadlines[t] = self.d0 if state is None else state
            masks[t], times[t], state = self.step(lat[t], state)
        return masks, times, {"deadlines": deadlines}


POLICIES = ("sync", "deadline", "backup", "adaptive")


def make_policy(name_or_policy: Union[str, SyncPolicy], **kw) -> SyncPolicy:
    if isinstance(name_or_policy, SyncPolicy):
        return name_or_policy
    registry = {"sync": WaitForAll, "deadline": DeadlinePolicy,
                "backup": BackupPolicy, "adaptive": AdaptiveDeadline}
    if name_or_policy not in registry:
        raise ValueError(f"unknown sync policy {name_or_policy!r}; "
                         f"have {POLICIES}")
    return registry[name_or_policy](**kw)


# --------------------------------------------------------------------------
# the co-simulation
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterRunResult:
    """One (code, trace, policy, decoder) cell of the co-simulation."""

    scheme: str
    policy: str
    decoder: str
    step_times: np.ndarray     # [S] modelled seconds per step
    masks: np.ndarray          # [S, n] non-straggler masks
    errors: np.ndarray         # [S] decode error / k per step
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def steps(self) -> int:
        return int(self.step_times.shape[0])

    @property
    def total_time(self) -> float:
        return float(self.step_times.sum())

    @property
    def mean_step_time(self) -> float:
        return float(self.step_times.mean())

    @property
    def mean_error(self) -> float:
        return float(self.errors.mean())

    @property
    def mean_stragglers(self) -> float:
        return float((~self.masks).sum(axis=1).mean())

    @property
    def worst_stragglers(self) -> int:
        return int((~self.masks).sum(axis=1).max())

    def summary(self) -> dict:
        return {
            "scheme": self.scheme, "policy": self.policy,
            "decoder": self.decoder, "steps": self.steps,
            "total_time": self.total_time,
            "mean_step_time": self.mean_step_time,
            "mean_error": self.mean_error,
            "mean_stragglers": self.mean_stragglers,
            "worst_stragglers": self.worst_stragglers,
        }


class ClusterSim:
    """Trace-driven wall-clock × accuracy co-simulation for one code.

    ``code`` may be a GradientCode or a registry scheme name (built at
    k = n = trace.n with the given ``s``); the requested decoder is
    validated against the family's declared compatibilities.

    The whole run decodes in exactly ONE DecodeEngine.decode_batch call:
    the policy first maps the trace to all S masks, then the engine
    decodes the [S, n] ensemble.  `engine.batch_calls` before/after is
    the test hook for that invariant.
    """

    def __init__(self, code: Union[GradientCode, str], trace: LatencyTrace,
                 policy: Union[str, SyncPolicy] = "deadline", *,
                 decoder: str = "onestep", backend: str = "numpy",
                 s: Optional[int] = None, iters: int = 8,
                 engine: Optional[DecodeEngine] = None,
                 code_seed: int = 0, staleness: int = 0,
                 decode_cost: float = 0.0, **policy_kw):
        if isinstance(code, str):
            # scheme name -> registry build sized to the trace (k = n).
            # Validate against the REQUESTED family (a registered alias
            # may construct codes named after its base constructor).
            if s is None:
                raise ValueError(
                    f"ClusterSim({code!r}, ...) needs an explicit s= "
                    f"(tasks per worker) to build the code; a silent "
                    f"default would misreport the frontier")
            fam = registry.get(code)
            code = fam.make(k=trace.n, n=trace.n, s=s, seed=code_seed)
        else:
            fam = registry.find(code.name)
        if fam is not None:
            fam.require_decoder(decoder)
        if trace.n != code.n:
            raise ValueError(f"trace has n={trace.n} workers but code has "
                             f"n={code.n}")
        self.code = code
        self.trace = trace
        self.policy = make_policy(policy, **policy_kw)
        self.decoder = decoder
        self.engine = engine if engine is not None else DecodeEngine(
            code, backend=backend, s=s, iters=iters)
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        # decode pipelining (docs/architecture.md §10): step t applies
        # the weights decoded from step t-staleness's mask, re-masked by
        # step t's stragglers; the decode overlaps the compute, so its
        # cost leaves the critical path whenever decode_cost <= the
        # policy's step time.  staleness=0 keeps the synchronous
        # semantics with the decode cost ADDED to every step.
        self.staleness = int(staleness)
        self.decode_cost = float(decode_cost)

    def run(self) -> ClusterRunResult:
        masks, times, extras = self.policy.apply(self.trace.latencies)
        if self.staleness == 0:
            errors = self.engine.errors_batch(masks, self.decoder) \
                / self.code.k
            if self.decode_cost:
                times = times + self.decode_cost   # synchronous barrier
            return ClusterRunResult(
                scheme=self.code.name, policy=self.policy.name,
                decoder=self.decoder, step_times=times, masks=masks,
                errors=errors, extras=extras)
        # stale-weighted pipelining, still ONE decode_batch: prepend
        # `staleness` all-alive warm-start rows so row t of the decoded
        # ensemble is what step t applies (weights of mask t-staleness)
        S, n = masks.shape
        st = self.staleness
        aug = np.vstack([np.ones((st, n), dtype=bool), masks])
        W = self.engine.decode_batch(aug, self.decoder).weights
        W_eff = W[:S] * masks                       # today's stragglers: 0
        errors = decoding.err_batch(self.code.G, W_eff) / self.code.k
        # the decode overlaps the next step's compute; it only stretches
        # a step whose compute finishes before the decode does
        times = np.maximum(times, self.decode_cost)
        return ClusterRunResult(
            scheme=self.code.name, policy=self.policy.name,
            decoder=self.decoder, step_times=times, masks=masks,
            errors=errors, extras=extras)

    def run_distributed(self, *, steps: Optional[int] = None,
                        task_grads: Optional[np.ndarray] = None,
                        mesh=None, impl: str = "xla",
                        fused: bool = False) -> ClusterRunResult:
        """The co-simulation executed on REAL devices (docs/architecture.md §9).

        Same trace -> policy -> masks dataflow as :meth:`run`, but the
        decode happens through ``dist.coded_allreduce``: each device
        combines its workers' coded messages with the step's decode
        weights and the weighted psum over the worker mesh produces the
        decoded gradient.  Weights for ALL S masks still come from ONE
        ``decode_batch`` call (the engine invariant holds on this path
        too).

        ``task_grads`` [k, P] are the per-task gradients; the default is
        the k standard basis vectors, for which the decoded vector is
        exactly ``G @ w_s`` and the on-device squared error against the
        full gradient (the all-ones vector) IS the decode error the
        analytic path reports — so ``errors`` (device-measured) can be
        compared against ``extras['analytic_errors']`` (engine-derived)
        to validate the E11 frontier against real multi-device
        execution.  Run with ``repro.platform.host_devices(8)`` (or
        ``REPRO_HOST_DEVICES=8``) for a real 8-way mesh; a single
        device degenerates to lanes = n.

        ``fused=True`` routes the aggregation through
        ``CodedAllReduce.aggregate_messages_fused`` (one-step decoder
        only): the decode weights are never materialized — each device
        contracts its raw mask lanes against the local messages and the
        per-step scale applies at emission.
        """
        from ..dist.coded_allreduce import CodedAllReduce

        lat = self.trace.latencies if steps is None \
            else self.trace.latencies[:steps]
        masks, times, extras = self.policy.apply(lat)
        if task_grads is None:
            task_grads = np.eye(self.code.k)
        task_grads = np.asarray(task_grads, dtype=np.float64)
        messages = self.code.G.T @ task_grads          # [n, P] worker msgs
        allreduce = CodedAllReduce(self.code, engine=self.engine, mesh=mesh)
        if fused:
            if self.decoder != "onestep":
                raise ValueError("fused=True implements the one-step "
                                 f"decoder; got decoder={self.decoder!r}")
            decoded = allreduce.aggregate_messages_fused(
                messages, masks, renorm=False, impl=impl)
            scales = self.engine.onestep_scales(masks)
            analytic = decoding.err_batch(
                self.code.G, scales[:, None] * masks) / self.code.k
        else:
            decoded_batch = self.engine.decode_batch(masks, self.decoder)
            decoded = allreduce.aggregate_messages_batch(
                messages, decoded_batch.weights, impl=impl)
            analytic = decoded_batch.errors / self.code.k
        full = task_grads.sum(axis=0)                  # the uncoded gradient
        dev_errors = ((decoded - full[None]) ** 2).sum(axis=1) / self.code.k
        extras = dict(extras,
                      analytic_errors=analytic,
                      decoded=decoded,
                      n_devices=allreduce.n_devices)
        return ClusterRunResult(
            scheme=self.code.name, policy=self.policy.name,
            decoder=self.decoder, step_times=times, masks=masks,
            errors=dev_errors, extras=extras)


# --------------------------------------------------------------------------
# elastic churn: membership change through the co-simulation
# --------------------------------------------------------------------------


RECOVERY_MODES = ("elastic", "restart", "oblivious")


def simulate_churn(scheme: Union[GradientCode, str],
                   scenario: ChurnScenario,
                   policy: Union[str, SyncPolicy] = "deadline", *,
                   decoder: str = "onestep", s: int,
                   recovery: str = "elastic", seed: int = 0,
                   ckpt_every: int = 25, restart_penalty: float = 10.0,
                   recode_penalty: float = 0.0, backend: str = "numpy",
                   **policy_kw) -> ClusterRunResult:
    """Co-simulate a run through a :class:`~repro.sim.traces.ChurnScenario`
    under one of three recovery modes (the E13 comparison):

      * ``elastic``  — every membership change re-codes for the new live
        set (the paper's O(n·s) construction makes this ~free:
        ``recode_penalty`` seconds per event, default 0) and training
        continues.  Decoding stays batched: ONE ``decode_batch`` per
        membership EPOCH, not per step.
      * ``restart``  — any membership change kills the gang-scheduled
        job: the run restores its last checkpoint (every ``ckpt_every``
        steps), re-pays the steps since that checkpoint, plus a fixed
        ``restart_penalty`` (scheduler + restore latency) per event.
        Decode errors match elastic (the restarted job also gets a
        right-sized code); only wall-clock differs.
      * ``oblivious``— no recovery at all: the code stays sized for the
        initial fleet, departed workers become PERMANENT stragglers
        (latency ``inf``), and arrivals are ignored.  Decode error
        accumulates with every departure; still one batched decode.

    Per-worker heterogeneity (``scenario.speed``) scales every latency
    row.  Use a bounded sync policy (deadline/backup): under
    ``oblivious`` churn a wait-for-all policy would wait forever on the
    first departure.  The result's masks are padded to capacity
    ``n_max`` (dead/unused slots False); ``extras`` carries the live
    count per step, the event list, epoch count, and for ``restart`` the
    redone wall-clock.
    """
    if recovery not in RECOVERY_MODES:
        raise ValueError(f"recovery {recovery!r} not in {RECOVERY_MODES}")
    policy = make_policy(policy, **policy_kw)
    if isinstance(scheme, GradientCode):
        fam = registry.find(scheme.name)
        if fam is None:
            raise ValueError(f"code family {scheme.name!r} not registered")
        scheme_name, params = scheme.name, dict(scheme.params)
    else:
        fam = registry.get(scheme)
        scheme_name, params = scheme, {}
    fam.require_decoder(decoder)
    S, n_max = scenario.steps, scenario.n_max
    masks = np.zeros((S, n_max), dtype=bool)
    times = np.empty(S)
    errors = np.empty(S)
    n_live = np.empty(S, dtype=np.int64)
    decode_calls = 0

    if recovery == "oblivious":
        n0 = scenario.n0
        code = fam.make(k=n0, n=n0, s=min(s, n0), seed=seed, **params)
        engine = DecodeEngine(code, backend=backend, s=code.s)
        # departed workers never report again: inf latency from their
        # death step on (arrivals ignored — nobody re-codes for them)
        alive = scenario.membership()[:, :n0].copy()   # never mutate cache
        alive = np.logical_and.accumulate(alive, axis=0)
        lat = scenario.trace.latencies[:S, :n0] * scenario.speed[None, :n0]
        lat = np.where(alive, lat, np.inf)
        pmasks, times, _ = policy.apply(lat)
        pmasks &= alive
        errors = engine.errors_batch(pmasks, decoder) / code.k
        decode_calls = 1
        masks[:, :n0] = pmasks
        n_live[:] = alive.sum(axis=1)
        return ClusterRunResult(
            scheme=code.name, policy=policy.name, decoder=decoder,
            step_times=times, masks=masks, errors=errors,
            extras={"recovery": recovery, "n_live": n_live,
                    "events": [e.as_dict() for e in scenario.events],
                    "epochs": 1, "decode_calls": decode_calls})

    # elastic / restart: membership epochs, one code + one batched
    # decode per epoch
    segments = []                      # (start, stop, live_ids)
    live = scenario.initial_ids()
    cursor = 0
    event_steps = sorted({e.step for e in scenario.events})
    for es in event_steps:
        if es > cursor:
            segments.append((cursor, es, live))
        for e in scenario.events_at(es):
            live = scenario.apply_event(live, e)
        if live.size < 2:
            raise ValueError(f"scenario drops below 2 live workers at "
                             f"step {es}")
        cursor = es
    segments.append((cursor, S, live))
    segments = [seg for seg in segments if seg[1] > seg[0]]

    for start, stop, ids in segments:
        n_seg = ids.size
        code = fam.make(k=n_seg, n=n_seg, s=min(s, n_seg), seed=seed,
                        **params)
        engine = DecodeEngine(code, backend=backend, s=code.s)
        lat = scenario.trace.latencies[start:stop, ids] \
            * scenario.speed[None, ids]
        seg_masks, seg_times, _ = policy.apply(lat)
        errors[start:stop] = engine.errors_batch(seg_masks, decoder) / code.k
        times[start:stop] = seg_times
        masks[start:stop][:, ids] = seg_masks
        n_live[start:stop] = n_seg
        decode_calls += 1

    redo_total = 0.0
    base_times = times.copy()          # penalty-free, for redo accounting
    for es in event_steps:
        if recovery == "elastic":
            times[es] += recode_penalty
        else:
            # the job dies and restarts from its last checkpoint: the
            # steps since it are recomputed (charged at their modelled
            # cost) on top of the scheduler/restore latency
            last_ckpt = (es // max(ckpt_every, 1)) * max(ckpt_every, 1)
            redo = float(base_times[last_ckpt:es].sum())
            times[es] += restart_penalty + redo
            redo_total += redo
    return ClusterRunResult(
        scheme=scheme_name, policy=policy.name, decoder=decoder,
        step_times=times, masks=masks, errors=errors,
        extras={"recovery": recovery, "n_live": n_live,
                "events": [e.as_dict() for e in scenario.events],
                "epochs": len(segments), "decode_calls": decode_calls,
                "redo_time": redo_total})


# --------------------------------------------------------------------------
# aggregate summary (absorbed the removed runtime.latency wrapper)
# --------------------------------------------------------------------------


def wallclock_summary(trace: LatencyTrace, policy: str = "deadline",
                      deadline: float = 1.5,
                      compute_scale: float = 1.0) -> dict:
    """Aggregate wall-clock + straggler stats — the PR-2 home of the
    old ``runtime.latency.simulate_wallclock`` semantics (the wrapper
    itself is gone; this is the API).

    The old implementation compared ``lat * compute_scale <= deadline *
    compute_scale`` — the scale cancels, so the mask is just ``lat <=
    deadline`` on the unscaled trace; only the step *times* scale.  Old
    quirks preserved for parity: 'sync' and 'backup' report zero
    stragglers (their mask statistic was all-ones), and 'backup' uses the
    0.95 quantile of the scaled latencies.
    """
    lat = trace.latencies * compute_scale
    if policy == "sync":
        times = lat.max(axis=1)
        masks = np.ones(lat.shape, dtype=bool)
    elif policy == "deadline":
        times = np.minimum(deadline * compute_scale, lat.max(axis=1))
        masks = trace.latencies <= deadline
    elif policy == "backup":
        times = np.quantile(lat, 0.95, axis=1)
        masks = np.ones(lat.shape, dtype=bool)
    else:
        raise ValueError(policy)
    total = float(times.sum())
    return {
        "total_time": total,
        "mean_step_time": total / trace.steps,
        "mean_stragglers": float((~masks).sum(axis=1).mean()),
        "worst_stragglers": int((~masks).sum(axis=1).max()),
    }
