"""ClusterSim: trace-driven wall-clock × accuracy co-simulation.

trace (sim.traces) -> masks + step times (sim.cluster sync policies)
-> one batched decode per run (core.engine) -> frontiers (sim.frontier).
See docs/architecture.md §8.
"""

from .cluster import (  # noqa: F401
    AdaptiveDeadline,
    BackupPolicy,
    ClusterRunResult,
    ClusterSim,
    DeadlinePolicy,
    POLICIES,
    SyncPolicy,
    WaitForAll,
    make_policy,
    wallclock_summary,
)
from .frontier import (  # noqa: F401
    FrontierPoint,
    pareto_front,
    sweep_adaptive,
    sweep_frontier,
    time_to_target_error,
)
from .traces import (  # noqa: F401
    LatencyTrace,
    TRACE_SOURCES,
    make_trace,
    trace_from_model,
)
