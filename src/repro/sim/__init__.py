"""ClusterSim: trace-driven wall-clock × accuracy co-simulation.

trace (sim.traces) -> masks + step times (sim.cluster sync policies)
-> one batched decode per run (core.engine) -> frontiers (sim.frontier).
Membership change rides the same trace layer: a ``ChurnScenario`` is a
latency trace plus worker arrival/departure events and per-worker speed
multipliers, consumed by ``simulate_churn`` (analytic, one batched
decode per membership epoch) and by the trainer's ``churn=`` path.
See docs/architecture.md §8 and §11.
"""

from .cluster import (  # noqa: F401
    AdaptiveDeadline,
    BackupPolicy,
    ClusterRunResult,
    ClusterSim,
    DeadlinePolicy,
    POLICIES,
    RECOVERY_MODES,
    SyncPolicy,
    WaitForAll,
    make_policy,
    simulate_churn,
    wallclock_summary,
)
from .frontier import (  # noqa: F401
    FrontierPoint,
    pareto_front,
    sweep_adaptive,
    sweep_frontier,
    time_to_target_error,
)
from .traces import (  # noqa: F401
    ChurnEvent,
    ChurnScenario,
    LatencyTrace,
    TRACE_SOURCES,
    ingest_machine_events,
    make_churn_scenario,
    make_trace,
    trace_from_model,
)
