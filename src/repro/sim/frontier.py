"""Runtime-vs-accuracy frontiers: the paper's central figure, sweepable.

Each (scheme, decoder, policy) cell runs one ClusterSim over a shared
latency trace and contributes a point (wall-clock, decode error).  The
frontier is the Pareto set of those points: the policies that buy the
most tail-latency for the least decode error.

``time_to_target_error`` converts a cell to a single scalar: the
modelled wall-clock to finish S optimization steps, inflated by the
standard first-order penalty for training on approximate gradients —
a gradient with relative decoding error e per step needs ~1/(1 - e)
times the steps to reach the same loss (e >= 1 never converges).  It is
a *model*, not a measurement; benchmarks/e2e_convergence.py measures the
real thing on a small LM.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from ..core import registry, theory
from .cluster import ClusterRunResult, ClusterSim, SyncPolicy, make_policy
from .traces import LatencyTrace

__all__ = ["FrontierPoint", "sweep_frontier", "sweep_adaptive",
           "pareto_front", "time_to_target_error", "gap_to_optimal_frac"]


@dataclasses.dataclass
class FrontierPoint:
    scheme: str
    policy: str
    decoder: str
    total_time: float
    mean_step_time: float
    mean_error: float          # mean decode err / k over the run
    mean_stragglers: float
    time_to_target: float      # convergence-penalty-adjusted wall-clock
    # measured error / Wang et al. fundamental lower bound at the cell's
    # realized straggler fraction (1.0 = on the limit; None when the
    # bound is 0, i.e. no stragglers, or for adaptive cells whose s
    # varies over the run)
    gap_to_optimal: Optional[float] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def gap_to_optimal_frac(mean_error: float, k: int, n: int, s: int,
                        mean_stragglers: float) -> Optional[float]:
    """Measured err/k over the fundamental limit's err/k at the
    realized mean straggler fraction (iid-load form — the ClusterSim
    deadline policies straggle per-worker, not fixed-count).  None when
    the bound is 0 (delta = 0: any code is exact with all workers
    alive, so there is no gap to measure); the bound evaluated at the
    MEAN delta understates the per-step average (delta**d is convex),
    so the ratio tends to overstate the gap.  A ratio below 1 — e.g. a
    backup policy that covered every task on every step, err exactly
    0 — means the realized mask ensemble was gentler than the uniform
    straggler model the bound assumes, not that the limit was beaten."""
    delta = float(min(max(mean_stragglers / max(n, 1), 0.0), 1.0))
    lb = theory.fundamental_err_lower_bound_load(k, s, delta, n) / k
    if lb <= 0.0:
        return None
    return max(0.0, mean_error) / lb


def time_to_target_error(result: ClusterRunResult,
                         max_inflation: float = 100.0) -> float:
    """Modelled time to a fixed optimization target (see module doc).

    total_time / (1 - mean_error), clipped: cells whose decode error
    approaches/exceeds 1 (gradient mostly noise) saturate at
    `max_inflation` x rather than going infinite/negative.
    """
    e = result.mean_error
    inflation = max_inflation if e >= 1.0 else min(1.0 / (1.0 - e),
                                                   max_inflation)
    return result.total_time * inflation


def sweep_frontier(
    schemes: Sequence[str],
    policies: Sequence[Union[str, SyncPolicy]],
    trace: LatencyTrace,
    *,
    k: Optional[int] = None,
    s: int = 8,
    decoders: Sequence[str] = ("onestep",),
    seed: int = 0,
    backend: str = "numpy",
    iters: int = 8,
    policy_kw: Optional[Dict[str, dict]] = None,
) -> List[FrontierPoint]:
    """One ClusterSim per (scheme, decoder, policy) cell over a shared
    trace; every cell is exactly one batched decode.  Schemes resolve
    through the registry; decoders a family does not declare are
    skipped (so a mixed sweep can request the union of decoders)."""
    n = trace.n
    k = n if k is None else k
    policy_kw = policy_kw or {}
    out: List[FrontierPoint] = []
    for scheme in schemes:
        fam = registry.get(scheme)
        code = fam.make(k=k, n=n, s=s, rng=np.random.default_rng(seed))
        for decoder in decoders:
            if not fam.supports_decoder(decoder):
                continue
            for pol in policies:
                name = pol if isinstance(pol, str) else pol.name
                policy = make_policy(pol, **policy_kw.get(name, {}))
                res = ClusterSim(code, trace, policy, decoder=decoder,
                                 backend=backend, s=s, iters=iters).run()
                out.append(FrontierPoint(
                    scheme=scheme, policy=res.policy, decoder=decoder,
                    total_time=res.total_time,
                    mean_step_time=res.mean_step_time,
                    mean_error=res.mean_error,
                    mean_stragglers=res.mean_stragglers,
                    time_to_target=time_to_target_error(res),
                    gap_to_optimal=gap_to_optimal_frac(
                        res.mean_error, k, n, s, res.mean_stragglers)))
    return out


def sweep_adaptive(
    schemes: Sequence[str],
    trace: LatencyTrace,
    *,
    s: int = 8,
    error_budget: float = 0.1,
    seed: int = 0,
    control_cfg=None,
) -> List[FrontierPoint]:
    """The ``adaptive_coder`` policy column of the frontier: one
    closed-loop AdaptiveCoder run per scheme over the shared trace
    (docs/adaptive.md).  ``s`` doubles as the static sweep's reference
    replication — adaptive step times are charged s_live / s for the
    compute of the live code, so the points are directly comparable
    with a ``sweep_frontier`` over the same trace at the same ``s``.
    Lazy import: ``repro.control`` depends on sim, not vice versa."""
    from ..control.runner import adaptive_frontier_point

    return [adaptive_frontier_point(scheme, trace, s=s,
                                    error_budget=error_budget,
                                    cfg=control_cfg, seed=seed)
            for scheme in schemes]


def pareto_front(points: Sequence[FrontierPoint],
                 x: str = "mean_step_time",
                 y: str = "mean_error") -> List[FrontierPoint]:
    """Non-dominated subset (minimize both axes), sorted by x."""
    pts = sorted(points, key=lambda p: (getattr(p, x), getattr(p, y)))
    front: List[FrontierPoint] = []
    best_y = np.inf
    for p in pts:
        if getattr(p, y) < best_y - 1e-15:
            front.append(p)
            best_y = getattr(p, y)
    return front
