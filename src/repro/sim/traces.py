"""LatencyTrace: the one latency abstraction behind ClusterSim.

A trace is a ``[steps, n]`` matrix of per-worker compute latencies for a
whole run — the co-simulation's ground truth.  Everything upstream of
the sync policy is a trace source:

  * the straggler models in ``runtime.straggler`` that own a real
    latency distribution (Pareto-tail deadline, bimodal slow-node)
    contribute their ``latencies(step, n)`` rows directly;
  * mask-only models (iid, fixed-fraction, pod-correlated, adversarial)
    are lifted to latencies by mapping straggler -> ``slow`` and
    non-straggler -> ``base`` — the two-point distribution their mask
    semantics already implies;
  * recorded cluster traces replay from JSON (``LatencyTrace.load``).

This unified the old ``runtime/latency.py`` (which sampled latencies
step by step; removed in PR 5) and ``runtime/straggler.py`` (which
samples masks) behind one API:
a trace is sampled once, then any sync policy in ``sim.cluster`` maps it
to per-step masks + step times, and the DecodeEngine decodes all the
masks in one batched call.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Tuple, Union

import numpy as np

from ..core.codes import block_ids
from ..runtime.straggler import StragglerModel, make_straggler_model

__all__ = ["LatencyTrace", "TraceCursor", "trace_from_model", "make_trace",
           "TRACE_SOURCES", "ChurnEvent", "ChurnScenario",
           "make_churn_scenario", "ingest_machine_events"]


@dataclasses.dataclass(frozen=True)
class LatencyTrace:
    """Per-worker latencies for a whole run: ``latencies[t, j]`` is the
    compute time of worker j at step t (seconds)."""

    latencies: np.ndarray          # [steps, n] float64
    source: str = "unknown"

    def __post_init__(self):
        lat = np.asarray(self.latencies, dtype=np.float64)
        if lat.ndim != 2:
            raise ValueError(f"trace must be [steps, n], got {lat.shape}")
        if lat.size and lat.min() < 0:
            raise ValueError("latencies must be non-negative")
        object.__setattr__(self, "latencies", lat)

    @property
    def steps(self) -> int:
        return int(self.latencies.shape[0])

    @property
    def n(self) -> int:
        return int(self.latencies.shape[1])

    def scaled(self, compute_scale: float) -> "LatencyTrace":
        """Rescale every latency (s coded tasks cost ~s/1 of the uncoded
        step — the paper's compute-overhead axis)."""
        return LatencyTrace(self.latencies * float(compute_scale),
                            source=self.source)

    def window(self, start: int, stop: Optional[int] = None) -> "LatencyTrace":
        return LatencyTrace(self.latencies[start:stop], source=self.source)

    def tile(self, steps: int) -> "LatencyTrace":
        """Repeat the trace to cover `steps` rows (replay longer runs)."""
        reps = -(-steps // self.steps)
        return LatencyTrace(np.tile(self.latencies, (reps, 1))[:steps],
                            source=self.source)

    # ---------------------------- JSON replay ----------------------------

    def to_json(self) -> str:
        return json.dumps({"source": self.source,
                           "latencies": self.latencies.tolist()})

    @classmethod
    def from_json(cls, text: str) -> "LatencyTrace":
        obj = json.loads(text)
        return cls(np.asarray(obj["latencies"], dtype=np.float64),
                   source=obj.get("source", "replay"))

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: Union[str, Path]) -> "LatencyTrace":
        return cls.from_json(Path(path).read_text())


class TraceCursor:
    """Per-column replay cursor over a :class:`LatencyTrace`.

    The serving simulator treats column j as replica j's latency
    *stream*: each draw for a replica consumes that replica's next row
    (wrapping modulo ``steps``), independently of the other replicas.
    ``take`` is fully vectorized — a chunk of replica ids draws all its
    latencies in one call, with requests routed to the same replica
    consuming consecutive rows in request order.
    """

    def __init__(self, trace: LatencyTrace):
        if trace.steps == 0 or trace.n == 0:
            raise ValueError("cursor needs a non-empty trace")
        self.trace = trace
        self._pos = np.zeros(trace.n, dtype=np.int64)

    def take(self, replicas: np.ndarray) -> np.ndarray:
        """Next latency for each entry of ``replicas`` ([R] int)."""
        r = np.asarray(replicas, dtype=np.int64)
        if r.size == 0:
            return np.empty(0)
        if r.min() < 0 or r.max() >= self.trace.n:
            raise ValueError(f"replica ids out of range [0, {self.trace.n})")
        order = np.argsort(r, kind="stable")
        sr = r[order]
        # cumcount within each replica group (sr is sorted, so groups
        # are contiguous): entry i gets its replica's (pos + cumcount)th row
        starts = np.flatnonzero(np.r_[True, sr[1:] != sr[:-1]])
        sizes = np.diff(np.r_[starts, sr.size])
        cum = np.arange(sr.size) - np.repeat(starts, sizes)
        rows = (self._pos[sr] + cum) % self.trace.steps
        out = np.empty(r.size)
        out[order] = self.trace.latencies[rows, sr]
        uniq = sr[starts]
        self._pos[uniq] = (self._pos[uniq] + sizes) % self.trace.steps
        return out


def _has_latency_distribution(model: StragglerModel) -> bool:
    """True when the model overrides the base unit-latency stub."""
    return type(model).latencies is not StragglerModel.latencies


def trace_from_model(model: StragglerModel, steps: int, n: int, *,
                     base: float = 1.0, slow: float = 3.0) -> LatencyTrace:
    """Sample a [steps, n] trace from any straggler model.

    Models with a real latency distribution (DeadlineStragglers,
    BimodalStragglers) are sampled directly; mask-only models are lifted
    via straggler -> `slow`, non-straggler -> `base`.
    """
    lat = np.empty((steps, n))
    if _has_latency_distribution(model):
        for t in range(steps):
            lat[t] = model.latencies(t, n)
    else:
        for t in range(steps):
            lat[t] = np.where(model.sample(t, n), base, slow)
    return LatencyTrace(lat, source=type(model).__name__)


# sources with first-class latency semantics; anything accepted by
# make_straggler_model also works (lifted through the two-point map).
# 'clustered' is the block-correlated slow-episode source whose failing
# blocks align with the SBM code's worker clusters (core.codes.block_ids)
TRACE_SOURCES = ("pareto", "bimodal", "clustered", "correlated",
                 "adversarial", "iid", "fixed", "none", "replay")


def make_trace(source: str, steps: int = 0, n: int = 0, *,
               path: Optional[Union[str, Path]] = None,
               base: float = 1.0, slow: float = 3.0,
               **kw) -> LatencyTrace:
    """Trace factory: named straggler models plus JSON replay.

    'pareto' aliases the DeadlineStragglers Pareto-tail model; 'replay'
    loads `path` and tiles it to `steps` when steps > 0.
    """
    if source == "replay":
        if path is None:
            raise ValueError("replay trace needs path=")
        trace = LatencyTrace.load(path)
        return trace.tile(steps) if steps else trace
    if steps <= 0 or n <= 0:
        raise ValueError("generated traces need steps > 0 and n > 0")
    name = "deadline" if source == "pareto" else source
    model = make_straggler_model(name, **kw)
    return trace_from_model(model, steps, n, base=base, slow=slow)


# ==========================================================================
# churn: worker arrival / departure as a first-class trace channel
# ==========================================================================

EVENT_KINDS = ("preempt", "preempt_block", "scale_up")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """One membership change, applied at the TOP of ``step`` (before the
    step's mask is drawn).

    ``preempt`` / ``preempt_block`` remove the capacity slots listed in
    ``workers`` (block preemption lists a whole code block — aligned to
    :func:`repro.core.codes.block_ids` over the live set at emission
    time); ``scale_up`` adds ``count`` fresh workers drawn from the
    lowest inactive capacity slots.
    """

    step: int
    kind: str
    workers: Tuple[int, ...] = ()    # capacity slot ids removed (preempt*)
    count: int = 0                   # workers added (scale_up)

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {EVENT_KINDS}")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")
        if self.kind.startswith("preempt") and not self.workers:
            raise ValueError(f"{self.kind} event needs workers")
        if self.kind == "scale_up" and self.count <= 0:
            raise ValueError("scale_up event needs count > 0")
        object.__setattr__(self, "workers",
                           tuple(int(w) for w in self.workers))

    def as_dict(self) -> dict:
        return {"step": int(self.step), "kind": self.kind,
                "workers": list(self.workers), "count": int(self.count)}

    @classmethod
    def from_dict(cls, d: dict) -> "ChurnEvent":
        return cls(step=int(d["step"]), kind=d["kind"],
                   workers=tuple(d.get("workers", ())),
                   count=int(d.get("count", 0)))


@dataclasses.dataclass(frozen=True)
class ChurnScenario:
    """A latency trace plus the membership channel on top of it.

    The trace is sampled at full CAPACITY ``n_max`` (= ``trace.n``);
    slots ``[0, n0)`` are live at step 0 and :class:`ChurnEvent`\\ s
    mutate the live set over the run.  ``speed`` is the heterogeneous
    per-worker latency multiplier (worker j's latency at step t is
    ``trace.latencies[t, j] * speed[j]``) — spot fleets are not uniform
    hardware.  Membership replay is pure in the scenario, so every
    consumer (trainer, analytic sim, E13) derives the identical live-set
    trajectory.
    """

    trace: LatencyTrace
    events: Tuple[ChurnEvent, ...] = ()
    speed: Optional[np.ndarray] = None     # [n_max] multipliers, default 1
    n0: Optional[int] = None               # live at step 0 (default n_max)

    def __post_init__(self):
        events = tuple(sorted((e if isinstance(e, ChurnEvent)
                               else ChurnEvent.from_dict(e)
                               for e in self.events), key=lambda e: e.step))
        object.__setattr__(self, "events", events)
        n0 = self.trace.n if self.n0 is None else int(self.n0)
        if not (1 <= n0 <= self.trace.n):
            raise ValueError(f"n0={n0} must be in [1, n_max={self.trace.n}]")
        object.__setattr__(self, "n0", n0)
        speed = (np.ones(self.trace.n) if self.speed is None
                 else np.asarray(self.speed, dtype=np.float64))
        if speed.shape != (self.trace.n,):
            raise ValueError(f"speed shape {speed.shape} != ({self.trace.n},)")
        if speed.size and speed.min() <= 0:
            raise ValueError("speed multipliers must be positive")
        object.__setattr__(self, "speed", speed)
        for e in events:
            if not (0 <= e.step < self.steps):
                raise ValueError(f"event at step {e.step} outside "
                                 f"[0, {self.steps})")
            if e.workers and (min(e.workers) < 0
                              or max(e.workers) >= self.n_max):
                raise ValueError(f"event workers {e.workers} outside "
                                 f"[0, {self.n_max})")

    @property
    def steps(self) -> int:
        return self.trace.steps

    @property
    def n_max(self) -> int:
        return self.trace.n

    def events_at(self, step: int) -> Tuple[ChurnEvent, ...]:
        return tuple(e for e in self.events if e.step == step)

    def initial_ids(self) -> np.ndarray:
        return np.arange(self.n0, dtype=np.int64)

    def apply_event(self, live: np.ndarray, event: ChurnEvent) -> np.ndarray:
        """THE membership-transition rule (single source of truth).

        preempt*: drop the listed slots (already-dead slots are ignored
        — replayed external traces can double-report removals).
        scale_up: add the ``count`` lowest inactive capacity slots
        (clamped at capacity).  Returns a sorted live-id array.
        """
        live_set = set(int(x) for x in np.asarray(live).ravel())
        if event.kind in ("preempt", "preempt_block"):
            live_set -= set(event.workers)
        else:
            free = [j for j in range(self.n_max) if j not in live_set]
            live_set |= set(free[: event.count])
        return np.array(sorted(live_set), dtype=np.int64)

    def membership(self) -> np.ndarray:
        """[steps, n_max] bool live matrix from replaying the events."""
        cached = self.__dict__.get("_membership")
        if cached is not None:
            return cached
        out = np.zeros((self.steps, self.n_max), dtype=bool)
        live = self.initial_ids()
        by_step: dict = {}
        for e in self.events:
            by_step.setdefault(e.step, []).append(e)
        for t in range(self.steps):
            for e in by_step.get(t, ()):
                live = self.apply_event(live, e)
            out[t, live] = True
        object.__setattr__(self, "_membership", out)
        return out

    def latencies_at(self, step: int, ids: np.ndarray) -> np.ndarray:
        """Speed-scaled latency row for the given live slots."""
        ids = np.asarray(ids, dtype=np.int64)
        row = self.trace.latencies[step % self.steps, ids]
        return row * self.speed[ids]

    # ---------------------------- JSON replay ----------------------------

    def to_json(self) -> str:
        return json.dumps({
            "source": self.trace.source,
            "latencies": self.trace.latencies.tolist(),
            "events": [e.as_dict() for e in self.events],
            "speed": self.speed.tolist(),
            "n0": int(self.n0),
        })

    @classmethod
    def from_json(cls, text: str) -> "ChurnScenario":
        obj = json.loads(text)
        return cls(
            trace=LatencyTrace(np.asarray(obj["latencies"], dtype=np.float64),
                               source=obj.get("source", "replay")),
            events=tuple(ChurnEvent.from_dict(d)
                         for d in obj.get("events", ())),
            speed=(np.asarray(obj["speed"], dtype=np.float64)
                   if obj.get("speed") is not None else None),
            n0=obj.get("n0"),
        )

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: Union[str, Path]) -> "ChurnScenario":
        return cls.from_json(Path(path).read_text())


def make_churn_scenario(source: str = "bimodal", steps: int = 400,
                        n0: int = 64, *, n_max: Optional[int] = None,
                        preempt_rate: float = 0.02, preempt_max: int = 2,
                        block_rate: float = 0.0, blocks: int = 4,
                        scaleup_rate: float = 0.01, scaleup_max: int = 4,
                        min_workers: int = 4, speed_sigma: float = 0.0,
                        warmup: int = 10, seed: int = 0,
                        **trace_kw) -> ChurnScenario:
    """Scenario generator: spot-market churn over any trace source.

    Per step (after ``warmup``), at most one event fires: a whole-block
    preemption with probability ``block_rate`` (the block drawn from
    :func:`~repro.core.codes.block_ids` over the CURRENT live set, so a
    failing block is exactly one of the blocks an SBM code built over
    those workers would use), else a spot preemption of 1..preempt_max
    random live workers with probability ``preempt_rate``, else a
    scale-up of 1..scaleup_max fresh workers with probability
    ``scaleup_rate``.  Events never push the fleet below ``min_workers``
    or above capacity.  ``speed_sigma > 0`` draws lognormal per-worker
    speed multipliers.  Everything is pure in ``seed``.
    """
    if n_max is None:
        n_max = max(n0 + max(2 * scaleup_max, n0 // 4), n0)
    if not (1 <= min_workers <= n0 <= n_max):
        raise ValueError(f"need 1 <= min_workers <= n0 <= n_max, got "
                         f"({min_workers}, {n0}, {n_max})")
    trace = make_trace(source, steps=steps, n=n_max, seed=seed, **trace_kw)
    ev_rng = np.random.default_rng(np.random.SeedSequence([seed, 0xC4]))
    sp_rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5D]))
    speed = (np.exp(sp_rng.normal(0.0, speed_sigma, n_max))
             if speed_sigma > 0 else None)
    scenario = ChurnScenario(trace=trace, speed=speed, n0=n0)  # event-free
    live = scenario.initial_ids()
    events = []
    for t in range(warmup, steps):
        u = ev_rng.random()
        event = None
        if u < block_rate and blocks > 1:
            # whole-block loss: the correlated-failure world of the
            # clustered trace, hitting membership instead of latency
            ids = block_ids(live.size, min(blocks, live.size))
            b = int(ev_rng.integers(ids.max() + 1))
            victims = live[ids == b]
            if live.size - victims.size >= min_workers and victims.size:
                event = ChurnEvent(step=t, kind="preempt_block",
                                   workers=tuple(victims))
        elif u < block_rate + preempt_rate:
            m = int(ev_rng.integers(1, preempt_max + 1))
            m = min(m, live.size - min_workers)
            if m > 0:
                victims = ev_rng.choice(live, size=m, replace=False)
                event = ChurnEvent(step=t, kind="preempt",
                                   workers=tuple(int(v) for v in victims))
        elif u < block_rate + preempt_rate + scaleup_rate:
            m = int(ev_rng.integers(1, scaleup_max + 1))
            m = min(m, n_max - live.size)
            if m > 0:
                event = ChurnEvent(step=t, kind="scale_up", count=m)
        if event is not None:
            events.append(event)
            live = scenario.apply_event(live, event)
    return ChurnScenario(trace=trace, events=tuple(events), speed=speed,
                         n0=n0)


def ingest_machine_events(path: Union[str, Path], *,
                          bin_seconds: float = 300.0,
                          latency_source: str = "bimodal",
                          min_workers: int = 2, seed: int = 0,
                          max_steps: Optional[int] = None,
                          **trace_kw) -> ChurnScenario:
    """Ingest a public machine-events cluster trace as a ChurnScenario.

    Accepts the Google ``clusterdata-2011`` ``machine_events`` CSV
    schema: ``timestamp_us, machine_id, event_type[, platform, cpus,
    mem]`` with event_type 0 = ADD, 1 = REMOVE, 2 = UPDATE (ignored),
    no header row ('#'-prefixed comment lines are skipped).  Machines
    present at the trace start (events at timestamp 0) form the initial
    fleet; later ADD/REMOVE events are binned into ``bin_seconds`` steps
    and replayed as scale-up / preemption events, so the ARRIVAL AND
    DEPARTURE PROCESS is the external cluster's own.  The public
    membership traces carry no per-step worker latencies, so the latency
    channel is synthesized from ``latency_source`` at full capacity;
    which live slot a removal hits is drawn from ``seed`` (machine
    identity across re-adds is not preserved — counts and timing are).
    """
    adds: dict = {}
    removes: dict = {}
    machines = set()
    t0 = None
    initial = set()
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",")
        ts, mid, etype = float(parts[0]), parts[1], int(parts[2])
        if etype == 2:
            continue
        machines.add(mid)
        if etype == 0 and ts <= 0:
            initial.add(mid)
            continue
        t0 = ts if t0 is None else min(t0, ts)
        (adds if etype == 0 else removes).setdefault(ts, []).append(mid)
    if not initial:
        raise ValueError(f"{path}: no initial fleet (ADD events at t=0)")
    n0 = len(initial)
    n_max = len(machines)
    usec = 1e6 * bin_seconds
    bins = sorted({int((ts - t0) // usec) + 1
                   for ts in list(adds) + list(removes)}) if t0 is not None \
        else []
    steps = (bins[-1] + 1) if bins else 1
    if max_steps is not None:
        steps = min(steps, int(max_steps))
    trace = make_trace(latency_source, steps=steps, n=n_max, seed=seed,
                       **trace_kw)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x1E]))
    scenario = ChurnScenario(trace=trace, n0=n0)
    live = scenario.initial_ids()
    events = []
    per_step: dict = {}
    for ts, mids in sorted(adds.items()):
        step = int((ts - t0) // usec) + 1
        per_step.setdefault(step, []).append(("add", len(mids)))
    for ts, mids in sorted(removes.items()):
        step = int((ts - t0) // usec) + 1
        per_step.setdefault(step, []).append(("remove", len(mids)))
    for step in sorted(per_step):
        if step >= steps:
            break
        for op, count in per_step[step]:
            if op == "remove":
                count = min(count, live.size - min_workers)
                if count <= 0:
                    continue
                victims = rng.choice(live, size=count, replace=False)
                event = ChurnEvent(step=step, kind="preempt",
                                   workers=tuple(int(v) for v in victims))
            else:
                count = min(count, n_max - live.size)
                if count <= 0:
                    continue
                event = ChurnEvent(step=step, kind="scale_up", count=count)
            events.append(event)
            live = scenario.apply_event(live, event)
    return ChurnScenario(trace=trace, events=tuple(events), n0=n0)
