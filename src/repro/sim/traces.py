"""LatencyTrace: the one latency abstraction behind ClusterSim.

A trace is a ``[steps, n]`` matrix of per-worker compute latencies for a
whole run — the co-simulation's ground truth.  Everything upstream of
the sync policy is a trace source:

  * the straggler models in ``runtime.straggler`` that own a real
    latency distribution (Pareto-tail deadline, bimodal slow-node)
    contribute their ``latencies(step, n)`` rows directly;
  * mask-only models (iid, fixed-fraction, pod-correlated, adversarial)
    are lifted to latencies by mapping straggler -> ``slow`` and
    non-straggler -> ``base`` — the two-point distribution their mask
    semantics already implies;
  * recorded cluster traces replay from JSON (``LatencyTrace.load``).

This unified the old ``runtime/latency.py`` (which sampled latencies
step by step; removed in PR 5) and ``runtime/straggler.py`` (which
samples masks) behind one API:
a trace is sampled once, then any sync policy in ``sim.cluster`` maps it
to per-step masks + step times, and the DecodeEngine decodes all the
masks in one batched call.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Optional, Union

import numpy as np

from ..runtime.straggler import StragglerModel, make_straggler_model

__all__ = ["LatencyTrace", "TraceCursor", "trace_from_model", "make_trace",
           "TRACE_SOURCES"]


@dataclasses.dataclass(frozen=True)
class LatencyTrace:
    """Per-worker latencies for a whole run: ``latencies[t, j]`` is the
    compute time of worker j at step t (seconds)."""

    latencies: np.ndarray          # [steps, n] float64
    source: str = "unknown"

    def __post_init__(self):
        lat = np.asarray(self.latencies, dtype=np.float64)
        if lat.ndim != 2:
            raise ValueError(f"trace must be [steps, n], got {lat.shape}")
        if lat.size and lat.min() < 0:
            raise ValueError("latencies must be non-negative")
        object.__setattr__(self, "latencies", lat)

    @property
    def steps(self) -> int:
        return int(self.latencies.shape[0])

    @property
    def n(self) -> int:
        return int(self.latencies.shape[1])

    def scaled(self, compute_scale: float) -> "LatencyTrace":
        """Rescale every latency (s coded tasks cost ~s/1 of the uncoded
        step — the paper's compute-overhead axis)."""
        return LatencyTrace(self.latencies * float(compute_scale),
                            source=self.source)

    def window(self, start: int, stop: Optional[int] = None) -> "LatencyTrace":
        return LatencyTrace(self.latencies[start:stop], source=self.source)

    def tile(self, steps: int) -> "LatencyTrace":
        """Repeat the trace to cover `steps` rows (replay longer runs)."""
        reps = -(-steps // self.steps)
        return LatencyTrace(np.tile(self.latencies, (reps, 1))[:steps],
                            source=self.source)

    # ---------------------------- JSON replay ----------------------------

    def to_json(self) -> str:
        return json.dumps({"source": self.source,
                           "latencies": self.latencies.tolist()})

    @classmethod
    def from_json(cls, text: str) -> "LatencyTrace":
        obj = json.loads(text)
        return cls(np.asarray(obj["latencies"], dtype=np.float64),
                   source=obj.get("source", "replay"))

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path: Union[str, Path]) -> "LatencyTrace":
        return cls.from_json(Path(path).read_text())


class TraceCursor:
    """Per-column replay cursor over a :class:`LatencyTrace`.

    The serving simulator treats column j as replica j's latency
    *stream*: each draw for a replica consumes that replica's next row
    (wrapping modulo ``steps``), independently of the other replicas.
    ``take`` is fully vectorized — a chunk of replica ids draws all its
    latencies in one call, with requests routed to the same replica
    consuming consecutive rows in request order.
    """

    def __init__(self, trace: LatencyTrace):
        if trace.steps == 0 or trace.n == 0:
            raise ValueError("cursor needs a non-empty trace")
        self.trace = trace
        self._pos = np.zeros(trace.n, dtype=np.int64)

    def take(self, replicas: np.ndarray) -> np.ndarray:
        """Next latency for each entry of ``replicas`` ([R] int)."""
        r = np.asarray(replicas, dtype=np.int64)
        if r.size == 0:
            return np.empty(0)
        if r.min() < 0 or r.max() >= self.trace.n:
            raise ValueError(f"replica ids out of range [0, {self.trace.n})")
        order = np.argsort(r, kind="stable")
        sr = r[order]
        # cumcount within each replica group (sr is sorted, so groups
        # are contiguous): entry i gets its replica's (pos + cumcount)th row
        starts = np.flatnonzero(np.r_[True, sr[1:] != sr[:-1]])
        sizes = np.diff(np.r_[starts, sr.size])
        cum = np.arange(sr.size) - np.repeat(starts, sizes)
        rows = (self._pos[sr] + cum) % self.trace.steps
        out = np.empty(r.size)
        out[order] = self.trace.latencies[rows, sr]
        uniq = sr[starts]
        self._pos[uniq] = (self._pos[uniq] + sizes) % self.trace.steps
        return out


def _has_latency_distribution(model: StragglerModel) -> bool:
    """True when the model overrides the base unit-latency stub."""
    return type(model).latencies is not StragglerModel.latencies


def trace_from_model(model: StragglerModel, steps: int, n: int, *,
                     base: float = 1.0, slow: float = 3.0) -> LatencyTrace:
    """Sample a [steps, n] trace from any straggler model.

    Models with a real latency distribution (DeadlineStragglers,
    BimodalStragglers) are sampled directly; mask-only models are lifted
    via straggler -> `slow`, non-straggler -> `base`.
    """
    lat = np.empty((steps, n))
    if _has_latency_distribution(model):
        for t in range(steps):
            lat[t] = model.latencies(t, n)
    else:
        for t in range(steps):
            lat[t] = np.where(model.sample(t, n), base, slow)
    return LatencyTrace(lat, source=type(model).__name__)


# sources with first-class latency semantics; anything accepted by
# make_straggler_model also works (lifted through the two-point map).
# 'clustered' is the block-correlated slow-episode source whose failing
# blocks align with the SBM code's worker clusters (core.codes.block_ids)
TRACE_SOURCES = ("pareto", "bimodal", "clustered", "correlated",
                 "adversarial", "iid", "fixed", "none", "replay")


def make_trace(source: str, steps: int = 0, n: int = 0, *,
               path: Optional[Union[str, Path]] = None,
               base: float = 1.0, slow: float = 3.0,
               **kw) -> LatencyTrace:
    """Trace factory: named straggler models plus JSON replay.

    'pareto' aliases the DeadlineStragglers Pareto-tail model; 'replay'
    loads `path` and tiles it to `steps` when steps > 0.
    """
    if source == "replay":
        if path is None:
            raise ValueError("replay trace needs path=")
        trace = LatencyTrace.load(path)
        return trace.tile(steps) if steps else trace
    if steps <= 0 or n <= 0:
        raise ValueError("generated traces need steps > 0 and n > 0")
    name = "deadline" if source == "pareto" else source
    model = make_straggler_model(name, **kw)
    return trace_from_model(model, steps, n, base=base, slow=slow)
