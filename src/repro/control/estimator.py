"""Online straggler-model estimation: what the cluster is actually doing.

The frontier sweep (E11) tells the user *after* a run which
`(s, decoder, deadline)` they should have picked; this module is the
observation half of closing that loop at runtime.  A
:class:`StragglerEstimator` ingests one `(mask, latencies)` observation
per step — from a :class:`~repro.sim.traces.LatencyTrace` row in
simulation, or from real per-worker step times in a live job — and
maintains:

  * **per-worker erasure rates** — exponentially weighted
    (bias-corrected, Adam-style) so a persistently slow node
    (`BimodalStragglers`) separates from iid noise within
    ~1/alpha steps;
  * **block-correlation score** — do erasures cluster by worker block
    (the shared :func:`~repro.core.codes.block_ids` partition the SBM
    code and the clustered trace source both use)?  +1 means stragglers
    always share a block (Charles & Papailiopoulos's regime, where
    cross-cluster replication wins), 0 means placement-independent;
  * **tail-latency quantiles and a sliding latency window** — so the
    controller can ask what-if questions: the erasure fraction and the
    expected step time any candidate deadline would have produced;
  * **realized decode error** — EW mean of the per-step decode error
    the trainer/simulator actually observed, used to calibrate the
    closed-form error bands of :mod:`repro.core.theory` online.

Everything is O(n) per step and a pure function of the observations,
so fused and distributed trainers fed identical masks derive identical
estimates (the SPMD no-communication property extends to the control
loop).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core.codes import block_ids

__all__ = ["EstimatorState", "StragglerEstimator"]


@dataclasses.dataclass(frozen=True)
class EstimatorState:
    """Snapshot the controller consumes; all fields bias-corrected."""

    steps: int  # observations ingested
    erasure: np.ndarray  # [n] per-worker EW erasure rate
    mean_erasure: float  # fleet-wide straggler fraction
    block_corr: float  # within-block erasure clustering, [-1, 1]
    err_ew: Optional[float]  # EW realized decode error / k (if fed)
    quantiles: Dict[float, float]  # latency quantiles over the window
    lat_rows: Optional[np.ndarray] = None  # [W, n] latency window view

    def latency_quantile(self, q: float, default: float = 1.5) -> float:
        """Interpolated latency quantile from the window (controller's
        deadline lookup); `default` when no latencies were observed."""
        if not self.quantiles:
            return default
        qs = sorted(self.quantiles)
        vs = [self.quantiles[x] for x in qs]
        return float(np.interp(q, qs, vs))

    def erasure_at(self, deadline: float) -> float:
        """Straggler fraction a given deadline would have produced over
        the window — the controller's what-if erasure lookup."""
        if self.lat_rows is None or not self.lat_rows.size:
            return self.mean_erasure
        return float((self.lat_rows > deadline).mean())

    def step_time_at(self, deadline: float) -> float:
        """Expected modelled step seconds under a candidate deadline:
        E[min(deadline, max_j latency_j)] over the window."""
        if self.lat_rows is None or not self.lat_rows.size:
            return float(deadline)
        return float(np.minimum(deadline, self.lat_rows.max(axis=1)).mean())


class StragglerEstimator:
    """EW straggler-model estimator over per-step (mask, latency) rows.

    ``alpha`` is the EW update weight (effective memory ~1/alpha steps);
    ``blocks`` the worker partition used for the correlation score
    (match the SBM code's ``blocks`` when adapting an SBM family);
    ``window`` the number of latency rows kept for quantiles.
    """

    # quantile grid kept in every state snapshot; the controller
    # interpolates between them for arbitrary (1 - delta) lookups
    QUANTS = (0.05, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99)

    def __init__(
        self,
        n: int,
        *,
        alpha: float = 0.1,
        blocks: int = 4,
        window: int = 64,
        err_alpha: Optional[float] = None,
    ):
        if n <= 0:
            raise ValueError(f"need n > 0, got {n}")
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha={alpha} must be in (0, 1]")
        self.n = n
        self.alpha = float(alpha)
        # realized decode errors spike with straggler episodes; smooth
        # them ~4x slower than the erasure rates so the controller's
        # calibration tracks the regime, not the episode
        if err_alpha is not None:
            self.err_alpha = float(err_alpha)
        else:
            self.err_alpha = self.alpha / 4.0
        self.blocks = max(1, min(int(blocks), n))
        self.window = max(1, int(window))
        self._member = block_ids(n, self.blocks)
        # expected within-block fraction of straggler pairs under
        # placement-independent erasures (the correlation score's zero)
        sizes = np.bincount(self._member, minlength=self.blocks)
        pairs_in = float((sizes * (sizes - 1)).sum())
        pairs_all = float(n * (n - 1))
        self._p_exp = pairs_in / pairs_all if pairs_all else 0.0
        self._steps = 0
        self._erasure = np.zeros(n)
        self._corr = 0.0
        self._corr_steps = 0  # steps with >= 2 stragglers observed
        self._err = 0.0
        self._err_steps = 0
        self._lat_rows: list = []  # ring buffer of [n] latency rows

    # ------------------------------------------------------------------

    def update(
        self,
        mask: np.ndarray,
        latencies: Optional[np.ndarray] = None,
        decode_err: Optional[float] = None,
    ) -> None:
        """Ingest one step: non-straggler mask, optional latency row and
        optional realized decode error (err / k)."""
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != (self.n,):
            raise ValueError(f"mask shape {mask.shape} != ({self.n},)")
        a = self.alpha
        self._steps += 1
        self._erasure += a * ((~mask).astype(np.float64) - self._erasure)
        stragglers = np.flatnonzero(~mask)
        if stragglers.size >= 2 and 0.0 < self._p_exp < 1.0:
            ids = self._member[stragglers]
            f = stragglers.size
            same = (ids[:, None] == ids[None, :]).sum() - f
            p_obs = same / float(f * (f - 1))
            score = (p_obs - self._p_exp) / (1.0 - self._p_exp)
            self._corr_steps += 1
            self._corr += a * (score - self._corr)
        if latencies is not None:
            lat = np.asarray(latencies, dtype=np.float64)
            if lat.shape != (self.n,):
                raise ValueError(f"latencies shape {lat.shape} != ({self.n},)")
            self._lat_rows.append(lat)
            if len(self._lat_rows) > self.window:
                self._lat_rows.pop(0)
        if decode_err is not None:
            self.update_error(decode_err)

    def update_error(self, decode_err: float) -> None:
        """Fold one realized decode error (err / k) into the EW mean.

        Separated from :meth:`update` because the batched simulation
        path decodes masks in chunks and feeds their errors back a few
        steps after the masks themselves (runner.py's feedback_every).
        """
        self._err_steps += 1
        self._err += self.err_alpha * (float(decode_err) - self._err)

    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of the raw (pre-debias) EW state,
        for checkpoint metadata — restoring it makes a resumed run's
        controller decisions identical to an uninterrupted one's."""
        return {
            "n": self.n,
            "alpha": self.alpha,
            "err_alpha": self.err_alpha,
            "blocks": self.blocks,
            "window": self.window,
            "steps": self._steps,
            "erasure": self._erasure.tolist(),
            "corr": self._corr,
            "corr_steps": self._corr_steps,
            "err": self._err,
            "err_steps": self._err_steps,
            "lat_rows": [row.tolist() for row in self._lat_rows],
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore a :meth:`state_dict` snapshot (rebuilds block
        membership if the checkpointed fleet size differs)."""
        n = int(state["n"])
        if n != self.n:
            self.__init__(
                n,
                alpha=float(state["alpha"]),
                blocks=int(state["blocks"]),
                window=int(state["window"]),
                err_alpha=float(state["err_alpha"]),
            )
        self.alpha = float(state["alpha"])
        self.err_alpha = float(state["err_alpha"])
        self.window = int(state["window"])
        self._steps = int(state["steps"])
        self._erasure = np.asarray(state["erasure"], dtype=np.float64)
        self._corr = float(state["corr"])
        self._corr_steps = int(state["corr_steps"])
        self._err = float(state["err"])
        self._err_steps = int(state["err_steps"])
        self._lat_rows = [
            np.asarray(row, dtype=np.float64) for row in state["lat_rows"]
        ]

    # ------------------------------------------------------------------

    def _debias(self, value, steps: int):
        """Adam-style bias correction for the zero-initialized EW mean."""
        if steps == 0:
            return value
        return value / (1.0 - (1.0 - self.alpha) ** steps)

    def state(self) -> EstimatorState:
        erasure = np.asarray(self._debias(self._erasure, self._steps))
        quants: Dict[float, float] = {}
        if self._lat_rows:
            flat = np.concatenate(self._lat_rows)
            for q in self.QUANTS:
                quants[q] = float(np.quantile(flat, q))
        err_ew = None
        if self._err_steps:
            err_ew = self._err / (1.0 - (1.0 - self.err_alpha) ** self._err_steps)
        mean_erasure = float(erasure.mean()) if self._steps else 0.0
        lat_rows = np.asarray(self._lat_rows) if self._lat_rows else None
        return EstimatorState(
            steps=self._steps,
            erasure=erasure,
            mean_erasure=mean_erasure,
            block_corr=float(self._debias(self._corr, self._corr_steps)),
            err_ew=err_ew,
            quantiles=quants,
            lat_rows=lat_rows,
        )
