"""AdaptiveCoder: the closed loop, wired into training and simulation.

Two consumers share the estimator + policy pair:

  * :class:`AdaptiveCoder` — the controller object
    ``training.train_loop.CodedTrainer`` accepts as ``controller=``.
    The trainer feeds it one ``observe(step, mask, latencies,
    decode_err)`` per step and asks ``decide(step)`` at the top of the
    next one; a returned re-code action makes the trainer rebuild code,
    assignment, pipeline, engine AND step_fn through the same path the
    elastic-fault machinery uses (so ``dist_mode="coded_allreduce"``
    partitions can never go stale).  The controller is a pure function
    of its observations, so fused and distributed trainers fed the same
    masks take identical action sequences — the basis of the fp64
    re-code parity test in tests/test_coded_allreduce.py.
  * :func:`run_adaptive_sim` — the ClusterSim-shaped co-simulation with
    the controller in the loop, contributing the ``adaptive_coder``
    policy column to the E11 frontier
    (:func:`repro.sim.frontier.sweep_adaptive`).  Decoding stays
    batched: masks accumulate and are decoded in control-interval
    chunks (every ``feedback_every`` steps and at re-code boundaries),
    each chunk ONE ``DecodeEngine.decode_batch`` call whose realized
    errors feed the estimator's calibration — ~S/feedback_every batched
    calls per run, never a per-step decode loop.

Compute model: a worker computing s coded tasks spends ~s/s_ref of the
reference step time (the trace is calibrated at ``s_ref``), so lowering
s is a real wall-clock win and raising it a real cost — without this
the controller would trivially max out redundancy.  Scaling is uniform
across workers, so the straggler SET is scale-invariant: masks and the
deadline live in reference-trace units and only the realized step time
is multiplied by s/s_ref.  Static frontier cells all run at s_ref
(scale 1), which keeps the comparison fair.

``ScriptedController`` drives the same trainer hooks from an explicit
{step: Action} plan — the deterministic re-code injector the
differential tests use.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core import registry
from ..core.engine import DecodeEngine
from .estimator import StragglerEstimator
from .policy import Action, AdaptivePolicy, ControlConfig

__all__ = [
    "AdaptiveCoder",
    "ScriptedController",
    "AdaptiveRunResult",
    "run_adaptive_sim",
    "adaptive_frontier_point",
]


class AdaptiveCoder:
    """Estimator + policy bundle implementing the trainer's controller
    protocol (``observe`` / ``decide``).

    ``blocks`` defaults to the sbm family default (4) — pass the code's
    actual block count when adapting an SBM variant so the correlation
    score aligns with the real clusters.
    """

    def __init__(
        self,
        family: str,
        n: int,
        cfg: Optional[ControlConfig] = None,
        *,
        s: int,
        decoder: str = "onestep",
        deadline: float = 1.5,
        blocks: int = 4,
    ):
        self.cfg = cfg if cfg is not None else ControlConfig()
        self.family = registry.get(family)
        self.family.require_decoder(decoder)
        self.n = int(n)
        self.blocks = blocks
        self.estimator = StragglerEstimator(
            self.n, alpha=self.cfg.ew_alpha, blocks=blocks
        )
        self.policy = AdaptivePolicy(
            self.family,
            self.n,
            self.n,
            self.cfg,
            s=s,
            decoder=decoder,
            deadline=deadline,
        )

    # -- current operating point (what the policy believes is applied) --

    @property
    def s(self) -> int:
        return self.policy.s

    @property
    def decoder(self) -> str:
        return self.policy.decoder

    @property
    def deadline(self) -> float:
        return self.policy.deadline

    @property
    def recodes(self) -> int:
        recode_kinds = ("set_s", "set_decoder")
        return sum(1 for _, a in self.policy.actions if a.kind in recode_kinds)

    def _resize(self, n: int) -> None:
        """Elastic shrink: rebuild the estimator/ladder for n' workers
        (erasure history restarts — the fleet changed under us)."""
        self.n = n
        self.estimator = StragglerEstimator(
            n, alpha=self.cfg.ew_alpha, blocks=self.blocks
        )
        self.policy = AdaptivePolicy(
            self.family,
            n,
            n,
            self.cfg,
            s=min(self.policy.s, n),
            decoder=self.policy.decoder,
            deadline=self.policy.deadline,
        )

    # ------------------- checkpoint serialization -------------------

    def state_dict(self) -> dict:
        """JSON-serializable controller state for checkpoint metadata:
        estimator EW history plus the policy's operating point and
        hysteresis clocks, so a restored controller replays the exact
        decision sequence an uninterrupted run would have taken."""
        return {
            "kind": "adaptive_coder",
            "n": self.n,
            "blocks": self.blocks,
            "estimator": self.estimator.state_dict(),
            "policy": {
                "s": self.policy.s,
                "decoder": self.policy.decoder,
                "deadline": self.policy.deadline,
                "last_recode": self.policy._last_recode,
                "last_deadline": self.policy._last_deadline,
                "calib": dict(self.policy._calib),
            },
        }

    def load_state_dict(self, state: dict) -> None:
        n = int(state["n"])
        if n != self.n:
            self._resize(n)
        self.estimator.load_state_dict(state["estimator"])
        pol = state["policy"]
        self.policy.s = int(pol["s"])
        self.policy.decoder = str(pol["decoder"])
        self.policy.deadline = float(pol["deadline"])
        self.policy._last_recode = int(pol["last_recode"])
        self.policy._last_deadline = int(pol["last_deadline"])
        self.policy._calib.update({str(k): float(v) for k, v in pol["calib"].items()})
        if self.policy.s not in self.policy._ladder:
            self.policy._ladder = tuple(
                sorted(set(self.policy._ladder) | {self.policy.s})
            )

    # -------------------- the trainer protocol --------------------

    def observe(
        self,
        step: int,
        mask: np.ndarray,
        latencies: Optional[np.ndarray] = None,
        decode_err: Optional[float] = None,
    ) -> None:
        del step
        mask = np.asarray(mask, dtype=bool)
        if mask.shape[0] != self.n:
            self._resize(mask.shape[0])
        self.estimator.update(mask, latencies=latencies, decode_err=decode_err)

    def decide(self, step: int) -> Optional[Action]:
        return self.policy.decide(step, self.estimator.state())

    def feed_errors(self, errors) -> None:
        """Fold a chunk of realized decode errors (err / k each) into
        the estimator — the batched-decode feedback path."""
        for e in np.asarray(errors, dtype=np.float64).ravel():
            self.estimator.update_error(float(e))


class ScriptedController:
    """Deterministic {step: Action} plan with the AdaptiveCoder
    protocol — the tests' re-code injector (e.g. force ``set_s`` at a
    known step and prove fused == dist metric parity across it)."""

    def __init__(self, plan: Dict[int, Action]):
        self.plan = dict(plan)
        self.actions: list = []

    def observe(self, step: int, mask, latencies=None, decode_err=None) -> None:
        pass

    def decide(self, step: int) -> Optional[Action]:
        action = self.plan.get(step)
        if action is not None:
            self.actions.append((step, action))
        return action

    def state_dict(self) -> dict:
        # the plan is pure in `step`; only the applied-action log is state
        return {
            "kind": "scripted",
            "actions": [[t, dataclasses.asdict(a)] for t, a in self.actions],
        }

    def load_state_dict(self, state: dict) -> None:
        self.actions = [(int(t), Action(**a)) for t, a in state.get("actions", [])]


# --------------------------------------------------------------------------
# the co-simulation with the controller in the loop
# --------------------------------------------------------------------------


@dataclasses.dataclass
class AdaptiveRunResult:
    """ClusterRunResult-shaped summary plus the control trajectory."""

    scheme: str
    step_times: np.ndarray  # [S] modelled seconds (s-scaled)
    masks: np.ndarray  # [S, n]
    errors: np.ndarray  # [S] decode err / k
    s_traj: np.ndarray  # [S] replication factor per step
    deadlines: np.ndarray  # [S]
    decoder_traj: list  # [S] decoder names
    recodes: int  # segment boundaries crossed
    batch_calls: int  # ~ S/feedback_every + recodes
    policy: str = "adaptive_coder"
    decoder: str = "auto"

    @property
    def total_time(self) -> float:
        return float(self.step_times.sum())

    @property
    def mean_step_time(self) -> float:
        return float(self.step_times.mean())

    @property
    def mean_error(self) -> float:
        return float(self.errors.mean())

    @property
    def mean_stragglers(self) -> float:
        return float((~self.masks).sum(axis=1).mean())


def run_adaptive_sim(
    scheme: str,
    trace,
    cfg: Optional[ControlConfig] = None,
    *,
    s: int,
    s_ref: Optional[int] = None,
    decoder: str = "onestep",
    deadline: float = 1.5,
    seed: int = 0,
    backend: str = "numpy",
    blocks: int = 4,
    feedback_every: int = 10,
) -> AdaptiveRunResult:
    """Run the AdaptiveCoder over a LatencyTrace.

    Decoding is batched in control-interval chunks: accumulated masks
    are decoded every ``feedback_every`` steps (and at every re-code
    boundary) in one ``decode_batch`` call each, and the realized
    errors are fed back to the estimator so the policy's calibration
    engages — ~S / feedback_every batched calls per run, never a
    per-step decode.  ``s_ref`` is the replication the trace's
    latencies are calibrated at (defaults to the starting ``s``); step
    times scale by the live s / s_ref.
    """
    cfg = cfg if cfg is not None else ControlConfig()
    n = trace.n
    s_ref = s if s_ref is None else s_ref
    rng = np.random.default_rng(seed)
    fam = registry.get(scheme)
    coder = AdaptiveCoder(
        scheme, n, cfg, s=s, decoder=decoder, deadline=deadline, blocks=blocks
    )
    code = fam.make(k=n, n=n, s=s, rng=rng)
    engine = DecodeEngine(code, backend=backend, s=s)

    S = trace.steps
    masks = np.empty((S, n), dtype=bool)
    times = np.empty(S)
    errors = np.empty(S)
    s_traj = np.empty(S, dtype=np.int64)
    deadlines = np.empty(S)
    decoder_traj: list = []
    done = 0  # masks[:done] decoded + fed back
    recodes = 0
    batch_calls = 0
    decoder_now = decoder

    def flush(stop: int) -> None:
        nonlocal done, batch_calls
        if stop > done:
            errs = engine.errors_batch(masks[done:stop], decoder_now)
            errors[done:stop] = errs / code.k
            coder.feed_errors(errors[done:stop])
            done = stop
            batch_calls += 1

    for t in range(S):
        if t - done >= feedback_every:
            flush(t)
        action = coder.decide(t)
        if action is not None and action.kind in ("set_s", "set_decoder"):
            flush(t)
            recodes += 1
            if action.kind == "set_s":
                code = fam.make(k=n, n=n, s=coder.s, rng=rng)
                engine = DecodeEngine(code, backend=backend, s=coder.s)
            decoder_now = coder.decoder
        lat = trace.latencies[t]  # reference-trace units
        d = coder.deadline
        scale = coder.s / s_ref  # uniform compute scaling
        masks[t] = lat <= d
        times[t] = min(d, float(lat.max())) * scale
        s_traj[t] = coder.s
        deadlines[t] = d
        decoder_traj.append(decoder_now)
        coder.observe(t, masks[t], latencies=lat)
    flush(S)

    return AdaptiveRunResult(
        scheme=scheme,
        step_times=times,
        masks=masks,
        errors=errors,
        s_traj=s_traj,
        deadlines=deadlines,
        decoder_traj=decoder_traj,
        recodes=recodes,
        batch_calls=batch_calls,
    )


def adaptive_frontier_point(
    scheme: str,
    trace,
    *,
    s: int,
    error_budget: float = 0.05,
    cfg: Optional[ControlConfig] = None,
    seed: int = 0,
    max_inflation: float = 100.0,
):
    """One E11 frontier point for the adaptive policy (lazy frontier
    import keeps sim.frontier free of a control dependency cycle)."""
    from ..sim.frontier import FrontierPoint, time_to_target_error

    if cfg is None:
        cfg = ControlConfig(error_budget=error_budget)
    res = run_adaptive_sim(scheme, trace, cfg, s=s, seed=seed)
    return FrontierPoint(
        scheme=scheme,
        policy=res.policy,
        decoder=res.decoder,
        total_time=res.total_time,
        mean_step_time=res.mean_step_time,
        mean_error=res.mean_error,
        mean_stragglers=res.mean_stragglers,
        # AdaptiveRunResult exposes the same total_time/mean_error
        # surface, so the CANONICAL inflation clip applies verbatim
        time_to_target=time_to_target_error(res, max_inflation),
    )
