"""AdaptiveCoder: online straggler estimation + dynamic redundancy
control (docs/adaptive.md).

Public surface:

  * ``StragglerEstimator`` / ``EstimatorState`` — EW per-worker erasure
    rates, block-correlation score, tail-latency quantiles, realized
    decode error (estimator.py);
  * ``ControlConfig`` / ``Action`` / ``AdaptivePolicy`` / ``error_band``
    — the error-budget controller with hysteresis over the three action
    kinds set_s / set_decoder / set_deadline (policy.py);
  * ``AdaptiveCoder`` / ``ScriptedController`` — the controller
    protocol ``CodedTrainer(controller=...)`` consumes, and
    ``run_adaptive_sim`` / ``adaptive_frontier_point`` — the
    co-simulation loop behind E11's ``adaptive_coder`` policy column
    (runner.py).
"""

from .estimator import EstimatorState, StragglerEstimator  # noqa: F401
from .policy import (  # noqa: F401
    Action,
    AdaptivePolicy,
    ControlConfig,
    error_band,
)
from .runner import (  # noqa: F401
    AdaptiveCoder,
    AdaptiveRunResult,
    ScriptedController,
    adaptive_frontier_point,
    run_adaptive_sim,
)
