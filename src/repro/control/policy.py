"""Redundancy controller: estimator state + error budget -> actions.

The decision half of the AdaptiveCoder loop (docs/adaptive.md).  The
controller's objective is the E11 frontier's own scalar — modelled
time-to-target, ``E[step time] * s / (1 - err)`` — minimized subject to
the user *error budget* (mean decode err / k, the 1/(1-e)
convergence-penalty currency of ``sim.frontier``).  Every decision
epoch it enumerates candidate operating points

    (s in the registry's legal_s ladder)
  x (decoder in the family's declared onestep/optimal)
  x (deadline on the observed latency-quantile grid)

prices each with the calibrated error band and the estimator's
window-based what-if lookups (``erasure_at`` / ``step_time_at``), and
moves ONE coordinate toward the argmin per action.  Three action kinds
come out:

  * ``set_deadline`` — the PR-2 adaptive-deadline controller wrapped as
    an action: the deadline component of the argmin, ignored inside a
    relative ``deadline_deadband``.
  * ``set_decoder`` — onestep <-> optimal (least-squares never has
    larger error than one-step on the same mask, so a blown budget
    escalates decoder first: it costs no extra worker compute).
  * ``set_s`` — raise/lower replication one rung of the legal-s ladder
    (the elastic-rebuild path of ``GradientCode.with_workers`` /
    ``CodedTrainConfig.code_params`` keeps family variants intact).
    Worker compute scales ~ s, so the objective charges candidates
    linearly in s.

Hysteresis, so the controller cannot thrash: re-code actions respect a
``cooldown`` (min steps between them), a candidate must beat the
current point by ``improve_margin`` before any move happens, deadline
moves inside the deadband are ignored, running over budget is a soft
constraint (quadratic overspend penalty on the live point, so a
marginal breach nudges rather than flips), and block-correlated
erasures (the estimator's ``block_corr`` score) inflate candidate
error predictions — an alternating trace whose EW-smoothed estimates
sit inside the margins produces no actions at all.

The prediction model is the paper's closed forms
(:mod:`repro.core.theory`) plus the uncovered-task estimate for
least-squares decoding, with an online per-decoder multiplicative
calibration: ``predict = c[decoder] * band(k, s, delta, decoder)``
where ``c`` tracks realized-vs-band on the live operating point, so a
loose bound still ranks candidate configs correctly.

Since PR 10 the calibrated estimate is clamped by *certified* bounds
(docs/adaptive.md §2): the Wang et al. fundamental lower bound floors
every candidate band (no decoder on any code can beat it, so admission
can never ride a too-optimistic calibration below the information-
theoretic limit), and the spectral-gap certificate of
:mod:`repro.core.certify` caps it from above when informative.  A
candidate whose spectral certificate alone fits the error budget —
a worst-case, every-adversarial-mask guarantee, not an expectation —
is admitted with ``certified=True``, surfaced on the emitted
:class:`Action` and thus in the ``actions`` history.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core import certify as certify_lib
from ..core import theory
from ..core.registry import CodeFamily
from .estimator import EstimatorState

__all__ = ["Action", "ControlConfig", "AdaptivePolicy", "error_band"]


@dataclasses.dataclass(frozen=True)
class Action:
    """One controller decision; ``value`` is the new s / decoder name /
    deadline seconds depending on ``kind``.  ``certified`` records
    whether the admitted operating point's spectral certificate alone
    (worst-case over adversarial straggler sets — core.certify) fits
    the error budget; False means admission leaned on the calibrated
    estimate."""

    kind: str  # "set_s" | "set_decoder" | "set_deadline"
    value: object
    reason: str = ""
    certified: bool = False

    KINDS = ("set_s", "set_decoder", "set_deadline")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"action kind {self.kind!r} not in {self.KINDS}")


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """User surface of the AdaptiveCoder (docs/adaptive.md)."""

    error_budget: float = 0.05  # mean decode err / k to steer under
    improve_margin: float = 0.05  # min predicted ttt gain before moving
    cooldown: int = 25  # min steps between re-code actions
    warmup: int = 10  # observations before the first action
    deadline_every: int = 5  # min steps between deadline actions
    deadline_deadband: float = 0.1  # ignore < 10% relative deadline moves
    s_min: Optional[int] = None  # clamp on the legal_s search range
    s_max: Optional[int] = None
    ew_alpha: float = 0.1  # estimator memory (threaded by runner)

    def __post_init__(self):
        if self.error_budget <= 0:
            raise ValueError(f"error_budget={self.error_budget} must be > 0")
        if not (0.0 < self.improve_margin < 1.0):
            raise ValueError(
                f"improve_margin={self.improve_margin} must be in (0, 1)"
            )


def error_band(family: str, k: int, s: int, delta: float, decoder: str) -> float:
    """Predicted mean decode error / k at straggler fraction ``delta``.

    One-step decoding uses the paper's closed forms: Theorem 5 (exact
    finite-k version) for FRC, the exact Bernoulli E[err_1] for the
    random families.  Optimal decoding has no closed form outside FRC
    (Theorem 6), so the random families use the *uncovered-task*
    estimate — a task whose every replica straggles contributes ~1 to
    the least-squares error, and in the small-error regime uncovered
    tasks dominate it:

      * Bernoulli support (bgc / rbgc / sbm):
        P(task uncovered) = (1 - (1-delta) * s/k)^n;
      * (near-)regular row degree ns/k (expander / sregular / cyclic):
        P(task uncovered) ~= delta^(ns/k).

    Returns error already divided by k.  The policy multiplies this by
    an online calibration factor, so systematic looseness cancels; the
    band only has to *rank* candidate (s, decoder) pairs correctly.
    """
    delta = float(min(max(delta, 0.0), 0.95))
    r = max(int(round((1.0 - delta) * k)), 0)
    if r == 0:
        return 1.0
    if family == "uncoded":
        return delta
    if family == "frc" and k % s == 0:
        if decoder == "optimal":
            return theory.thm6_expected_err_frc(k, s, r) / k
        return max(theory.thm5_expected_err1_frc_exact(k, s, r), 0.0) / k
    if decoder == "optimal":
        if family in ("expander", "sregular", "cyclic"):
            row_deg = max(int(round(s)), 1)  # n = k row degree ~= s
            return float(delta**row_deg)
        # the stack runs square codes (k == n workers), so k is the
        # exponent's worker count
        return float((1.0 - (1.0 - delta) * s / k) ** k)
    return max(theory.expected_err1_bgc_exact(k, s, r), 0.0) / k


class AdaptivePolicy:
    """Maps estimator snapshots to actions for one live (family, k, n).

    Tracks the current operating point ``(s, decoder, deadline)`` — the
    caller confirms application implicitly: a returned action is assumed
    applied (the runner/trainer always applies it), which is what makes
    fused and distributed trainers fed identical observations take
    identical action sequences.
    """

    # calibration clip: wide because the uncovered-task band is a
    # small-error estimate the realized least-squares error can exceed
    # by orders of magnitude in the mid-delta regime
    CALIB_LO, CALIB_HI = 0.05, 1e3

    # candidate admission uses a safety factor under the budget while
    # the live point is only invalidated ABOVE the budget — the
    # hysteresis band that keeps spiky realized errors from flip-
    # flopping the operating point
    SAFETY = 0.8

    def __init__(
        self,
        family: CodeFamily,
        k: int,
        n: int,
        cfg: ControlConfig,
        *,
        s: int,
        decoder: str = "onestep",
        deadline: float = 1.5,
    ):
        self.family = family
        self.k, self.n = int(k), int(n)
        self.cfg = cfg
        self.s = int(s)
        self.decoder = decoder
        self.deadline = float(deadline)
        lo = cfg.s_min if cfg.s_min is not None else 1
        hi = cfg.s_max if cfg.s_max is not None else min(k, 4 * self.s)
        self._ladder: Tuple[int, ...] = family.legal_s(k, n, lo=lo, hi=hi)
        if self.s not in self._ladder:
            self._ladder = tuple(sorted(set(self._ladder) | {self.s}))
        decoders = [
            d for d in ("onestep", "optimal") if family.supports_decoder(d)
        ]
        self._decoders = tuple(decoders) or (decoder,)
        self._last_recode = -(10**9)
        self._last_deadline = -(10**9)
        # per-decoder realized-vs-band calibration (see module doc)
        self._calib = {d: 1.0 for d in self._decoders}
        self._calib.setdefault(decoder, 1.0)
        self.actions: list = []  # applied-action log of (step, Action)

    # ------------------------------------------------------------------
    # prediction model
    # ------------------------------------------------------------------

    def _band(self, s: int, delta: float, dec: str, guard: float = 1.0) -> float:
        return self._banded(s, delta, dec, guard)[0]

    def _lb_frac(self, s: int, delta: float) -> float:
        """Fundamental lower bound on err/k (Wang et al.) — no decoder
        on any code of sparsity s can do better in expectation."""
        delta = float(min(max(delta, 0.0), 1.0))
        r = max(0, min(self.n, int(round((1.0 - delta) * self.n))))
        return theory.fundamental_err_lower_bound(self.k, s, r, self.n) / self.k

    def _cert_frac(self, s: int, delta: float) -> Optional[float]:
        """Spectral-certificate err/k upper bound (None when the family
        can't be certified at this point or the bound is vacuous).
        Cached per (family, k, n, s) inside core.certify; for the
        randomized families this certifies a pinned representative
        draw (docs/adaptive.md §2)."""
        delta = float(min(max(delta, 0.0), 0.95))
        return certify_lib.certified_err_frac(
            self.family.name, self.k, self.n, s, delta
        )

    def _banded(
        self, s: int, delta: float, dec: str, guard: float = 1.0
    ) -> Tuple[float, bool]:
        """(band, certified): the calibrated estimate clamped into the
        certified corridor [fundamental LB, spectral UB].  The guard
        (block-correlation inflation) applies to the calibrated term
        only — the spectral certificate is already worst-case over
        every mask, correlated or not.  ``certified`` is True when the
        spectral certificate alone fits the full error budget."""
        c = self._calib.get(dec, 1.0)
        calib = guard * c * error_band(self.family.name, self.k, s, delta, dec)
        lb = self._lb_frac(s, delta)
        ub = self._cert_frac(s, delta)
        band = max(calib, lb)
        if ub is not None:
            band = max(lb, min(band, ub))
        certified = ub is not None and ub <= self.cfg.error_budget
        return band, certified

    def _calibrate(self, est: EstimatorState) -> None:
        """Track realized / band on the live operating point."""
        if est.err_ew is None:
            return
        band = error_band(
            self.family.name, self.k, self.s, est.mean_erasure, self.decoder
        )
        if band > 1e-12:
            ratio = est.err_ew / band
            self._calib[self.decoder] = float(
                np.clip(ratio, self.CALIB_LO, self.CALIB_HI)
            )

    def _candidates(self, est: EstimatorState):
        """(ttt, s, decoder, deadline, certified) over the ladder x
        decoders x the observed latency-quantile grid; onestep
        enumerated first so exact ties prefer the cheaper decoder."""
        if est.lat_rows is not None:
            quantile_grid = (0.5, 0.75, 0.9, 0.95, 0.99)
            grid = sorted(
                {round(est.latency_quantile(q), 12) for q in quantile_grid}
                | {self.deadline}
            )
        else:
            grid = [self.deadline]
        corr = float(min(max(est.block_corr, 0.0), 1.0))
        guard = 1.0 + corr
        budget = self.SAFETY * self.cfg.error_budget
        out = []
        for dec in self._decoders:
            for d in grid:
                delta = est.erasure_at(d)
                b_now = self._band(self.s, delta, dec, guard)
                for s in self._ladder:
                    e, cert = self._banded(s, delta, dec, guard)
                    if s > self.s and corr > 0.0 and e > 0.0 and b_now > 0.0:
                        # block-correlated erasures kill a task's
                        # same-block replicas together, so raising s
                        # buys less than the independence band claims:
                        # flatten the promised gain by the observed
                        # correlation (one-sided — s-down keeps the
                        # full pessimistic sensitivity)
                        e = e ** (1.0 - corr) * b_now**corr
                    if e > budget:
                        continue
                    ttt = est.step_time_at(d) * s / (1.0 - min(e, 0.99))
                    out.append((ttt, s, dec, d, cert))
        return out

    def _step_s(self, direction: int) -> Optional[int]:
        """Next rung of the legal-s ladder above (+1) / below (-1)."""
        if direction > 0:
            ups = [x for x in self._ladder if x > self.s]
            return min(ups) if ups else None
        downs = [x for x in self._ladder if x < self.s]
        return max(downs) if downs else None

    # ------------------------------------------------------------------
    # the decision rule
    # ------------------------------------------------------------------

    def decide(self, step: int, est: EstimatorState) -> Optional[Action]:
        """One decision per call; a returned action is considered
        applied (updates the tracked operating point + cooldowns)."""
        cfg = self.cfg
        if est.steps < cfg.warmup:
            return None
        self._calibrate(est)
        delta = est.erasure_at(self.deadline)
        if est.err_ew is not None:
            err_now = est.err_ew
        else:
            err_now = self._band(self.s, delta, self.decoder)
        over = err_now > cfg.error_budget

        cands = self._candidates(est)
        if not cands:
            # nothing predicted-safe anywhere on the grid: escalate
            # redundancy as the last resort (decoder first — free)
            if over and step - self._last_recode >= cfg.cooldown:
                if self.decoder != "optimal" and "optimal" in self._decoders:
                    reason = (
                        f"err {err_now:.4f} > budget; no safe candidate, "
                        f"escalating decoder"
                    )
                    action = Action("set_decoder", "optimal", reason)
                    return self._apply(step, action)
                s_up = self._step_s(+1)
                if s_up is not None:
                    reason = (
                        f"err {err_now:.4f} > budget; no safe candidate, "
                        f"escalating s"
                    )
                    return self._apply(step, Action("set_s", s_up, reason))
            return None
        best = min(cands)
        # the live point, priced with its REALIZED error; running over
        # budget is a soft constraint — quadratic overspend penalty, so
        # a marginal breach doesn't thrash but a real one forces a move
        err_clip = min(err_now, 0.99)
        ttt_now = est.step_time_at(self.deadline) * self.s / (1.0 - err_clip)
        if over:
            ttt_now *= (err_now / cfg.error_budget) ** 2
        if best[0] >= (1.0 - cfg.improve_margin) * ttt_now:
            return None  # not enough predicted gain: hold still
        _, s_c, dec_c, d_c, cert_c = best
        d_move = abs(d_c / max(self.deadline, 1e-9) - 1.0)
        if d_move > cfg.deadline_deadband:
            if step - self._last_deadline >= cfg.deadline_every:
                reason = f"quantile argmin (delta~{est.erasure_at(d_c):.3f})"
                action = Action("set_deadline", float(d_c), reason, certified=cert_c)
                return self._apply(step, action)
        if step - self._last_recode < cfg.cooldown:
            return None
        if dec_c != self.decoder:
            action = Action(
                "set_decoder", dec_c, "ttt argmin decoder", certified=cert_c
            )
            return self._apply(step, action)
        if s_c != self.s:
            rung = self._step_s(+1 if s_c > self.s else -1)
            if rung is not None:
                reason = f"toward ttt argmin s={s_c}"
                action = Action("set_s", rung, reason, certified=cert_c)
                return self._apply(step, action)
        return None

    def _apply(self, step: int, action: Action) -> Action:
        if action.kind == "set_s":
            self.s = int(action.value)
            self._last_recode = step
        elif action.kind == "set_decoder":
            self.decoder = str(action.value)
            self._last_recode = step
        else:
            self.deadline = float(action.value)
            self._last_deadline = step
        self.actions.append((step, action))
        return action
