"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must not
touch jax device state.  The 'pod' axis is the scale-out dimension: a
1000+-node deployment is (pods, data, model) with identical code because
every collective in the framework is expressed over named mesh axes.
"""

from __future__ import annotations

import math

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_debug_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — call "
            f"repro.platform.host_devices(512) before jax initializes "
            f"(dryrun.py does this automatically)")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:need])
    except TypeError:  # older make_mesh without devices kwarg
        return jax.sharding.Mesh(
            np.asarray(devs[:need]).reshape(shape), axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU multi-device tests (device count forced by the
    calling test via repro.platform in a subprocess)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()[:need]
    return jax.sharding.Mesh(np.asarray(devs).reshape(shape), axes)
