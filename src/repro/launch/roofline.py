"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_flops
    memory     = HLO_bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / link_bw

The peak/bandwidth constants come from ``repro.platform.HARDWARE`` —
``roofline_terms(hardware=...)`` takes a spec, a HARDWARE key
("tpu-v5e", "gpu-a100", "cpu", ...), or a platform name.  The default
is still the TPU-v5e target the dry-run pipeline models, but the
estimate is no longer silent about it: when jax is initialized on a
*different* backend the call warns (or raises with strict=True),
naming both the assumed hardware and the live backend.

`compiled.cost_analysis()` / `lowered/compiled.as_text()` describe the
per-device SPMD module, so no extra division by chip count is needed.

Two structural corrections documented in docs/architecture.md §6:
 * XLA counts a scan (`while`) body ONCE -> we lower small *unrolled*
   depth variants (L = p and 2p pattern groups) and extrapolate the
   per-layer slope to the full depth;
 * collective bytes are not in cost_analysis -> we parse the
   post-partitioning HLO text and sum operand bytes of all-gather /
   all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Dict, Optional, Tuple, Union

from repro.platform import (HARDWARE, HardwareSpec, resolve_hardware,
                            runtime_platform)

# the hardware the dry-run pipeline models by default; the historical
# module constants stay as back-compat aliases of the preset
_DEFAULT_HW = HARDWARE["tpu-v5e"]
PEAK_FLOPS = _DEFAULT_HW.peak_flops   # bf16 FLOP/s per chip
HBM_BW = _DEFAULT_HW.hbm_bw           # B/s per chip
LINK_BW = _DEFAULT_HW.link_bw         # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[4,128]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    in_loop_bytes: int           # bytes on ops inside while-bodies (flagged:
                                 # these are counted once; extrapolation
                                 # handles depth, inner loops are the caveat)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in an HLO module.

    For all-reduce the output size equals the contribution per device; for
    all-gather it is the gathered size — both are the right per-device
    wire-byte proxies for a ring implementation within a constant factor.
    """
    bytes_by_kind: Dict[str, int] = {}
    count_by_kind: Dict[str, int] = {}
    in_loop = 0

    # identify computations used as while bodies/conditions
    loop_comps = set(re.findall(r"(?:body|condition)=%?([\w.\-]+)", hlo_text))

    current_comp = None
    for line in hlo_text.splitlines():
        header = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if header and line.rstrip().endswith("{"):
            current_comp = header.group(1)
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # ops look like: %ar = f32[...] all-reduce(...), replica_groups=...
            if re.search(rf"=\s*[\w\[\],{{}}\s]*\b{kind}(?:-start|-done)?\(",
                         stripped):
                if kind + "-done" in stripped:
                    continue  # avoid double counting start/done pairs
                lhs = stripped.split("=", 1)[1]
                b = _shape_bytes(lhs.split(f"{kind}", 1)[0])
                bytes_by_kind[kind] = bytes_by_kind.get(kind, 0) + b
                count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
                if current_comp in loop_comps:
                    in_loop += b
                break
    return CollectiveStats(bytes_by_kind, count_by_kind, in_loop)


def cost_terms(cost: dict) -> Tuple[float, float]:
    """(flops, bytes_accessed) from compiled.cost_analysis()."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    if bytes_accessed == 0.0:
        bytes_accessed = sum(v for k, v in cost.items()
                             if k.startswith("bytes accessed"))
    return flops, bytes_accessed


def linear_extrapolate(v_small: float, v_big: float, layers_small: int,
                       layers_big: int, layers_full: int) -> float:
    """v(L) = base + slope*L fitted on two depths, evaluated at full depth."""
    slope = (v_big - v_small) / max(layers_big - layers_small, 1)
    base = v_small - slope * layers_small
    return base + slope * layers_full


def _check_hardware_matches(hw: HardwareSpec, strict: bool) -> None:
    """Warn/raise when estimating for one backend while running another.

    Only consulted when jax has already initialized — querying devices
    here must never *trigger* backend startup (roofline is static
    analysis and runs fine on a GPU-less CI host modeling a TPU pod).
    """
    live = runtime_platform()
    if live is None or live == hw.platform:
        return
    msg = (f"roofline estimate uses the {hw.name!r} hardware preset "
           f"({hw.platform}), but jax is running on the {live!r} backend — "
           f"the seconds/fractions model the preset, not this machine. "
           f"Pass hardware={live!r} (or a repro.platform.HARDWARE key) to "
           f"model the live backend.")
    if strict:
        raise RuntimeError(msg)
    warnings.warn(msg, stacklevel=3)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, *,
                   hardware: Union[None, str, HardwareSpec] = None,
                   check_backend: bool = True,
                   strict: bool = False) -> dict:
    """The three roofline terms (seconds) plus the dominant bound.

    ``hardware`` selects the peak/bandwidth preset: a
    :class:`repro.platform.HardwareSpec`, a ``HARDWARE`` key, a platform
    name ("tpu"/"gpu"/"cpu"), or None for the tpu-v5e dry-run target.
    """
    hw = _DEFAULT_HW if hardware is None else resolve_hardware(hardware)
    if check_backend:
        _check_hardware_matches(hw, strict)
    compute = flops / hw.peak_flops
    memory = bytes_accessed / hw.hbm_bw
    collective = collective_bytes / hw.link_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective, "hardware": hw.name}
    seconds = {"compute_s": compute, "memory_s": memory,
               "collective_s": collective}
    dominant = max(seconds, key=seconds.get)
    bound = max(compute, memory, collective)
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "roofline_fraction": compute / bound if bound > 0 else 0.0,
    })
    return terms


def _encdec_param_split(cfg) -> Tuple[float, float]:
    """(N_enc, N_dec): params touched per encoder token vs decoder token.

    Cross-attention K/V projections process encoder tokens (once per
    sequence); everything else in the decoder processes decoder tokens.
    """
    d = cfg.d_model
    per_attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    n_up = 2 if cfg.act in ("swiglu", "geglu") else 1
    per_mlp = (n_up + 1) * d * cfg.d_ff
    cross_kv = 2 * d * cfg.kv_dim
    cross_q_out = d * cfg.q_dim + cfg.q_dim * d
    n_enc = cfg.encoder_layers * (per_attn + per_mlp + 2 * d) \
        + cfg.n_layers * cross_kv
    n_dec = cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2) \
        + cfg.n_layers * (per_attn + per_mlp + cross_q_out + 3 * d)
    return float(n_enc), float(n_dec)


def analytic_model_flops(cfg, cell, n_active_params: int) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode (per step,
    whole job; divide by chips for per-device).  Encoder-decoder models
    split N by the tokens each side actually processes (decoder length =
    seq_len / 8 per the audio-stub convention)."""
    B, S = cell.global_batch, cell.seq_len
    mult = 6.0 if cell.kind == "train" else 2.0
    if getattr(cfg, "encoder_layers", 0):
        n_enc, n_dec = _encdec_param_split(cfg)
        s_dec = max(S // 8, 16)
        if cell.kind == "decode":
            return 2.0 * n_dec * B
        return mult * B * (n_enc * S + n_dec * s_dec)
    if cell.kind == "decode":
        return 2.0 * n_active_params * B
    return mult * n_active_params * B * S


def active_param_count(model) -> int:
    """Active (per-token) parameters: MoE counts top_k + shared experts."""
    cfg = model.cfg
    n = model.param_count()
    if cfg.moe is not None:
        m = cfg.moe
        total_e = m.e_padded  # storage may be padded for EP divisibility
        act_e = m.top_k
        # expert params per layer
        n_up = 2 if cfg.act in ("swiglu", "geglu") else 1
        per_expert = (n_up + 1) * cfg.d_model * m.d_ff_expert
        counts = cfg._block_counts()
        moe_layers = counts.get("attn", 0)
        n -= (total_e - act_e) * per_expert * moe_layers
    return n
