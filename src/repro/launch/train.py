"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --code bgc --decoder onestep --steps 50 [--straggler deadline] \
        [--mesh debug --mesh-data 2 --mesh-model 2]

Selects any assigned architecture (``--arch``), builds the gradient code,
wires the straggler model and fault plan, and runs the CodedTrainer.
On this CPU box use ``--smoke`` (reduced config); the full configs are
for the TPU meshes proven out by ``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import json

from repro.configs import get_config, list_archs
from repro.core import registry
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import FaultInjector, make_straggler_model
from repro.runtime.faults import FaultPlan
from repro.training import CodedTrainConfig, CodedTrainer

STRAGGLER_PRESETS = {
    "none": {},
    "iid": {"delta": 0.2},
    "fixed": {"delta": 0.25},
    "deadline": {"deadline": 1.5, "tail_scale": 0.3},
    "correlated": {"pod_size": 4, "p_pod": 0.1},
    "clustered": {"blocks": 4, "p_block": 0.15},
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    # scheme choices come from the registry: registering a family in
    # core/registry.py is all it takes to reach this CLI
    ap.add_argument("--code", default="bgc", choices=list(registry.names()))
    ap.add_argument("--decoder", default="onestep",
                    choices=list(registry.DECODERS))
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--s", type=int, default=3)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler", default="fixed",
                    choices=list(STRAGGLER_PRESETS))
    ap.add_argument("--fail-step", type=int, default=None,
                    help="inject a hard worker failure at this step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--dist-mode", default="fused",
                    choices=["fused", "coded_allreduce"],
                    help="'coded_allreduce' runs the shard_map coded "
                         "aggregation over a 1-D worker mesh spanning all "
                         "local devices (docs/architecture.md §9)")
    ap.add_argument("--trace", default="none",
                    choices=["none", "pareto", "bimodal", "clustered"],
                    help="drive straggler masks from a latency trace "
                         "through --sync-policy instead of --straggler")
    ap.add_argument("--sync-policy", default="deadline",
                    choices=["sync", "deadline", "backup", "adaptive"])
    ap.add_argument("--adaptive", action="store_true",
                    help="close the loop: an AdaptiveCoder controller "
                         "(repro.control) observes the straggler process "
                         "and re-tunes s / decoder / deadline online "
                         "(docs/adaptive.md)")
    ap.add_argument("--error-budget", type=float, default=0.05,
                    help="mean decode err/k the adaptive controller "
                         "steers under (with --adaptive)")
    ap.add_argument("--mesh", default="none", choices=["none", "debug"],
                    help="'debug' builds a small host mesh (needs a "
                         "forced host-device world — call "
                         "repro.platform.host_devices(n) before jax, or "
                         "export REPRO_HOST_DEVICES=n)")
    ap.add_argument("--mesh-data", type=int, default=2)
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {model.param_count() / 1e6:.1f}M params, "
          f"code={args.code} s={args.s} decoder={args.decoder} "
          f"workers={args.workers}")

    mesh = None
    if args.mesh == "debug":
        from .mesh import make_debug_mesh
        mesh = make_debug_mesh(args.mesh_data, args.mesh_model)
        print(f"[train] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    straggler = (make_straggler_model(args.straggler,
                                      **STRAGGLER_PRESETS[args.straggler])
                 if args.straggler != "none" else None)
    trace = None
    if args.trace != "none":
        from repro.sim.traces import make_trace
        trace = make_trace(args.trace, steps=args.steps, n=args.workers,
                           seed=args.seed)
        straggler = None    # masks come from the trace + sync policy
        print(f"[train] trace: {args.trace} x {args.steps} steps, "
              f"policy={args.sync_policy}")
    faults = None
    if args.fail_step is not None:
        faults = FaultInjector([FaultPlan(step=args.fail_step,
                                          workers=(args.workers - 1,))])

    controller = None
    if args.adaptive:
        from repro.control import AdaptiveCoder, ControlConfig
        controller = AdaptiveCoder(
            args.code, args.workers,
            ControlConfig(error_budget=args.error_budget),
            s=args.s, decoder=args.decoder)
        print(f"[train] adaptive controller: error budget "
              f"{args.error_budget}")

    tcfg = CodedTrainConfig(
        code=args.code, n_workers=args.workers, s=args.s,
        decoder=args.decoder, seq_len=args.seq_len, steps=args.steps,
        seed=args.seed,
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=max(args.steps // 10, 1), dist_mode=args.dist_mode)
    trainer = CodedTrainer(model, tcfg, straggler_model=straggler,
                           fault_injector=faults, mesh=mesh,
                           trace=trace,
                           sync_policy=args.sync_policy if trace else None,
                           controller=controller)
    if trainer.allreduce is not None:
        print(f"[train] coded_allreduce: {trainer.allreduce.n_devices} "
              f"device(s) x {trainer.allreduce.partition.lanes} lane(s)")
    out = trainer.run()

    for h in out["history"]:
        print(f"  step {h['step']:>5} ce={h['mean_ce']:.4f} "
              f"stragglers={h['stragglers']} "
              f"decode_err/k={h['decode_err']:.4f} workers={h['n_workers']}"
              + (f" s={h['s']} dec={h['decoder']}" if args.adaptive else ""))
    if controller is not None and controller.policy.actions:
        print("[train] controller actions:")
        for at_step, act in controller.policy.actions:
            print(f"  step {at_step:>5} {act.kind} -> {act.value}  "
                  f"({act.reason})")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(out["history"], f, indent=1)
    first, last = out["history"][0]["mean_ce"], out["history"][-1]["mean_ce"]
    print(f"[train] ce {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
