"""Roofline-seeded Pallas tile autotune.

Closes the loop the ROADMAP called out: ``launch/roofline.py`` models
cost but never fed kernel choices, and the kernel tile sizes were
hand-picked constants.  This module sweeps tile candidates for the
decode kernels and emits the committed per-(backend, kernel,
shape-class) table in ``src/repro/kernels/tile_tables.json`` that
``kernels.ops`` / ``DecodeEngine`` / ``CodedAllReduce`` load by default
(see :mod:`repro.kernels.tiles`).

The sweep is measurement-last, model-first:

1. **Candidates** are generated per kernel by varying only the grid
   axes marked *parallel* in the kernel's dimension semantics (bb / bp /
   bk-of-onestep / bi / bj).  Contraction axes keep their defaults:
   changing the contraction block regroups the fp32 accumulation and can
   legally change the last bits of the output — and the contract here is
   that autotuned tiles are BITWISE-identical to the defaults.
2. **Roofline ranking** scores each candidate with the platform preset
   from ``repro.platform.HARDWARE``:
       cost = flops/peak + bytes/hbm_bw + grid_cells * launch_overhead
   where the per-cell launch overhead is the term that actually differs
   between tiles at fixed problem size (interpret mode executes the grid
   as a host loop, so on CPU it dominates; on TPU it is ~µs).  Only the
   top ``--top`` candidates are measured.
3. **Measurement** is best-of-``--reps`` wall time with
   ``block_until_ready``, after a warmup that also produces the output
   for the bitwise check: any candidate whose output is not
   ``np.array_equal`` to the default-tile output is rejected outright,
   whatever its speed.

Usage:
    PYTHONPATH=src python -m repro.launch.autotune            # all kernels
    PYTHONPATH=src python -m repro.launch.autotune \
        --kernels fused_decode_apply batched_onestep_decode --top 4

The table merges per backend key (``repro.platform.backend_key()``), so
re-pinning on a TPU host leaves the committed CPU entries untouched.
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import math
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels.tiles import (DEFAULT_TILES, KERNEL_TILE_ARGS,
                                 TILE_TABLE_PATH, TileConfig, _table_cache,
                                 shape_class)
from repro.platform import backend_key, resolve_hardware

# per-grid-cell launch/dispatch overhead (seconds) by platform of the
# hardware spec — the roofline term that separates tile candidates at
# fixed problem size.  "cpu" models interpret mode's per-cell host loop.
LAUNCH_OVERHEAD_S = {"cpu": 2e-4, "tpu": 2e-6, "gpu": 5e-6}

# grid axes that are "parallel" in each kernel's dimension_semantics —
# the only axes autotune varies (see module docstring, point 1)
SAFE_AXES: Dict[str, Tuple[str, ...]] = {
    "batched_onestep_decode": ("bb", "bk"),
    "batched_onestep_decode_ell": ("bb", "bk"),
    "batched_masked_gram": ("bb", "bi", "bj"),
    "fused_decode_apply": ("bb", "bp"),
    "coded_accumulate_batched": ("bb", "bp"),
    "coded_accumulate": ("bp",),
}


@dataclasses.dataclass
class Workload:
    """One representative problem for a (kernel, shape-class) cell."""

    kernel: str
    B: Optional[int]                   # batch size (None for unbatched)
    dims: Dict[str, int]               # tile axis -> problem dim it tiles
    grid_axes: Tuple[str, ...]         # axes whose blocks multiply into
                                       # the grid (incl. contraction)
    flops: float
    bytes: float
    build: Callable[[np.random.Generator], tuple]   # -> jnp inputs
    call: Callable[[tuple, TileConfig], "object"]   # -> output array


def _workloads(k: int, B_list: Tuple[int, ...]) -> List[Workload]:
    """The tuned cells: the E10 decode ensemble shapes (k = n) plus the
    all-reduce accumulate at a per-device lane/param shape."""
    import jax.numpy as jnp

    from repro.kernels import ops

    impl = _impl()
    out: List[Workload] = []

    for B in B_list:
        def build_onestep(rng, B=B):
            G = rng.integers(0, 2, size=(k, k)).astype(np.float32)
            m = (rng.random((B, k)) > 0.3).astype(np.float32)
            r = rng.random(B).astype(np.float32) + 0.5
            return (jnp.asarray(G), jnp.asarray(m), jnp.asarray(r))

        out.append(Workload(
            kernel="batched_onestep_decode", B=B,
            dims={"bb": B, "bk": k, "bn": k},
            grid_axes=("bb", "bk", "bn"),
            flops=2.0 * B * k * k, bytes=4.0 * (B * k + k * k + B * k),
            build=build_onestep,
            call=lambda a, t: ops.batched_onestep_decode(
                *a, impl=impl, tiles=t)))

        def build_fused(rng, B=B):
            msgs = rng.standard_normal((k, k)).astype(np.float32)
            m = (rng.random((B, k)) > 0.3).astype(np.float32)
            s = rng.random(B).astype(np.float32) + 0.5
            return (jnp.asarray(msgs), jnp.asarray(m), jnp.asarray(s))

        out.append(Workload(
            kernel="fused_decode_apply", B=B,
            dims={"bb": B, "bl": k, "bp": k},
            grid_axes=("bb", "bp", "bl"),
            flops=2.0 * B * k * k, bytes=4.0 * (k * k + B * k + B * k),
            build=build_fused,
            call=lambda a, t: ops.fused_decode_apply(
                *a, impl=impl, tiles=t)))

        L, P = 32, 8192    # per-device lanes x flat params
        def build_acc(rng, B=B, L=L, P=P):
            g = rng.standard_normal((L, P)).astype(np.float32)
            w = rng.standard_normal((B, L)).astype(np.float32)
            return (jnp.asarray(g), jnp.asarray(w))

        out.append(Workload(
            kernel="coded_accumulate_batched", B=B,
            dims={"bb": B, "bk": L, "bp": P},
            grid_axes=("bb", "bp", "bk"),
            flops=2.0 * B * L * P, bytes=4.0 * (L * P + B * L + B * P),
            build=build_acc,
            call=lambda a, t: ops.coded_accumulate_batched(
                *a, impl=impl, tiles=t)))

    # the engine's gram path chunks the ensemble to ~n-row batches
    Bg = min(max(B_list), 256)
    def build_gram(rng, B=Bg):
        G = rng.integers(0, 2, size=(k, k)).astype(np.float32)
        gram = (G.T @ G).astype(np.float32)
        m = (rng.random((B, k)) > 0.3).astype(np.float32)
        return (jnp.asarray(gram), jnp.asarray(m))

    out.append(Workload(
        kernel="batched_masked_gram", B=Bg,
        dims={"bb": Bg, "bi": k, "bj": k},
        grid_axes=("bb", "bi", "bj"),
        flops=2.0 * Bg * k * k, bytes=4.0 * (k * k + Bg * k + Bg * k * k),
        build=build_gram,
        call=lambda a, t: ops.batched_masked_gram(*a, impl=impl, tiles=t)))
    return out


def _impl() -> str:
    """Compiled Pallas on an accelerator, interpret mode on a CPU host."""
    from repro.platform import backend_info

    return "pallas" if backend_info().platform != "cpu" \
        else "pallas_interpret"


# --------------------------------------------------------------------------
# candidate generation + roofline ranking
# --------------------------------------------------------------------------


def _axis_candidates(axis: str, default: int, dim: int) -> List[int]:
    """Powers of two from the default up to (and clamped at) the dim."""
    cands = {min(default, dim), dim}
    v = 8
    while v < dim:
        if v >= default // 2:      # don't bother going far below default
            cands.add(v)
        v *= 2
    return sorted(c for c in cands if c > 0)


def candidates_for(w: Workload) -> List[TileConfig]:
    """Fully-specified tile configs varying only the kernel's safe axes.

    Every candidate pins ALL of the kernel's tile args (safe-axis
    variation merged over the historical defaults) so the committed
    table can never inject a contraction-axis change behind our back.
    """
    base = DEFAULT_TILES[w.kernel]
    axes = [a for a in SAFE_AXES[w.kernel] if a in w.dims]
    grids = [_axis_candidates(a, getattr(base, a), w.dims[a]) for a in axes]
    out = []
    for combo in itertools.product(*grids):
        out.append(base.merged(TileConfig(**dict(zip(axes, combo)))))
    return out


def _grid_cells(w: Workload, t: TileConfig) -> int:
    cells = 1
    for a in w.grid_axes:
        blk = min(getattr(t, a), w.dims[a])
        cells *= math.ceil(w.dims[a] / blk)
    return cells


def _vmem_bytes(w: Workload, t: TileConfig) -> int:
    """fp32 footprint proxy: one block per operand axis-pair + 2 output
    blocks (out + accumulator).  Coarse, but it culls the configs that
    could not possibly fit the scratch budget."""
    blocks = [min(getattr(t, a), w.dims[a]) for a in w.grid_axes]
    total = 0
    for x, y in itertools.combinations(blocks, 2):
        total += x * y
    total += 2 * blocks[0] * blocks[-1]
    return 4 * total


def roofline_cost(w: Workload, t: TileConfig, hw) -> float:
    overhead = LAUNCH_OVERHEAD_S.get(hw.platform, 2e-4)
    if _impl() == "pallas_interpret":
        overhead = LAUNCH_OVERHEAD_S["cpu"]
    return (w.flops / hw.peak_flops + w.bytes / hw.hbm_bw
            + _grid_cells(w, t) * overhead)


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------


def _time_call(fn, reps: int) -> Tuple[float, np.ndarray]:
    out = fn()
    out = np.asarray(out.block_until_ready()
                     if hasattr(out, "block_until_ready") else out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, out


def tune_workload(w: Workload, *, hw, top: int, reps: int,
                  seed: int = 0, verbose: bool = True) -> dict:
    """Sweep one (kernel, shape-class) cell.  Returns the result record
    (chosen tiles, timings, rejects)."""
    rng = np.random.default_rng(seed)
    inputs = w.build(rng)
    default = DEFAULT_TILES[w.kernel]

    cands = [c for c in candidates_for(w)
             if _vmem_bytes(w, c) <= hw.vmem_bytes]
    cands.sort(key=lambda c: roofline_cost(w, c, hw))
    ranked = cands[:top]
    if default not in ranked:
        ranked.append(default)      # the bitwise reference always runs

    t_default, ref = _time_call(lambda: w.call(inputs, default), reps)
    rows, rejected = [], []
    for c in ranked:
        if c == default:
            rows.append({"tiles": c.as_dict(), "time_s": t_default,
                         "default": True})
            continue
        t, out = _time_call(lambda: w.call(inputs, c), reps)
        if not np.array_equal(out, ref):
            rejected.append(c.as_dict())
            continue
        rows.append({"tiles": c.as_dict(), "time_s": t, "default": False})
    best = min(rows, key=lambda r: r["time_s"])
    # table entry: only the axes that differ from the default AFTER the
    # kernel's min(tile, dim) clamp — an axis the workload merely
    # clamped (e.g. bp=256 because P was 256) must not pin that smaller
    # tile onto production shapes where the default would be larger
    entry = {a: v for a, v in best["tiles"].items()
             if min(v, w.dims[a]) != min(getattr(default, a), w.dims[a])}
    rec = {
        "kernel": w.kernel, "shape_class": shape_class(w.B),
        "dims": w.dims, "best": best["tiles"], "entry": entry,
        "default_time_s": t_default, "best_time_s": best["time_s"],
        "speedup_vs_default": t_default / max(best["time_s"], 1e-12),
        "measured": rows, "rejected_bitwise": rejected,
    }
    if verbose:
        print(f"  {w.kernel:28s} {rec['shape_class']:>6s}  "
              f"best={best['tiles']}  "
              f"{rec['speedup_vs_default']:.2f}x vs default"
              + (f"  ({len(rejected)} rejected bitwise)" if rejected else ""))
    return rec


# --------------------------------------------------------------------------
# table emission
# --------------------------------------------------------------------------


def write_table(records: List[dict], *, backend: str,
                path: Optional[Path] = None) -> Path:
    """Merge the sweep results into the committed tile table."""
    p = Path(path) if path is not None else TILE_TABLE_PATH
    try:
        table = json.loads(p.read_text())
        if not isinstance(table, dict):
            table = {}
    except (OSError, json.JSONDecodeError):
        table = {}
    slot = table.setdefault(backend, {})
    for rec in records:
        entry = rec.get("entry", rec["best"])
        if not entry:               # default won: nothing to pin
            slot.get(rec["kernel"], {}).pop(rec["shape_class"], None)
            continue
        slot.setdefault(rec["kernel"], {})[rec["shape_class"]] = entry
    p.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    _table_cache.clear()            # resolve() must see the new table
    return p


def run(kernels: Optional[List[str]] = None, *, k: int = 256,
        batches: Tuple[int, ...] = (300, 1000), top: int = 4,
        reps: int = 3, table_path: Optional[Path] = None,
        write: bool = True) -> dict:
    key = backend_key(initialize=True)
    hw = resolve_hardware(key)
    print(f"autotune: backend={key} impl={_impl()} "
          f"(peak={hw.peak_flops:.3g} FLOP/s, hbm={hw.hbm_bw:.3g} B/s)")
    work = [w for w in _workloads(k, tuple(batches))
            if kernels is None or w.kernel in kernels]
    if not work:
        raise SystemExit(f"no workloads match kernels={kernels!r}; "
                         f"tunable: {sorted(SAFE_AXES)}")
    records = [tune_workload(w, hw=hw, top=top, reps=reps) for w in work]
    out = {"backend": key, "records": records}
    if write:
        p = write_table(records, backend=key, path=table_path)
        print(f"wrote {p}")
        out["table"] = str(p)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--kernels", nargs="*", default=None,
                    help=f"subset of {sorted(SAFE_AXES)} (default: all "
                         f"with workloads)")
    ap.add_argument("--k", type=int, default=256,
                    help="decode cell size k = n (default 256, the E10 cell)")
    ap.add_argument("--batches", type=int, nargs="*", default=[300, 1000],
                    help="mask-ensemble sizes to tune (each pins its "
                         "shape class)")
    ap.add_argument("--top", type=int, default=4,
                    help="measure the N roofline-best candidates")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", type=Path, default=None,
                    help=f"table path (default {TILE_TABLE_PATH})")
    ap.add_argument("--no-write", action="store_true",
                    help="rank and measure only; do not touch the table")
    args = ap.parse_args(argv)
    run(args.kernels, k=args.k, batches=tuple(args.batches), top=args.top,
        reps=args.reps, table_path=args.out, write=not args.no_write)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
