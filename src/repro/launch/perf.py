"""Per-arch beyond-paper performance configurations (EXPERIMENTS.md
Sec-Perf).

The BASELINE config (repro/configs/<arch>.py, unmodified) is the
paper-faithful port; `optimize(cfg)` applies the hillclimbed changes for
the three selected cells (and any arch that shares the bottleneck).
``dryrun.py --opt`` lowers these and writes ``*__opt.json`` artifacts so
before/after roofline terms are directly comparable.

Changes (hypotheses + measurements logged in EXPERIMENTS.md):
  granite / dbrx : MoE dispatch 'global' -> 'grouped' (per-sequence sort;
                   dispatch buffers stay on their data shard)
  rwkv6          : batch_shard_model=True ('model' axis as extra DP for
                   the attn-free arch; kills per-op all-gathers forced by
                   the unshardable 40-head reshape)
  command-r      : microbatched train step (grad accumulation over
                   lax.scan) + bf16 logits CE — see dryrun.build_cell
                   (microbatches) and config.loss_chunk.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

_OPT: Dict[str, Callable] = {}


def _reg(name):
    def deco(fn):
        _OPT[name] = fn
        return fn
    return deco


@_reg("dbrx-132b")
def _moe_grouped(cfg):
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped"))


@_reg("granite-moe-3b-a800m")
def _moe_grouped_ep(cfg):
    # iteration 1: grouped dispatch (5.9x memory / 12.5x collective);
    # iteration 2: pad expert storage 40 -> 48 so the expert dim divides
    # the 16-way 'model' axis -> clean EP (3 experts/device) instead of
    # 32-wide d_ff TP slivers; dummy experts are zero-routed.
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped",
                                     pad_experts_to=48, expert_shard="ep"))


@_reg("rwkv6-3b")
def _ssm_full_dp(cfg):
    return dataclasses.replace(cfg, batch_shard_model=True)


@_reg("command-r-plus-104b")
def _dense_mem(cfg):
    # Memory/footprint package, FINAL (iteration 3 — see EXPERIMENTS.md
    # 4.3).  The per-change ablation REFUTED bf16-norm-I/O and chunked-CE
    # on the byte proxy (checkpoint recompute + unfused bf16 chains cost
    # more than they save), so the final config keeps only the changes
    # that pay: bf16 param storage (neutral bytes, halves weight
    # footprint), FSDP param storage (args 28 -> 3.9 GiB: FITS), and
    # remat=full + 8 microbatches (dryrun) for live-activation footprint.
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat="full",
                               fsdp_params=True)


def optimize(cfg):
    fn = _OPT.get(cfg.name)
    return fn(cfg) if fn else cfg


def microbatches_for(arch: str, shape: str, opt: bool) -> int:
    """Gradient-accumulation factor for the optimized train step."""
    if not opt or shape != "train_4k":
        return 1
    return {"command-r-plus-104b": 8}.get(arch, 1)
