"""Launchers and validation harnesses (CLI entry points).

Public surface, all `python -m repro.launch.<name>`: ``train`` (the
CodedTrainer CLI: --code/--decoder/--dist-mode/--trace/--adaptive),
``serve`` (hedged continuous-batching demo), ``dryrun`` (compile-only
512-device validation + roofline extraction, docs/architecture.md §6),
``roofline`` / ``perf`` (analysis helpers) and ``mesh`` (debug host
meshes).  Importable as a package for the pieces the benchmarks reuse.
"""
