"""Serving launcher CLI (batched prefill + continuous-batching decode).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 8 --max-new 12

``--sim`` skips the model entirely and replays a latency trace through
the multi-replica hedged-serving simulator instead (E12 interactive):

    PYTHONPATH=src python -m repro.launch.serve --sim --trace bimodal \
        --replicas 8 --requests 1000000 --hedge-quantile 0.85
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _run_sim(args) -> int:
    from repro.serving import HedgePolicy, simulate_serving
    from repro.sim.traces import make_trace

    trace = make_trace(args.trace, steps=args.trace_steps, n=args.replicas,
                       seed=args.seed)
    policy = None
    if args.hedge_quantile > 0:
        policy = HedgePolicy(quantile=args.hedge_quantile)
    t0 = time.time()
    res = simulate_serving(trace, args.requests, policy=policy,
                           router_policy=args.router, seed=args.seed)
    dt = time.time() - t0
    mode = (f"hedge@q{args.hedge_quantile}" if policy else "unhedged")
    print(f"[serve --sim] {args.trace} x{args.replicas} replicas, "
          f"{args.requests} requests ({mode}, {args.router} routing): "
          f"{dt:.1f}s")
    for q, v in sorted(res.quantiles.items()):
        print(f"  p{100 * q:<5g} {v:.3f}")
    print(f"  mean_compute {res.mean_compute:.3f}  "
          f"hedge_rate {res.hedge_rate:.3f}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sim", action="store_true",
                    help="replay a trace through the multi-replica "
                         "simulator (no model)")
    ap.add_argument("--trace", default="bimodal",
                    help="trace source for --sim (see sim.traces)")
    ap.add_argument("--trace-steps", type=int, default=32_768)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--hedge-quantile", type=float, default=0.85,
                    help="0 disables hedging")
    ap.add_argument("--router", default="uniform",
                    choices=("uniform", "p2c"))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.sim:
        return _run_sim(args)

    import jax

    from repro.configs import get_config, list_archs
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    if args.arch is None or args.arch not in list_archs():
        ap.error(f"--arch is required without --sim "
                 f"(choices: {', '.join(list_archs())})")

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: {model.param_count() / 1e6:.1f}M params")

    engine = ServingEngine(model, params, batch_slots=args.slots,
                           cache_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = engine.serve_queue(reqs)
    dt = time.time() - t0
    tok = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {tok} tokens, {dt:.1f}s "
          f"({tok / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
