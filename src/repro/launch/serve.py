"""Serving launcher CLI (batched prefill + continuous-batching decode).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 8 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {cfg.name}: {model.param_count() / 1e6:.1f}M params")

    engine = ServingEngine(model, params, batch_slots=args.slots,
                           cache_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    results = engine.serve_queue(reqs)
    dt = time.time() - t0
    tok = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {tok} tokens, {dt:.1f}s "
          f"({tok / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
