"""Multi-pod dry-run: prove the distribution config is coherent.

Device-world precedence: this module needs a 512-device placeholder
world (jax locks the host device count on first init, so it must be
configured before any jax import).  That rule now lives in ONE place —
``repro.platform.host_devices`` — whose contract is exactly the old
setdefault: a caller that already exported XLA_FLAGS wins VERBATIM —
e.g. the 8-device coded-allreduce test lane sets
``--xla_force_host_platform_device_count=8`` and can then import dryrun
helpers in the same process without its world being clobbered.  Only
when no XLA_FLAGS are present does importing this module install the
512-device default (in that case production-mesh cells run as designed;
under a caller's smaller world ``make_production_mesh`` raises with a
clear message rather than silently mis-meshing).

For every (architecture x input-shape x mesh) cell this lowers and
compiles the real step function (train_step / prefill / decode_step)
against ShapeDtypeStruct inputs — no allocation — on the production
meshes:

    single pod : (data=16, model=16)          = 256 chips
    multi pod  : (pod=2, data=16, model=16)   = 512 chips

and records, per cell:
  * compile success + wall time (failures here are bugs in our sharding),
  * compiled.memory_analysis()  -> bytes per device (proves it fits),
  * compiled.cost_analysis()    -> HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the post-SPMD HLO text,
  * reduced-depth UNROLLED variants (1 and 2 pattern groups, single-pod)
    whose per-layer slope extrapolates scan-hidden terms to full depth
    (XLA counts a `while` body once — docs/architecture.md §6).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minicpm-2b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # 40 cells x 2 meshes
"""

from repro.platform import host_devices

# Must precede every other import (jax locks the device count on first
# init).  host_devices follows the documented precedence: a pre-set
# XLA_FLAGS is respected verbatim — see the module docstring.
host_devices(512)

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.dist.sharding import logical_to_pspec, param_shardings, \
    rules_for, use_mesh, use_rules
from repro.launch import perf as PERF
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, build_model
from repro.optim import OptConfig, adamw_update, init_opt_state, \
    opt_state_shardings

DEFAULT_OUT = Path("artifacts/dryrun")

_is_axes = lambda t: isinstance(t, tuple) and all(
    isinstance(e, (str, type(None))) for e in t)


# ------------------------- sharding helpers ---------------------------------

def _batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _input_shardings(mesh, specs: Dict[str, Any], model) -> Dict[str, Any]:
    """NamedShardings for the input_specs() tree of a cell."""
    ba = _batch_axes(mesh)
    bsz_div = all(
        s.shape[0] % RL_prod(mesh, ba) == 0
        for k, s in specs.items()
        if k != "caches" and hasattr(s, "shape") and s.ndim >= 1)
    lead = ba if bsz_div else None

    out: Dict[str, Any] = {}
    for name, s in specs.items():
        if name == "caches":
            axes_tree = model.cache_axes()
            out[name] = jax.tree_util.tree_map(
                lambda axes, aval: NamedSharding(
                    mesh, logical_to_pspec(axes, aval.shape, mesh)),
                axes_tree, s, is_leaf=_is_axes)
        else:
            spec = [lead] + [None] * (s.ndim - 1) if s.ndim >= 1 else []
            out[name] = NamedSharding(mesh, P(*spec))
    return out


def RL_prod(mesh, names) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for nm in names:
        n *= sizes[nm]
    return n


# ------------------------- step builders ------------------------------------

def build_cell(model, cell, mesh, *, with_opt: bool = True,
               microbatches: int = 1):
    """Returns (fn, args, in_shardings, out_shardings) ready to jit/lower.

    microbatches > 1: gradient accumulation over a python-unrolled loop
    (NOT lax.scan — the roofline accounting must see every microstep)."""
    specs = model.input_specs(cell)
    aparams = model.abstract_params()
    p_sh = param_shardings(model.param_axes(), aparams, mesh,
                           fsdp=getattr(model.cfg, "fsdp_params", False))

    if cell.kind == "train":
        opt_cfg = OptConfig()
        aopt = jax.eval_shape(init_opt_state, aparams)
        o_sh = opt_state_shardings(model.param_axes(), aparams, mesh)
        b_sh = _input_shardings(mesh, specs, model)

        if with_opt:
            def train_step(params, opt_state, batch):
                if microbatches == 1:
                    (loss, metrics), grads = jax.value_and_grad(
                        model.loss_fn, has_aux=True)(params, batch)
                    mean_ce = metrics["mean_ce"]
                else:
                    def sl(v, i):
                        if hasattr(v, "ndim") and v.ndim >= 1:
                            mb = v.shape[0] // microbatches
                            return v[i * mb: (i + 1) * mb]
                        return v
                    loss = jnp.zeros((), jnp.float32)
                    mean_ce = jnp.zeros((), jnp.float32)
                    grads = None
                    for i in range(microbatches):
                        micro = {k: sl(v, i) for k, v in batch.items()}
                        (li, mi), gi = jax.value_and_grad(
                            model.loss_fn, has_aux=True)(params, micro)
                        gi = jax.tree_util.tree_map(
                            lambda g: g.astype(jnp.float32), gi)
                        grads = gi if grads is None else \
                            jax.tree_util.tree_map(jnp.add, grads, gi)
                        loss = loss + li
                        mean_ce = mean_ce + mi["mean_ce"] / microbatches
                lr = jnp.asarray(1e-4, jnp.float32)
                params, opt_state, om = adamw_update(
                    params, grads, opt_state, opt_cfg, lr)
                return params, opt_state, (loss, mean_ce, om["grad_norm"])

            return (train_step, (aparams, aopt, specs),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None))

        def grad_step(params, batch):
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            return loss, grads

        return grad_step, (aparams, specs), (p_sh, b_sh), None

    if cell.kind == "prefill":
        b_sh = _input_shardings(mesh, specs, model)

        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len=cell.seq_len)

        return prefill_step, (aparams, specs), (p_sh, b_sh), None

    # decode: one new token against a cache of seq_len
    b_sh = _input_shardings(mesh, specs, model)

    def decode_step(params, tokens, caches):
        return model.decode_step(params, tokens, caches)

    return (decode_step, (aparams, specs["tokens"], specs["caches"]),
            (p_sh, b_sh["tokens"], b_sh["caches"]), None)


# ------------------------- per-cell dry run ---------------------------------

def _memory_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover - backend-specific
        return {"error": repr(e)}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def lower_compile_cell(arch: str, shape: str, multi_pod: bool,
                       *, hlo_dir: Optional[Path] = None,
                       opt: bool = False) -> Dict[str, Any]:
    """Lower + compile one cell; return the dry-run record."""
    cfg = get_config(arch)
    if opt:
        cfg = PERF.optimize(cfg)
    model = build_model(cfg)
    cell = SHAPES[shape]
    micro = PERF.microbatches_for(arch, shape, opt)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "pod2_data16_model16" if multi_pod else "data16_model16",
        "kind": cell.kind,
        "opt": opt,
        "microbatches": micro,
        "params": model.param_count(),
        "active_params": RL.active_param_count(model),
    }

    ok, reason = model.supports_cell(cell)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh), use_rules(rules_for(cfg)):
        fn, args, in_sh, out_sh = build_cell(model, cell, mesh,
                                             microbatches=micro)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory_analysis"] = _memory_analysis(compiled)
    rec["cost_analysis"] = _cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = RL.parse_collectives(hlo)
    rec["collectives"] = {
        "bytes_by_kind": coll.bytes_by_kind,
        "count_by_kind": coll.count_by_kind,
        "in_loop_bytes": coll.in_loop_bytes,
        "total_bytes": coll.total_bytes,
    }
    rec["status"] = "ok"
    if hlo_dir is not None:
        hlo_dir.mkdir(parents=True, exist_ok=True)
        (hlo_dir / f"{arch}__{shape}__{rec['mesh']}.hlo.txt").write_text(hlo)
    return rec


# -------------------- reduced-depth roofline variants ------------------------

def _reduced_cfg(cfg, groups: int):
    """Full-width, UNROLLED, `groups` pattern groups deep (no layer scan,
    no remat — HLO terms become per-layer-exact for extrapolation)."""
    p = len(cfg.block_pattern)
    kw: Dict[str, Any] = dict(
        name=f"{cfg.name}-g{groups}", n_layers=groups * p,
        scan_layers=False, remat="none")
    if cfg.encoder_layers:
        ratio = cfg.encoder_layers / cfg.n_layers
        kw["encoder_layers"] = max(int(round(groups * p * ratio)), 1)
    return dataclasses.replace(cfg, **kw)


def roofline_variant(arch: str, shape: str, groups: int,
                     opt: bool = False) -> Dict[str, Any]:
    """cost/collective terms of a reduced-depth unrolled variant
    (single-pod mesh)."""
    cfg = get_config(arch)
    if opt:
        cfg = PERF.optimize(cfg)
    cfg = _reduced_cfg(cfg, groups)
    model = build_model(cfg)
    cell = SHAPES[shape]
    micro = PERF.microbatches_for(arch, shape, opt)
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with use_mesh(mesh), use_rules(rules_for(cfg)):
        fn, args, in_sh, out_sh = build_cell(model, cell, mesh,
                                             microbatches=micro)
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    coll = RL.parse_collectives(compiled.as_text())
    return {
        "groups": groups,
        "n_layers": cfg.n_layers,
        "encoder_layers": cfg.encoder_layers,
        "cost_analysis": _cost_analysis(compiled),
        "collective_bytes": coll.total_bytes,
        "collective_in_loop_bytes": coll.in_loop_bytes,
        "compile_s": round(time.time() - t0, 2),
    }


# ------------------------- driver -------------------------------------------

def run_cell(arch: str, shape: str, meshes, out_dir: Path,
             *, variants: bool, skip_existing: bool,
             hlo_dir: Optional[Path] = None, opt: bool = False) -> None:
    for mesh_name in meshes:
        multi = mesh_name == "multi"
        tag = "pod2_data16_model16" if multi else "data16_model16"
        suffix = "__opt" if opt else ""
        out = out_dir / f"{arch}__{shape}__{tag}{suffix}.json"
        if skip_existing and out.exists():
            prev = json.loads(out.read_text())
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip-existing] {out.name}")
                continue
        print(f"[dryrun] {arch} x {shape} x {tag}{suffix} ...", flush=True)
        try:
            rec = lower_compile_cell(arch, shape, multi, hlo_dir=hlo_dir,
                                     opt=opt)
        except Exception:
            rec = {"arch": arch, "shape": shape, "mesh": tag, "opt": opt,
                   "status": "error", "traceback": traceback.format_exc()}
        # reduced-depth variants: single-pod only, successful cells only
        if variants and not multi and rec.get("status") == "ok":
            rec["variants"] = []
            for g in (1, 2):
                try:
                    rec["variants"].append(
                        roofline_variant(arch, shape, g, opt=opt))
                except Exception:
                    rec["variants"].append(
                        {"groups": g, "status": "error",
                         "traceback": traceback.format_exc()})
        out_dir.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))
        status = rec.get("status")
        extra = (f" compile={rec.get('compile_s')}s" if status == "ok"
                 else f" ({rec.get('reason', '')[:60]})" if status == "skipped"
                 else "")
        print(f"[dryrun] {arch} x {shape} x {tag}: {status}{extra}",
              flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None],
                    help="input-shape cell (default: all)")
    ap.add_argument("--mesh", default="single,multi",
                    help="comma list from {single,multi}")
    ap.add_argument("--all", action="store_true", help="all 40 cells x meshes")
    ap.add_argument("--out-dir", default=str(DEFAULT_OUT))
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump compiled HLO text here")
    ap.add_argument("--no-variants", action="store_true",
                    help="skip reduced-depth roofline variants")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the Sec-Perf optimized configs "
                         "(repro.launch.perf) and write *__opt.json")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [m.strip() for m in args.mesh.split(",") if m.strip()]
    out_dir = Path(args.out_dir)
    hlo_dir = Path(args.hlo_dir) if args.hlo_dir else None

    for arch in archs:
        for shape in shapes:
            run_cell(arch, shape, meshes, out_dir,
                     variants=not args.no_variants,
                     skip_existing=args.skip_existing, hlo_dir=hlo_dir,
                     opt=args.opt)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
