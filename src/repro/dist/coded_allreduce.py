"""CodedAllReduce: shard_map coded gradient aggregation (docs/architecture.md §9).

After PR 1-2 the coded path still executed as a single-process
simulation — decode weights were folded into per-row loss weights and
one process computed the whole batch.  This module is the first place
the paper's Algorithm 1/2 dataflow runs on *actual devices*:

    workers  --(partition_workers)-->  devices      (ELL column packing)
    trace    --(sync policy)------->   masks [S, n]
    masks    --(DecodeEngine)------>   weights [S, n]   (ONE decode_batch)
    device d --(local grad)-------->   Σ_{j∈d} w_j Σ_i G[i,j] ∇L_i /(kT)
    devices  --(psum over 'workers')-> decoded gradient  (replicated)

Each of the n logical workers (columns of G) is pinned to a device lane;
a device owns ``lanes = ceil(n / D)`` workers (``-1``-padded when n is
not a multiple of the device count, so every device sees identical
shapes).  A straggler mask zeroes a worker's decode weight and with it
the whole device-lane contribution; decoding is the weighted ``psum``
over the 'workers' mesh axis.  The weights come from the cached batched
:class:`~repro.core.engine.DecodeEngine` — one ``decode_batch`` call per
trace, the PR 2 invariant, never a per-step decode loop.

Two aggregation surfaces:

  * :meth:`CodedAllReduce.value_and_grad` — the training path.  Wraps a
    loss function in shard_map: every device differentiates only its
    local rows (the decode-as-loss-reweighting identity of
    docs/architecture.md §2.1 restricted to the device's workers) and
    the psum of the local
    gradients IS the master decode.  Differentially tested against
    ``training.train_loop.explicit_master_decode_grads`` to fp64 in
    tests/test_coded_allreduce.py.
  * :meth:`CodedAllReduce.aggregate_messages_batch` — the explicit
    message path.  Per-worker coded gradient messages are combined
    on-device with the batched weighted-accumulate kernel
    (``kernels.coded_accumulate.coded_accumulate_batched``) and psum'd;
    ``sim.cluster.ClusterSim.run_distributed`` uses it to validate the
    E11 frontier errors against real multi-device execution.
  * :meth:`CodedAllReduce.aggregate_messages_fused` — the pipelined hot
    path.  For the one-step decoder the weights are rank-1 in the mask,
    so the decode rides the accumulate (``kernels.fused_decode_apply``):
    one pass over the worker messages, no weight ensemble.

The mesh may be multi-axis: the worker axis (``axis_name``) is manual
under shard_map while any remaining axes (data / model / FSDP) stay
GSPMD-automatic, so the coded aggregation composes with tensor-sharded
params (``sharding.make_coded_mesh`` builds such a mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.4.35 keeps shard_map under jax.experimental
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - newer jax moved it to the top level
    from jax import shard_map  # type: ignore[attr-defined]

from ..core.assignment import CodedAssignment, build_assignment
from ..core.codes import GradientCode
from ..core.engine import DecodeEngine

__all__ = [
    "WORKER_AXIS",
    "DevicePartition",
    "partition_workers",
    "make_worker_mesh",
    "CodedAllReduce",
]

WORKER_AXIS = "workers"


# --------------------------------------------------------------------------
# worker -> device partition
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DevicePartition:
    """Static assignment of the n code columns to D device lanes.

    ``worker_ids[d, l]`` is the worker owned by lane l of device d, or
    -1 for a padding lane.  Workers are packed contiguously so the flat
    [worker, slot, row] batch layout of the pipeline reshapes into
    per-device microbatches with one gather.
    """

    n: int                      # logical workers (columns of G)
    n_devices: int              # mesh size D
    lanes: int                  # worker slots per device, ceil(n / D)
    worker_ids: np.ndarray      # [D, lanes] int32, -1 = padding lane

    @property
    def padded_n(self) -> int:
        return self.n_devices * self.lanes

    @property
    def lane_mask(self) -> np.ndarray:
        """[D, lanes] bool — True where the lane holds a real worker."""
        return self.worker_ids >= 0

    def scatter(self, per_worker: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """[n, ...] per-worker array -> [D, lanes, ...]; pads get `fill`."""
        per_worker = np.asarray(per_worker)
        if per_worker.shape[0] != self.n:
            raise ValueError(f"leading dim {per_worker.shape[0]} != n={self.n}")
        out = np.full((self.padded_n,) + per_worker.shape[1:], fill,
                      dtype=per_worker.dtype)
        ids = self.worker_ids.reshape(-1)
        out[ids >= 0] = per_worker[ids[ids >= 0]]
        return out.reshape((self.n_devices, self.lanes) + per_worker.shape[1:])

    def gather(self, per_device: np.ndarray) -> np.ndarray:
        """[D, lanes, ...] -> [n, ...], dropping padding lanes (inverse
        of :meth:`scatter` for any fill value)."""
        per_device = np.asarray(per_device)
        flat = per_device.reshape((self.padded_n,) + per_device.shape[2:])
        ids = self.worker_ids.reshape(-1)
        out = np.empty((self.n,) + per_device.shape[2:], dtype=per_device.dtype)
        out[ids[ids >= 0]] = flat[ids >= 0]
        return out


def partition_workers(n: int, n_devices: int) -> DevicePartition:
    """Contiguous block partition of n workers over D devices.

    Handles every ragged case the tests exercise: n not a multiple of D
    (padding lanes), D = 1 (everything local), and D > n (trailing
    devices hold only padding and contribute exact zeros to the psum).
    """
    if n <= 0 or n_devices <= 0:
        raise ValueError(f"need n > 0 and n_devices > 0, got ({n}, {n_devices})")
    lanes = max(-(-n // n_devices), 1)
    ids = np.full((n_devices, lanes), -1, dtype=np.int32)
    flat = ids.reshape(-1)
    flat[:n] = np.arange(n, dtype=np.int32)
    return DevicePartition(n=n, n_devices=n_devices, lanes=lanes,
                           worker_ids=ids)


def make_worker_mesh(devices=None, axis_name: str = WORKER_AXIS) -> Mesh:
    """1-D mesh over the local devices; the coded all-reduce's world."""
    devs = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devs), (axis_name,))


# --------------------------------------------------------------------------
# the coded all-reduce
# --------------------------------------------------------------------------


class CodedAllReduce:
    """Coded data-parallel aggregation for one GradientCode on one mesh.

    Owns the worker->device partition and the shard_map'd aggregation
    functions.  The DecodeEngine is shared with (not owned by) the
    caller so the trainer / ClusterSim batch-call invariants hold on the
    engine they observe.
    """

    def __init__(self, code: GradientCode, *,
                 engine: Optional[DecodeEngine] = None,
                 assignment: Optional[CodedAssignment] = None,
                 mesh: Optional[Mesh] = None,
                 axis_name: str = WORKER_AXIS):
        self.code = code
        self.assignment = assignment if assignment is not None \
            else build_assignment(code)
        self.engine = engine if engine is not None else DecodeEngine(code)
        self.mesh = mesh if mesh is not None else make_worker_mesh(
            axis_name=axis_name)
        names = tuple(self.mesh.axis_names)
        # the worker axis may compose with data/model/FSDP axes: manual
        # over `axis_name`, GSPMD-automatic over everything else
        if axis_name in names:
            self.axis_name = axis_name
        elif len(names) == 1:
            self.axis_name = names[0]       # 1-D mesh: any axis name works
        else:
            raise ValueError(
                f"mesh axes {names} do not include the worker axis "
                f"{axis_name!r}; pass axis_name= to pick the coded axis of "
                f"a multi-axis mesh")
        self.auto_axes = frozenset(names) - {self.axis_name}
        self.partition = partition_workers(
            code.n, self.mesh.shape[self.axis_name])

    @classmethod
    def for_scheme(cls, scheme: str, n: int, *, s: int,
                   seed: int = 0, **kw) -> "CodedAllReduce":
        """Build the all-reduce for a registry scheme name at k = n.

        The registry-driven entry point the parametrized differential
        tests use: any family registered in core.registry (including
        sbm / expander) runs on the device mesh without this module
        knowing its name.
        """
        from ..core import registry

        return cls(registry.make(scheme, k=n, n=n, s=s, seed=seed), **kw)

    @property
    def n_devices(self) -> int:
        return self.partition.n_devices

    def _shard_map(self, fn, *, in_specs, out_specs):
        """shard_map manual over the worker axis only: any other mesh
        axes (data/model/FSDP) stay automatic, so GSPMD keeps sharding
        params and activations over them inside the worker-local body."""
        kw = {"auto": self.auto_axes} if self.auto_axes else {}
        out = shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False, **kw)
        # partial-auto shard_map only lowers under jit (jax 0.4.37's
        # eager impl rejects auto axes), so multi-axis meshes force it
        return jax.jit(out) if self.auto_axes else out

    # ------------------------------------------------------------------
    # per-step decode weights
    # ------------------------------------------------------------------

    def weights_for_masks(self, masks: np.ndarray, method: str = "onestep",
                          *, renorm: bool = True) -> np.ndarray:
        """[S, n] masks -> [S, n] decode weights in ONE decode_batch call.

        The whole trace decodes at once (the PR 2 ClusterSim invariant —
        ``engine.batch_calls`` advances by exactly 1); per-step lookup is
        then a row index.  ``renorm`` applies the trainer's
        exact-decode rescaling w <- w * k / sum(G @ w) per step, skipped
        for all-straggler rows where the denominator vanishes.
        """
        from ..core.decoding import exact_decode_renorm

        masks = np.asarray(masks, dtype=bool)
        if masks.ndim == 1:
            masks = masks[None]
        W = self.engine.decode_batch(masks, method).weights
        return exact_decode_renorm(self.code.G, W) if renorm else W

    def device_weights(self, w: np.ndarray) -> np.ndarray:
        """[n] decode weights -> [D, lanes] (zeros at padding lanes)."""
        return self.partition.scatter(np.asarray(w, dtype=np.float64))

    # ------------------------------------------------------------------
    # training path: shard_map'd loss gradient
    # ------------------------------------------------------------------

    def value_and_grad(self, loss_fn: Callable, *, has_aux: bool = True,
                       jit: bool = True) -> Callable:
        """shard_map'd ``(params, device_batch) -> ((loss, aux), grads)``.

        ``device_batch`` leaves lead with the device dimension D (from
        ``CodedDataPipeline.device_batch_for_step``); decode weights are
        already folded into each row's ``loss_weight``, restricted to
        the device's workers.  Every device runs one backward pass over
        its local rows and the gradients / loss are psum'd over the
        worker axis — the weighted-psum realization of the master
        decode.  Outputs are replicated on every device.

        Scalar aux metrics come back SUMMED over devices (psum); divide
        means (e.g. ``mean_ce``) by ``n_devices`` — every device holds
        the same padded row count so the mean of per-device means is the
        global mean.

        Additive regularizers beyond the per-row weighted sum (the MoE
        load-balance aux: loss = wloss + c*aux with the aux a LOCAL
        batch mean) would psum to c*D*aux_mean; when the aux dict
        carries the bare weighted loss under ``"loss"`` (the repo's
        loss_fn convention), the local objective is recomposed as
        ``wloss + (loss - wloss) * mine / n_real`` where ``mine`` zeroes
        the term on padding-only devices (whose rows are all zero
        tokens — their router statistics are garbage) and ``n_real``
        averages over the devices that hold real workers, so the psum'd
        regularizer matches the fused path.  Exact no-op when
        loss == wloss (dense models, the fp64 differential toys).
        """
        ax = self.axis_name
        # devices holding at least one real worker participate in the
        # additive-regularizer average; padding-only devices are masked.
        # The flag rides in as a worker-sharded input rather than an
        # axis_index lookup: partial-auto meshes can't lower PartitionId
        real_dev = self.partition.lane_mask.any(axis=1)     # [D] host-side
        n_real = max(int(real_dev.sum()), 1)
        flag = jnp.asarray(real_dev.astype(np.float32))     # [D]

        def local(params, dbatch, flag_d):
            batch = jax.tree_util.tree_map(lambda x: x[0], dbatch)
            if has_aux:
                def local_loss(p, b):
                    loss, aux = loss_fn(p, b)
                    base = aux.get("loss") if isinstance(aux, dict) else None
                    if base is not None:   # de-scale additive regularizers
                        loss = base + (loss - base) * flag_d[0] / n_real
                    return loss, aux

                (loss, aux), grads = jax.value_and_grad(
                    local_loss, has_aux=True)(params, batch)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                aux = ()
            loss = jax.lax.psum(loss, ax)
            grads = jax.lax.psum(grads, ax)
            aux = jax.tree_util.tree_map(lambda v: jax.lax.psum(v, ax), aux)
            return (loss, aux), grads

        inner = self._shard_map(local, in_specs=(P(), P(ax), P(ax)),
                                out_specs=P())

        def fn(params, dbatch):
            return inner(params, dbatch, flag)

        return jax.jit(fn) if jit else fn

    def batch_sharding(self) -> NamedSharding:
        """Sharding for device_batch leaves (leading dim D over workers)."""
        return NamedSharding(self.mesh, P(self.axis_name))

    def shard_batch(self, device_batch: dict) -> dict:
        """device_put a [D, ...]-leading batch onto the worker mesh."""
        sh = self.batch_sharding()
        return {k: jax.device_put(jnp.asarray(v), sh)
                for k, v in device_batch.items()}

    # ------------------------------------------------------------------
    # message path: explicit per-worker coded gradients
    # ------------------------------------------------------------------

    def aggregate_messages_batch(self, messages: np.ndarray,
                                 weights: np.ndarray, *,
                                 impl: str = "xla") -> np.ndarray:
        """Decode S steps of per-worker messages on the mesh: [S, P].

        ``messages[j]`` is worker j's coded partial Σ_i G[i,j] g_i
        (shape [n, P]); ``weights`` is the [S, n] decode-weight ensemble
        for S straggler masks.  Each device combines its local lanes
        with the batched weighted-accumulate kernel (`impl` selects
        xla / pallas / pallas_interpret) and the psum over the worker
        axis completes the decode.  Padding lanes carry zero weights so
        they contribute exact zeros.
        """
        from ..kernels import ops

        messages = np.asarray(messages)
        weights = np.atleast_2d(np.asarray(weights))
        if messages.shape[0] != self.code.n or weights.shape[1] != self.code.n:
            raise ValueError(
                f"messages {messages.shape} / weights {weights.shape} do not "
                f"match n={self.code.n}")
        part = self.partition
        msg = part.scatter(messages)                     # [D, L, P]
        wts = part.scatter(weights.T)                    # [D, L, S]
        ax = self.axis_name
        f64 = messages.dtype == np.float64 or weights.dtype == np.float64
        f64 = f64 and jax.config.jax_enable_x64

        def local(msg_d, w_d):
            m = msg_d[0]                                 # [L, P]
            w = w_d[0].T                                 # [S, L]
            if f64:   # dtype-preserving reference path (fp64 differential)
                out = w.astype(m.dtype) @ m
            else:
                out = ops.coded_accumulate_batched(
                    m, w, impl=impl, tiles=self.engine.tiles)
            return jax.lax.psum(out, ax)

        fn = self._shard_map(local, in_specs=(P(ax), P(ax)), out_specs=P())
        return np.asarray(fn(jnp.asarray(msg), jnp.asarray(wts)))

    def aggregate_messages_fused(self, messages: np.ndarray,
                                 masks: np.ndarray, *, renorm: bool = True,
                                 impl: str = "xla") -> np.ndarray:
        """One-step decode fused into the device-local accumulate: [S, P].

        Semantically ``aggregate_messages_batch(messages,
        weights_for_masks(masks, 'onestep', renorm=renorm))`` but the
        [S, n] weight ensemble is never materialized: the one-step
        weights are rank-1 in the mask (w = scale * m, see
        ``DecodeEngine.onestep_scales``), so each device contracts its
        raw 0/1 mask lanes against the local messages in a single
        ``kernels.fused_decode_apply`` pass and applies the per-step
        scale at emission.  The psum over the worker axis completes the
        decode.  Padding lanes scatter ``False`` masks -> exact zeros.
        """
        from ..kernels import ops

        messages = np.asarray(messages)
        masks = np.atleast_2d(np.asarray(masks, dtype=bool))
        if messages.shape[0] != self.code.n or masks.shape[1] != self.code.n:
            raise ValueError(
                f"messages {messages.shape} / masks {masks.shape} do not "
                f"match n={self.code.n}")
        part = self.partition
        scales = self.engine.onestep_scales(masks, renorm=renorm)
        msg = part.scatter(messages)                     # [D, L, P]
        mks = part.scatter(masks.T, fill=False)          # [D, L, S]
        ax = self.axis_name
        f64 = messages.dtype == np.float64 and jax.config.jax_enable_x64
        sc = jnp.asarray(scales if f64 else scales.astype(np.float32))

        def local(msg_d, m_d):
            m = msg_d[0]                                 # [L, P]
            mask_l = m_d[0].T                            # [S, L]
            if f64:   # dtype-preserving reference path (fp64 differential)
                out = (sc[:, None] * mask_l.astype(m.dtype)) @ m
            else:
                out = ops.fused_decode_apply(m, mask_l, sc, impl=impl,
                                             tiles=self.engine.tiles)
            return jax.lax.psum(out, ax)

        fn = self._shard_map(local, in_specs=(P(ax), P(ax)), out_specs=P())
        return np.asarray(fn(jnp.asarray(msg), jnp.asarray(mks)))

    def aggregate_messages(self, messages: np.ndarray, w: np.ndarray, *,
                           impl: str = "xla") -> np.ndarray:
        """Single-mask decode of per-worker messages -> [P]."""
        return self.aggregate_messages_batch(messages, np.asarray(w)[None],
                                             impl=impl)[0]

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"CodedAllReduce(code={self.code.name!r}, n={self.code.n}, "
                f"devices={self.n_devices}, lanes={self.partition.lanes}, "
                f"axis={self.axis_name!r})")
