"""Logical-axis sharding: rules, resolution, and the mesh/rules context.

The models annotate every parameter dimension and key activation with a
*logical* axis name (see models.spec.ParamSpec.axes and
models.*.constrain calls).  This module is the single place those names
meet physical mesh axes:

  * ``DEFAULT_RULES`` maps each logical name to an ordered list of
    candidate mesh-axis assignments (a candidate is a tuple of mesh axis
    names, e.g. ``("pod", "data")`` for the batch dimension).
  * ``logical_to_pspec`` resolves an axes-tuple against a mesh: the
    first candidate whose mesh axes all exist, are not already used by
    another dimension of the same tensor, and whose combined size
    divides the dimension wins; otherwise the dimension is replicated.
    Divisibility fallback is what lets one rule set serve the 512-chip
    production mesh and the 8-device CPU debug mesh.
  * ``use_mesh`` / ``use_rules`` install the active mesh / rule set for
    a region (trace-time context: wrap the jit/lower call).
  * ``constrain`` applies a logical-axes sharding constraint to an
    activation inside a traced function; it is the identity when no
    mesh is active, so the same model code runs single-device.
  * ``param_pspec`` / ``param_shardings`` add the FSDP option: shard a
    still-replicated (non-"layers") parameter dimension over 'data'.

Per-arch overrides come from ``rules_for(cfg)``: the only current
override is ``batch_shard_model`` (attn-free archs can treat the
'model' axis as extra data parallelism).
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "active_mesh",
    "active_rules",
    "constrain",
    "logical_to_pspec",
    "make_coded_mesh",
    "param_pspec",
    "param_shardings",
    "rules_for",
    "use_mesh",
    "use_rules",
]

# Candidate lists are ordered best-first; each candidate is a tuple of
# mesh axis names sharding that one dimension jointly.  Names absent
# from the mapping (or mapped to an empty tuple) are replicated.
Rules = Dict[str, Tuple[Tuple[str, ...], ...]]

DEFAULT_RULES: Rules = {
    # -------- data dims (activations / batch) --------
    "batch": (("pod", "data"), ("data",)),
    "seq": (),
    "seq_shard": (),
    # -------- parameter dims --------
    "vocab": (("model",),),
    "embed": (),          # d_model stays replicated; TP slices heads/mlp
    "mlp": (("model",),),
    "heads": (("model",),),
    "kv": (("model",),),
    "experts": (("model",),),
    "rnn": (("model",),),
    "conv": (),
    "layers": (),         # scan dim: must stay replicated
    # -------- activation-only dims --------
    "act_heads": (("model",),),
    "act_kv": (("model",),),
    "act_mlp": (("model",),),
    "act_experts": (("model",),),
}

# batch_shard_model: the 'model' axis joins data parallelism (attn-free
# archs whose head reshapes can't use TP — rwkv6).  Falls back through
# progressively narrower assignments on divisibility.
_BATCH_SHARD_MODEL_RULES: Rules = dict(
    DEFAULT_RULES,
    batch=(("pod", "data", "model"), ("data", "model"), ("data",)),
)


def rules_for(cfg) -> Rules:
    """Rule set for an architecture config (identity: DEFAULT_RULES
    unless the config carries a distribution override)."""
    if getattr(cfg, "batch_shard_model", False):
        return _BATCH_SHARD_MODEL_RULES
    return DEFAULT_RULES


# --------------------------------------------------------------------------
# mesh construction
# --------------------------------------------------------------------------


def make_coded_mesh(workers: int, *, devices=None,
                    worker_axis: str = "workers",
                    model_axis: str = "model"):
    """2-D (workers × model) mesh composing coded aggregation with TP.

    The leading axis carries the CodedAllReduce worker lanes (manual
    under its shard_map); the trailing axis is left to GSPMD for
    model / FSDP sharding via the logical-axis rules above.  `workers`
    must divide the device count; the model axis gets the rest.  With
    model size 1 this degenerates to the 1-D worker mesh (same device
    order), so one entry point serves both layouts.
    """
    devs = jax.devices() if devices is None else list(devices)
    if workers <= 0 or len(devs) % workers != 0:
        raise ValueError(f"workers={workers} must divide the device count "
                         f"{len(devs)}")
    grid = np.asarray(devs).reshape(workers, len(devs) // workers)
    return Mesh(grid, (worker_axis, model_axis))


# --------------------------------------------------------------------------
# active mesh / rules context
# --------------------------------------------------------------------------

_ACTIVE: Dict[str, Any] = {"mesh": None, "rules": DEFAULT_RULES}


@contextlib.contextmanager
def use_mesh(mesh):
    """Install `mesh` as the active mesh (None = single-device no-op)."""
    prev = _ACTIVE["mesh"]
    _ACTIVE["mesh"] = mesh
    try:
        yield mesh
    finally:
        _ACTIVE["mesh"] = prev


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    """Install a rule set (None keeps the current one)."""
    prev = _ACTIVE["rules"]
    _ACTIVE["rules"] = prev if rules is None else rules
    try:
        yield _ACTIVE["rules"]
    finally:
        _ACTIVE["rules"] = prev


def active_mesh():
    return _ACTIVE["mesh"]


def active_rules() -> Rules:
    return _ACTIVE["rules"]


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------

def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _norm_candidate(cand) -> Tuple[str, ...]:
    return (cand,) if isinstance(cand, str) else tuple(cand)


def _resolve_dim(name: Optional[str], dim: int, sizes: Dict[str, int],
                 used: set, rules: Rules):
    """PartitionSpec entry for one dimension (None = replicated)."""
    if name is None:
        return None
    for cand in rules.get(name, ()):
        cand = _norm_candidate(cand)
        if not cand:
            return None
        if any(a not in sizes or a in used for a in cand):
            continue
        span = math.prod(sizes[a] for a in cand)
        if span <= 1 or dim % span != 0:
            continue
        used.update(cand)
        return cand[0] if len(cand) == 1 else cand
    return None


def logical_to_pspec(axes: Sequence[Optional[str]],
                     shape: Sequence[int],
                     mesh=None,
                     rules: Optional[Rules] = None) -> P:
    """Resolve a logical-axes tuple to a PartitionSpec for `mesh`.

    Mesh / rules default to the active context.  Each dimension takes
    the first rule candidate that (a) names only axes present in the
    mesh, (b) does not reuse a mesh axis already claimed by an earlier
    dimension of this tensor, and (c) evenly divides the dimension.
    """
    mesh = active_mesh() if mesh is None else mesh
    rules = active_rules() if rules is None else rules
    if mesh is None:
        return P()
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries = [_resolve_dim(name, dim, sizes, used, rules)
               for name, dim in zip(axes, shape)]
    return P(*entries)


def param_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                mesh=None, *, fsdp: bool = False,
                rules: Optional[Rules] = None) -> P:
    """PartitionSpec for one parameter; optionally FSDP over 'data'.

    FSDP shards the first still-replicated dimension that divides the
    'data' axis — preferring dimensions that are NOT the 'layers' scan
    dimension (slicing the scan dim would break lax.scan carry layout).
    """
    mesh = active_mesh() if mesh is None else mesh
    if mesh is None:
        return P()
    spec = list(logical_to_pspec(axes, shape, mesh, rules=rules))
    spec += [None] * (len(shape) - len(spec))
    if fsdp:
        sizes = _axis_sizes(mesh)
        data = sizes.get("data", 1)
        taken = {a for e in spec if e is not None
                 for a in (_norm_candidate(e))}
        if data > 1 and "data" not in taken:
            names = list(axes) + [None] * (len(shape) - len(axes))
            for i, (entry, name, dim) in enumerate(zip(spec, names, shape)):
                if entry is None and name != "layers" and dim % data == 0:
                    spec[i] = "data"
                    break
    return P(*spec)


def _is_axes(t) -> bool:
    return isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)


def param_shardings(param_axes, params, mesh=None, *, fsdp: bool = False,
                    rules: Optional[Rules] = None):
    """NamedSharding tree for a parameter tree (abstract or concrete)."""
    mesh = active_mesh() if mesh is None else mesh

    def one(axes, aval):
        return NamedSharding(
            mesh, param_pspec(axes, aval.shape, mesh, fsdp=fsdp, rules=rules))

    return jax.tree_util.tree_map(one, param_axes, params, is_leaf=_is_axes)


# --------------------------------------------------------------------------
# activation constraints
# --------------------------------------------------------------------------

def constrain(x, *axes: Optional[str]):
    """Sharding-constrain an activation by logical axis names.

    Identity when no mesh is active (single-device tests / CPU smoke)
    or when no logical name resolves against the active mesh.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(axes, x.shape, mesh)
    if all(e is None for e in tuple(spec) + (None,) * (x.ndim - len(spec))):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
