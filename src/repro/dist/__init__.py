"""Distribution layer: logical-axis sharding rules, mesh context, and
activation constraints.

Everything the models / optimizer / launchers need to be mesh-agnostic:
parameters and activations name *logical* axes ("vocab", "mlp", "batch",
...) and `repro.dist.sharding` resolves them against the active mesh and
rule set, with divisibility-checked fallbacks.
"""

from .sharding import (  # noqa: F401
    DEFAULT_RULES,
    constrain,
    logical_to_pspec,
    param_pspec,
    param_shardings,
    rules_for,
    use_mesh,
    use_rules,
)
from . import sharding  # noqa: F401

__all__ = [
    "DEFAULT_RULES",
    "constrain",
    "logical_to_pspec",
    "param_pspec",
    "param_shardings",
    "rules_for",
    "use_mesh",
    "use_rules",
    "sharding",
]
