"""Distribution layer: sharding rules, mesh context, coded all-reduce.

Two public surfaces:

* `repro.dist.sharding` — everything the models / optimizer / launchers
  need to be mesh-agnostic: parameters and activations name *logical*
  axes ("vocab", "mlp", "batch", ...) resolved against the active mesh
  and rule set with divisibility-checked fallbacks (use_mesh /
  use_rules / constrain / param_shardings ...).
* `repro.dist.coded_allreduce` — the paper's Algorithm 1/2 on real
  devices: CodedAllReduce pins the n code columns to device lanes
  (partition_workers / DevicePartition) and decodes as a weighted psum
  over the 1-D worker mesh (docs/architecture.md §9).
"""

from .sharding import (  # noqa: F401
    DEFAULT_RULES,
    constrain,
    logical_to_pspec,
    param_pspec,
    param_shardings,
    rules_for,
    use_mesh,
    use_rules,
)
from .coded_allreduce import (  # noqa: F401
    CodedAllReduce,
    DevicePartition,
    make_worker_mesh,
    partition_workers,
)
from . import coded_allreduce  # noqa: F401
from . import sharding  # noqa: F401

__all__ = [
    "DEFAULT_RULES",
    "CodedAllReduce",
    "DevicePartition",
    "coded_allreduce",
    "constrain",
    "logical_to_pspec",
    "make_worker_mesh",
    "param_pspec",
    "param_shardings",
    "partition_workers",
    "rules_for",
    "use_mesh",
    "use_rules",
    "sharding",
]
