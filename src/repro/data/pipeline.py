"""Coded data pipeline.

Deterministic, stateless synthetic token streams: the tokens of (step,
task, row) are a pure function of (seed, step, task, row), so

  * every worker assigned task i generates *identical* data with zero
    communication (replication comes free),
  * resume-after-restart needs only the step counter (checkpointed),
  * elastic re-coding just changes the (worker -> task) table.

The stream is learnable (noisy affine-recurrence tokens) so end-to-end
convergence tests are meaningful, not pure noise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..core.assignment import CodedAssignment

__all__ = ["PipelineConfig", "CodedDataPipeline"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    rows_per_slot: int            # T: examples per task slot
    seed: int = 0
    mode: str = "markov"          # markov (learnable) | uniform


def _task_tokens(seed: int, step: int, task: int, rows: int, seq: int,
                 vocab: int, mode: str) -> np.ndarray:
    """Deterministic tokens for one task at one step: [rows, seq+1]."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, task & 0x7FFFFFFF]))
    if mode == "uniform":
        return rng.integers(0, vocab, (rows, seq + 1), dtype=np.int64)
    # learnable stream: a GLOBAL affine recurrence over a small alphabet
    #   x_{t+1} = (a * x_t + c + eps_t) mod A,   eps in {0, 1}
    # (a, c) depend only on the seed, so the mapping is stationary across
    # steps/tasks and a small model visibly learns it within ~10 steps.
    A = min(64, vocab)
    g = np.random.default_rng(np.random.SeedSequence([seed]))
    a = int(g.integers(2, 8))
    c = int(g.integers(0, A))
    x0 = rng.integers(0, A, (rows, 1))
    noise = rng.integers(0, 2, (rows, seq + 1))
    out = np.empty((rows, seq + 1), dtype=np.int64)
    out[:, 0:1] = x0
    for t in range(1, seq + 1):
        out[:, t] = (a * out[:, t - 1] + c + noise[:, t]) % A
    return out


class CodedDataPipeline:
    """Produces physical batches laid out [worker, slot, row] -> flat B."""

    def __init__(self, assignment: CodedAssignment, cfg: PipelineConfig):
        self.asg = assignment
        self.cfg = cfg
        self._lane_mask_cache: Dict[tuple, np.ndarray] = {}

    def reshard_for(self, assignment: CodedAssignment) -> "CodedDataPipeline":
        """Rebind the stream to a new assignment (elastic re-code / churn).

        Token content is a pure function of ``(cfg.seed, step, task)``, so
        resharding moves tasks between workers without dropping or
        double-counting any shard: the same logical examples reappear in
        the new layout, and a resharded pipeline at the same step yields
        the same per-task rows as an uninterrupted one.
        """
        return CodedDataPipeline(assignment, self.cfg)

    @property
    def physical_batch(self) -> int:
        return self.asg.n * self.asg.slots * self.cfg.rows_per_slot

    @property
    def unique_examples(self) -> int:
        return self.asg.k * self.cfg.rows_per_slot

    def batch_for_step(self, step: int, decode_w: np.ndarray
                       ) -> Dict[str, np.ndarray]:
        """Materialize the physical batch + coded loss weights for a step.

        decode_w: (n,) decode weights for this step's straggler mask.
        """
        cfg, asg = self.cfg, self.asg
        T, S, V = cfg.rows_per_slot, cfg.seq_len, cfg.vocab
        B = self.physical_batch
        tokens = np.zeros((B, S), dtype=np.int32)
        labels = np.zeros((B, S), dtype=np.int32)

        # generate each unique task once, then fan out to its replicas
        cache: Dict[int, np.ndarray] = {}
        row = 0
        for j in range(asg.n):
            for t in range(asg.slots):
                task = int(asg.task_ids[j, t])
                if task >= 0:
                    if task not in cache:
                        cache[task] = _task_tokens(cfg.seed, step, task, T, S,
                                                   V, cfg.mode)
                    data = cache[task]
                    tokens[row : row + T] = data[:, :-1]
                    labels[row : row + T] = data[:, 1:]
                row += T

        weights = self.asg.row_weights(decode_w, T)
        return {"tokens": tokens, "labels": labels, "loss_weight": weights}

    def device_batch_for_step(self, step: int, decode_w: np.ndarray,
                              partition) -> Dict[str, np.ndarray]:
        """The coded batch re-laid-out as per-device microbatches.

        partition: a dist.coded_allreduce.DevicePartition for this
        assignment's n workers.  Every leaf leads with the device
        dimension D; each device's microbatch holds the rows of its
        ``lanes`` workers in lane order (R = lanes * slots * T rows per
        device).  Padding lanes (n not a multiple of D) carry zero
        tokens with zero loss_weight, so all devices see identical
        shapes and contribute exact zeros to the coded psum.
        """
        if partition.n != self.asg.n:
            raise ValueError(f"partition has n={partition.n} workers, "
                             f"assignment has n={self.asg.n}")
        flat = self.batch_for_step(step, decode_w)
        rpw = self.asg.slots * self.cfg.rows_per_slot
        D, L = partition.n_devices, partition.lanes
        ids = partition.worker_ids                          # [D, L]
        src = np.where(ids >= 0, ids, 0)[..., None] * rpw + np.arange(rpw)
        src = src.reshape(-1)                               # [D*L*rpw]
        row_ok = np.repeat(partition.lane_mask.reshape(-1), rpw)
        out: Dict[str, np.ndarray] = {}
        for name, x in flat.items():
            v = x[src]
            v[~row_ok] = 0
            out[name] = v.reshape((D, L * rpw) + x.shape[1:])
        if not partition.lane_mask.all():
            # ragged n/D: zero the padding-lane rows out of the models'
            # per-row CE (they already carry zero loss_weight, but the
            # mean_ce metric would otherwise average in garbage rows —
            # the trainer rescales by padded_n/n to undo the dilution).
            # Step-independent -> built once per (partition, seq) shape.
            seq = flat["labels"].shape[1]
            key = (D, L, partition.n, rpw, seq)
            lm = self._lane_mask_cache.get(key)
            if lm is None:
                lm = np.ascontiguousarray(np.broadcast_to(
                    row_ok.reshape(D, L * rpw)[..., None],
                    (D, L * rpw, seq)), dtype=np.float32)
                self._lane_mask_cache[key] = lm
            out["loss_mask"] = lm
        return out

    def uncoded_batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """The k*T unique examples with uniform mean weights (baseline)."""
        cfg, asg = self.cfg, self.asg
        T, S, V = cfg.rows_per_slot, cfg.seq_len, cfg.vocab
        k = asg.k
        tokens = np.zeros((k * T, S), dtype=np.int32)
        labels = np.zeros((k * T, S), dtype=np.int32)
        for task in range(k):
            data = _task_tokens(cfg.seed, step, task, T, S, V, cfg.mode)
            tokens[task * T : (task + 1) * T] = data[:, :-1]
            labels[task * T : (task + 1) * T] = data[:, 1:]
        w = np.full((k * T,), 1.0 / (k * T), dtype=np.float32)
        return {"tokens": tokens, "labels": labels, "loss_weight": w}

    def state(self) -> dict:
        return {"seed": self.cfg.seed}  # stateless beyond the step counter
