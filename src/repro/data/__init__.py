"""Coded data pipeline."""

from .pipeline import CodedDataPipeline, PipelineConfig  # noqa: F401
