"""Coded data pipeline.

Public surface: ``PipelineConfig`` and ``CodedDataPipeline`` — maps a
``CodedAssignment`` to physical batches: ``batch_for_step`` stamps the
per-row loss weights w_j G[i,j] / (kT) of the decode-as-loss-
reweighting identity (docs/architecture.md 2.1), ``uncoded_batch_for_
step`` is the plain-DP reference, and ``device_batch_for_step`` lays
rows out per device lane for dist_mode="coded_allreduce" (padding
lanes zeroed and masked out of the CE).
"""

from .pipeline import CodedDataPipeline, PipelineConfig  # noqa: F401
