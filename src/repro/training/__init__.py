"""Coded training loop + elasticity."""

from .train_loop import (  # noqa: F401
    CodedTrainConfig,
    CodedTrainer,
    explicit_master_decode_grads,
)
