"""Coded training loop + elasticity + adaptive control.

Public surface: ``CodedTrainConfig`` / ``CodedTrainer`` (fused and
coded_allreduce dist modes, trace-driven co-simulation via ``trace=`` /
``sync_policy=``, elastic re-coding on hard faults, AdaptiveCoder
re-coding via ``controller=``) and ``explicit_master_decode_grads``
(the literal Algorithm-1 master-side decode the differential tests
compare against).
"""

from .train_loop import (  # noqa: F401
    CodedTrainConfig,
    CodedTrainer,
    explicit_master_decode_grads,
)
