"""Coded training loop: gradient coding as a first-class data-parallel
feature.

Per step:
  1. the straggler model samples a non-straggler mask (deterministic in
     (seed, step) -> derived identically on every host, no communication);
  2. the decoder turns (G, mask) into decode weights w;
  3. the pipeline materializes the physical batch with per-row loss
     weights  w_j * G[i,j] / (k*T)  — the decode-as-loss-reweighting
     identity (docs/architecture.md §2.1), so XLA's ordinary gradient all-reduce IS
     the coded aggregation;
  4. one jitted train_step (grad + AdamW) under the active mesh.

Elasticity: on hard faults the worker set shrinks, the code is rebuilt
for n' (O(n s)), the assignment/pipeline remapped, and training continues
without losing optimizer state.

Membership churn: pass ``churn=`` (a sim.traces.ChurnScenario) and worker
arrival/departure becomes a trained-through event — departures shrink
through the elastic path above (or, under ``recovery='restart'``, restore
the last checkpoint onto the post-event fleet and recompute the lost
steps), arrivals grow through the same rebuild, and the data pipeline
reshards without dropping or double-counting a shard (the stream is pure
in (seed, step, task)).  Checkpoints carry code/controller/churn metadata
so a killed-then-restarted run equals an uninterrupted one
(docs/architecture.md §11).

Co-simulation hook: pass ``trace=`` (a sim.traces.LatencyTrace) and the
trainer derives each step's straggler mask from the trace through a sync
policy (``sync_policy=``, default a 1.5s deadline) instead of the
straggler model, and logs the modelled wall-clock per step
(``step_time`` / cumulative ``sim_time`` in history) — the ClusterSim
dataflow riding the real training loop.

Adaptive control: pass ``controller=`` (a ``repro.control.AdaptiveCoder``
or anything with its observe/decide protocol) and the trainer feeds the
controller each step's mask / latencies / realized decode error, then
applies the actions it returns — ``set_s`` re-codes through the elastic
rebuild path (code, assignment, pipeline, engine, allreduce, step_fn),
``set_decoder`` / ``set_deadline`` recompute the trace schedule.  The
system picks its own operating point on the paper's frontier
(docs/adaptive.md).

Pipelined decoding: ``staleness=1`` removes the per-step decode barrier —
step t applies the weights decoded from step t-1's mask (re-masked by
today's stragglers, whose messages never arrived) and today's decode is
issued after the async step dispatch, overlapping the backprop.  Step 0
warm-starts from an all-alive decode; elastic re-codes, ``set_s`` and
``set_decoder`` flush the in-flight weights (docs/architecture.md §10).

Distributed execution: ``dist_mode="coded_allreduce"`` replaces step 3-4
with the shard_map path of ``dist.coded_allreduce`` (docs/architecture.md §9): the
batch is sliced into per-device microbatches (each device computes only
its workers' assigned task-gradients), and decoding happens as the
weighted psum over the 1-D worker mesh.  With a trace attached, the
whole run's masks are mapped through the policy up front and decoded in
ONE DecodeEngine.decode_batch call (the ClusterSim invariant); per-step
weights are then row lookups.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from ..core import assignment as ASG
from ..core import decoding as DEC
from ..core import registry as REG
from ..core.engine import DecodeEngine
from ..data import CodedDataPipeline, PipelineConfig
from ..dist import use_mesh
from ..models import Model
from ..optim import OptConfig, adamw_update, init_opt_state, make_schedule
from ..runtime import FaultInjector, StragglerModel, NoStragglers

__all__ = ["CodedTrainConfig", "CodedTrainer", "explicit_master_decode_grads"]


@dataclasses.dataclass
class CodedTrainConfig:
    code: str = "bgc"            # any core.registry family name
    code_params: dict = dataclasses.field(default_factory=dict)
    #   family extras (e.g. sbm blocks/intra) — forwarded to the
    #   constructor on every (re)build, elastic re-codes included
    n_workers: int = 8           # number of DP groups (paper's n); k = n
    s: int = 2                   # tasks per worker
    decoder: str = "onestep"     # onestep | optimal | algorithmic | ignore
    decoder_iters: int = 4       # algorithmic decoder iterations
    rows_per_slot: int = 1       # T examples per task slot
    seq_len: int = 128
    steps: int = 50
    seed: int = 0
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    keep_last: int = 2
    log_every: int = 10
    exact_decode_renorm: bool = True  # rescale w so sum(G@w)=k (unbiased-ish)
    decode_cache_size: int = 512      # mask->weights LRU entries (engine)
    dist_mode: str = "fused"          # fused | coded_allreduce (docs/architecture.md §9)
    optimal_impl: str = "auto"        # least-squares strategy (engine):
    #   auto/gram = masked-Gram normal equations (fast default);
    #   pinv = exact min-norm pinv, the exact-oracle opt-in
    staleness: int = 0                # decode pipelining depth: step t
    #   applies weights decoded from step t-staleness's mask (masked by
    #   today's stragglers), overlapping decode with backprop.  0 =
    #   synchronous.  Stale weights flush on elastic re-code / set_s /
    #   set_decoder (docs/architecture.md §10).


class CodedTrainer:
    def __init__(self, model: Model, tcfg: CodedTrainConfig,
                 straggler_model: Optional[StragglerModel] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 mesh=None, trace=None, sync_policy=None,
                 controller=None, churn=None, recovery: str = "elastic"):
        self.model = model
        self.tcfg = tcfg
        self.straggler = straggler_model or NoStragglers()
        self.faults = fault_injector or FaultInjector()
        self.mesh = mesh
        # AdaptiveCoder protocol (repro.control): observe(step, mask,
        # latencies, decode_err) each step, decide(step) at the top of
        # the next one; returned actions are applied through the same
        # rebuild path as elastic faults (docs/adaptive.md)
        self.controller = controller
        if tcfg.dist_mode not in ("fused", "coded_allreduce"):
            raise ValueError(f"dist_mode {tcfg.dist_mode!r} not in "
                             f"('fused', 'coded_allreduce')")
        if tcfg.dist_mode == "coded_allreduce" and mesh is not None:
            from ..dist.coded_allreduce import WORKER_AXIS
            if WORKER_AXIS not in getattr(mesh, "axis_names", ()):
                raise ValueError(
                    "dist_mode='coded_allreduce' with mesh= needs a mesh "
                    f"carrying the {WORKER_AXIS!r} axis (see "
                    "dist.sharding.make_coded_mesh); got axes "
                    f"{tuple(getattr(mesh, 'axis_names', ()))}")
        if tcfg.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {tcfg.staleness}")
        # code builds draw from a counter-derived rng stream so the N-th
        # (re)build is deterministic in (seed, N): a restored run rebuilds
        # bit-identical codes (see maybe_restore) and an elastic/churn
        # re-code is reproducible across trainer instances
        self._builds = 0
        # trace-driven co-simulation (sim.cluster): trace rows -> masks +
        # modelled step times through a sync policy
        self.trace = trace
        self.sync_policy = None
        self._policy_state = None
        self.sim_time = 0.0
        # membership churn (sim.traces.ChurnScenario): worker arrival /
        # departure trained through — departures shrink-re-code (or
        # restore a checkpoint under recovery='restart'), arrivals grow
        self.churn = churn
        self.recovery = recovery
        self._live_ids = None
        self._churn_cursor = 0
        self.churn_log: list = []
        if churn is not None:
            if trace is not None:
                raise ValueError("churn= and trace= are exclusive: a "
                                 "ChurnScenario carries its own latency "
                                 "trace")
            if recovery not in ("elastic", "restart"):
                raise ValueError(f"recovery {recovery!r} not in "
                                 f"('elastic', 'restart')")
            if recovery == "restart" and not (tcfg.ckpt_dir
                                              and tcfg.ckpt_every):
                raise ValueError("recovery='restart' needs ckpt_dir and "
                                 "ckpt_every (restores the last checkpoint "
                                 "on membership change)")
            if churn.n0 != tcfg.n_workers:
                raise ValueError(f"churn scenario starts with n0="
                                 f"{churn.n0} workers, config has "
                                 f"n_workers={tcfg.n_workers}")
            self._live_ids = churn.initial_ids()
            from ..sim.cluster import make_policy
            self.sync_policy = make_policy(sync_policy or "deadline")
        elif trace is not None:
            from ..sim.cluster import make_policy
            if trace.n != tcfg.n_workers:
                raise ValueError(f"trace has n={trace.n} workers, config "
                                 f"has n_workers={tcfg.n_workers}")
            self.sync_policy = make_policy(sync_policy or "deadline")
            if controller is not None:
                from ..sim.cluster import DeadlinePolicy
                if not isinstance(self.sync_policy, DeadlinePolicy):
                    # the controller prices/emits set_deadline actions;
                    # silently dropping them would desync its tracked
                    # operating point from the trainer's reality
                    raise ValueError(
                        "controller= with trace= requires a DeadlinePolicy "
                        f"sync policy (its deadline is a controller "
                        f"actuator); got {type(self.sync_policy).__name__}")
        elif sync_policy is not None:
            raise ValueError("sync_policy requires trace= or churn=")
        self._build_code(tcfg.n_workers)
        self._step_fn = self._make_step_fn()
        self.history: list = []
        # per-step applied decode weights (the staleness tests assert
        # the staleness=0 stream is bitwise the synchronous stream)
        self.weight_log: list = []

    def _mask_and_time(self, step: int, n: int):
        """(mask, modelled step time | None) — trace-driven when a trace
        is attached, else the straggler model with no time model."""
        if self.churn is not None:
            # latencies of the LIVE capacity slots, speed-scaled; the
            # policy sees an n-wide fleet whose identity churns
            lat = self.churn.latencies_at(step, self._live_ids)
            mask, t, self._policy_state = self.sync_policy.step(
                lat, self._policy_state)
            self.sim_time += t
            return mask, t
        if self.trace is None:
            return self.straggler.sample(step, n), None
        if self._trace_masks is not None:   # dist path: precomputed schedule
            i = step % self._trace_masks.shape[0]
            t = float(self._trace_times[i])
            self.sim_time += t
            return self._trace_masks[i], t
        lat = self.trace.latencies[step % self.trace.steps]
        if n != lat.shape[0]:   # elastic shrink: simulate surviving workers
            lat = lat[:n]
        mask, t, self._policy_state = self.sync_policy.step(
            lat, self._policy_state)
        self.sim_time += t
        return mask, t

    # ------------- code / assignment / pipeline -------------
    def _build_code(self, n: int) -> None:
        t = self.tcfg
        fam = REG.get(t.code)     # actionable KeyError on unknown schemes
        fam.require_decoder(t.decoder)
        rng = np.random.default_rng([t.seed, 0xC0DE, self._builds])
        self._builds += 1
        self.code = fam.make(k=n, n=n, s=min(t.s, n), rng=rng,
                             **t.code_params)
        # one engine per live code; rebuilt (cache and all) on elastic
        # re-coding since the weights are a function of G
        self.engine = DecodeEngine(self.code, iters=t.decoder_iters,
                                   cache_size=t.decode_cache_size,
                                   optimal_impl=t.optimal_impl)
        self.assignment = ASG.build_assignment(self.code)
        if getattr(self, "pipeline", None) is not None:
            # reshard: same (seed, step, task)-pure stream, new layout —
            # no shard dropped or double-counted across the re-code
            self.pipeline = self.pipeline.reshard_for(self.assignment)
        else:
            self.pipeline = CodedDataPipeline(
                self.assignment,
                PipelineConfig(vocab=self.model.cfg.vocab, seq_len=t.seq_len,
                               rows_per_slot=t.rows_per_slot, seed=t.seed))
        self.allreduce = None
        self._trace_masks = self._trace_times = self._trace_weights = None
        # elastic re-code invalidation: weights decoded against the OLD
        # G are meaningless for the new code — drop the whole pipeline
        # (the next step warm-starts from an all-alive decode)
        self._pending_w = None
        if t.dist_mode == "coded_allreduce":
            from ..dist.coded_allreduce import CodedAllReduce
            kw = {"mesh": self.mesh} if self.mesh is not None else {}
            self.allreduce = CodedAllReduce(
                self.code, engine=self.engine, assignment=self.assignment,
                **kw)
            if self.trace is not None:
                self._prepare_trace_schedule()

    def _prepare_trace_schedule(self) -> None:
        """Distributed path: map the WHOLE trace through the sync policy
        and decode every step's mask in ONE decode_batch call (the
        ClusterSim invariant — ``engine.batch_calls`` advances by 1 per
        trace/engine, never once per step).  Recomputed on elastic
        re-coding since the engine is rebuilt with the code."""
        lat = self.trace.latencies
        n = self.assignment.n
        if lat.shape[1] != n:   # elastic shrink: surviving workers
            lat = lat[:, :n]
        masks, times, _ = self.sync_policy.apply(lat)
        self._trace_masks = masks
        self._trace_times = times
        self._trace_weights = self.allreduce.weights_for_masks(
            masks, method=self.tcfg.decoder,
            renorm=self.tcfg.exact_decode_renorm)

    # ------------- adaptive re-coding (repro.control) -------------
    def _apply_action(self, action) -> None:
        """Apply one controller action (docs/adaptive.md).

        ``set_s`` rebuilds code / assignment / pipeline / engine /
        allreduce AND the jitted step_fn — exactly the elastic-fault
        path, so partition-derived closures (ce_fix, D) can never go
        stale.  ``set_decoder`` / ``set_deadline`` leave the code alone
        (no resample) but recompute the distributed trace schedule,
        whose masks/weights depend on both.
        """
        t = self.tcfg
        if action.kind == "set_s":
            self.tcfg = dataclasses.replace(t, s=int(action.value))
            self._build_code(self.assignment.n)
            self._step_fn = self._make_step_fn()
            return
        if action.kind == "set_decoder":
            decoder = str(action.value)
            REG.get(t.code).require_decoder(decoder)
            self.tcfg = dataclasses.replace(t, decoder=decoder)
            self._pending_w = None   # in-flight weights used the old decoder
            if self._trace_masks is not None:
                self._prepare_trace_schedule()
            return
        if action.kind == "set_deadline":
            from ..sim.cluster import DeadlinePolicy
            if isinstance(self.sync_policy, DeadlinePolicy):
                self.sync_policy = dataclasses.replace(
                    self.sync_policy, deadline=float(action.value))
                if self._trace_masks is not None:
                    self._prepare_trace_schedule()
            # without a trace no latencies are observed, so controllers
            # never emit deadline actions; the trace+non-deadline-policy
            # combination is rejected in __init__
            return
        raise ValueError(f"unknown controller action kind {action.kind!r}")

    # ------------- jitted step -------------
    def _make_step_fn(self) -> Callable:
        model, opt_cfg = self.model, self.tcfg.opt
        sched = make_schedule(opt_cfg.schedule
                              if model.cfg.schedule == "cosine"
                              else model.cfg.schedule,
                              opt_cfg.lr, opt_cfg.total_steps,
                              opt_cfg.warmup_steps, opt_cfg.min_ratio,
                              opt_cfg.decay_frac)

        if self.tcfg.dist_mode == "coded_allreduce":
            vg = self.allreduce.value_and_grad(model.loss_fn, jit=False)
            part = self.allreduce.partition
            D = part.n_devices
            # padding-lane rows are masked out of the per-row CE (see
            # device_batch_for_step) but still counted by row.mean();
            # padded_n/n undoes the dilution so mean_ce matches fused
            ce_fix = part.padded_n / part.n

            def step_fn(params, opt_state, batch):
                (loss, metrics), grads = vg(params, batch)
                # psum sums scalar aux over devices: means divide back
                metrics = dict(metrics)
                for key in ("mean_ce", "aux_loss"):
                    if key in metrics:
                        metrics[key] = metrics[key] / D
                if "mean_ce" in metrics:
                    metrics["mean_ce"] = metrics["mean_ce"] * ce_fix
                lr = sched(opt_state["step"])
                params, opt_state, om = adamw_update(params, grads, opt_state,
                                                     opt_cfg, lr)
                metrics = dict(metrics, **om)
                return params, opt_state, metrics

            return jax.jit(step_fn, donate_argnums=(0, 1))

        def step_fn(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            lr = sched(opt_state["step"])
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg, lr)
            metrics = dict(metrics, **om)
            return params, opt_state, metrics

        return jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------- decode weights -------------
    def decode_weights_for(self, mask: np.ndarray) -> np.ndarray:
        """mask -> decode weights via the engine's LRU cache.

        Repeated masks (adversarial stragglers, stable deadline cohorts,
        the no-straggler fast path) decode once per distinct mask.
        """
        t = self.tcfg
        w = self.engine.decode(mask, method=t.decoder)
        if t.exact_decode_renorm:
            w = DEC.exact_decode_renorm(self.code.G, w)
        return w

    # ------------- state init / restore -------------
    def init_state(self, rng_key=None):
        key = jax.random.PRNGKey(self.tcfg.seed) if rng_key is None else rng_key
        params = self.model.init(key)
        opt_state = init_opt_state(params)
        return {"params": params, "opt": opt_state}

    def maybe_restore(self, state):
        """Restore the latest checkpoint under ckpt_dir, if any.

        Applies the checkpoint's metadata, not just its arrays: the code
        is rebuilt at the checkpointed (family, params, s, n, decoder)
        operating point — and at the checkpointed build counter, so the
        rebuilt G is bit-identical to the one the interrupted run was
        using — the churn cursor / live worker set / sim clock resume,
        and the controller reloads its estimator state.  A restored run
        is therefore equal to an uninterrupted one, which is what the
        restart-recovery equivalence test asserts.
        """
        t = self.tcfg
        if not (t.ckpt_dir and latest_step(t.ckpt_dir) is not None):
            return state, 0
        state, meta = restore_checkpoint(t.ckpt_dir, state)
        code_meta = meta.get("code")
        if code_meta:
            self.tcfg = dataclasses.replace(
                t, code=str(code_meta["family"]),
                code_params=dict(code_meta.get("params", {})),
                s=int(code_meta["s"]), decoder=str(code_meta["decoder"]))
            # rewind the build counter so the rebuild replays the exact
            # rng draw the checkpointed code came from
            self._builds = max(int(code_meta.get("builds", 1)) - 1, 0)
            self._build_code(int(code_meta["n"]))
            self._step_fn = self._make_step_fn()
        self.sim_time = float(meta.get("sim_time", self.sim_time))
        if self.churn is not None and "live_ids" in meta:
            self._live_ids = np.asarray(meta["live_ids"], dtype=np.int64)
            self._churn_cursor = int(meta.get("churn_cursor", 0))
        ctrl_meta = meta.get("controller")
        if ctrl_meta and hasattr(self.controller, "load_state_dict"):
            self.controller.load_state_dict(ctrl_meta)
        return state, int(meta.get("next_step", 0))

    def _ckpt_metadata(self, next_step: int) -> dict:
        """Everything a fresh process needs to resume equal to an
        uninterrupted run (see maybe_restore)."""
        live = self.tcfg
        meta = {
            "next_step": int(next_step),
            "code": {"family": live.code,
                     "params": dict(live.code_params),
                     "s": int(self.code.s),
                     "n": int(self.assignment.n),
                     "decoder": live.decoder,
                     "builds": int(self._builds)},
            "sim_time": float(self.sim_time),
        }
        if self.churn is not None:
            meta["live_ids"] = [int(i) for i in self._live_ids]
            meta["churn_cursor"] = int(self._churn_cursor)
        if self.controller is not None and hasattr(self.controller,
                                                   "state_dict"):
            meta["controller"] = self.controller.state_dict()
        return meta

    # ------------- churn events -------------
    def _consume_churn(self, step: int, state, ckpt):
        """Apply every scenario event scheduled at `step` (top-of-step).

        The cursor is monotonic: events consumed once never reapply, so
        a restart rewind replays *steps* (recomputing lost work on the
        current fleet) without replaying *events*.  Departures shrink
        the fleet — elastic re-code, or checkpoint restore + rewind
        under recovery='restart' (gang-scheduling semantics: ANY
        membership change restarts the job).  Arrivals grow through the
        same rebuild path.  Returns (state, step, recoded).
        """
        events = self.churn.events
        fired = []
        while (self._churn_cursor < len(events)
               and events[self._churn_cursor].step <= step):
            # a restart rewind leaves the cursor PAST the triggering
            # event, so replayed steps reach here with nothing to fire
            fired.append(events[self._churn_cursor])
            self._churn_cursor += 1
        if not fired:
            return state, step, False
        live = self._live_ids
        for ev in fired:
            live = self.churn.apply_event(live, ev)
            self.churn_log.append({"step": step, "kind": ev.kind,
                                   "n_live": int(live.size)})
        if live.size < 2:
            raise RuntimeError(f"churn left {live.size} worker(s) alive at "
                               f"step {step}; need >= 2")
        self._live_ids = live
        if self.recovery == "restart":
            # the new incarnation restores the last checkpoint (or cold
            # starts) on the post-event fleet and recomputes lost steps;
            # the (seed, step, task)-pure pipeline makes the redo exact
            if ckpt is not None:
                ckpt.wait()   # in-flight saves land before we look
            if latest_step(self.tcfg.ckpt_dir) is not None:
                state, meta = restore_checkpoint(self.tcfg.ckpt_dir, state)
                step = int(meta.get("next_step", 0))
            else:
                state = self.init_state()
                step = 0
            self.churn_log[-1]["restart_to"] = step
        self._build_code(len(self._live_ids))
        self._step_fn = self._make_step_fn()
        return state, step, True

    # ------------- main loop -------------
    def run(self, state=None, start_step: int = 0,
            steps: Optional[int] = None) -> Dict[str, Any]:
        t = self.tcfg
        if state is None:
            state = self.init_state()
        if start_step == 0:
            # fires for explicitly-passed state too: a fresh process
            # handed init_state() must still resume from ckpt_dir (the
            # old `state is None` guard silently restarted from scratch)
            state, start_step = self.maybe_restore(state)
            t = self.tcfg   # maybe_restore may have applied code metadata
        # default = finish the configured job: a restored run completes
        # the REMAINING steps (explicit steps= keeps count semantics)
        steps = max(t.steps - start_step, 0) if steps is None else steps
        ckpt = (AsyncCheckpointer(t.ckpt_dir, t.keep_last)
                if t.ckpt_dir and t.ckpt_every else None)
        n0 = self.assignment.n

        step = start_step
        end = start_step + steps
        with use_mesh(self.mesh):
            while step < end:
                # --- membership churn -> elastic re-code / restart ---
                if self.churn is not None:
                    state, step, _ = self._consume_churn(step, state, ckpt)

                # --- hard faults -> elastic re-code ---
                plan = self.faults.check(step)
                if plan is not None:
                    alive = self.faults.alive_count(n0)
                    self._build_code(max(alive, 2))
                    # step_fn closures capture partition-derived scalars
                    # (ce_fix, D) — rebuild with the new code
                    self._step_fn = self._make_step_fn()

                # --- controller decision -> adaptive re-code ---
                if self.controller is not None:
                    action = self.controller.decide(step)
                    if action is not None:
                        self._apply_action(action)

                # --- straggler mask -> decode weights -> coded batch ---
                mask, step_time = self._mask_and_time(step, self.assignment.n)
                deferred = None
                if t.staleness > 0:
                    # pipelined: apply weights decoded `staleness` steps
                    # ago, re-masked by TODAY's stragglers (their
                    # messages never arrived); today's decode is issued
                    # after the jitted step dispatch so it overlaps the
                    # backprop (docs/architecture.md §10)
                    if self._pending_w is None:   # warm start / post-flush
                        ones = np.ones(self.assignment.n, dtype=bool)
                        self._pending_w = [self.decode_weights_for(ones)
                                           ] * t.staleness
                    w = self._pending_w.pop(0) * mask
                    deferred = mask
                elif self._trace_weights is not None:
                    w = self._trace_weights[step % self._trace_weights.shape[0]]
                else:
                    w = self.decode_weights_for(mask)
                self.weight_log.append(np.array(w))

                if self.controller is not None:
                    # realized decode error of the weights in effect —
                    # the calibration signal closing the control loop
                    derr = float(((self.code.G @ w - 1.0) ** 2).sum()
                                 ) / self.code.k
                    lat = None
                    if self.churn is not None:
                        lat = self.churn.latencies_at(step, self._live_ids)
                    elif self.trace is not None:
                        lat = self.trace.latencies[step % self.trace.steps]
                        lat = lat[:mask.shape[0]]
                    self.controller.observe(step, mask, latencies=lat,
                                            decode_err=derr)
                if self.allreduce is not None:
                    batch_np = self.pipeline.device_batch_for_step(
                        step, w, self.allreduce.partition)
                    batch = self.allreduce.shard_batch(batch_np)
                else:
                    batch_np = self.pipeline.batch_for_step(step, w)
                    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

                state["params"], state["opt"], metrics = self._step_fn(
                    state["params"], state["opt"], batch)

                if deferred is not None:
                    # decode of step t's own mask, issued while the step
                    # above executes asynchronously — consumed at t+st.
                    # The trace-schedule path reuses its precomputed row
                    # (still ONE decode_batch per trace)
                    if self._trace_weights is not None:
                        S = self._trace_weights.shape[0]
                        self._pending_w.append(self._trace_weights[step % S])
                    else:
                        self._pending_w.append(
                            self.decode_weights_for(deferred))

                if step % max(t.log_every, 1) == 0 or step == end - 1:
                    # read the LIVE config: controller actions may have
                    # replaced self.tcfg since the loop started
                    live = self.tcfg
                    rec = {"step": step,
                           "loss": float(metrics["loss"]),
                           "mean_ce": float(metrics["mean_ce"]),
                           "grad_norm": float(metrics["grad_norm"]),
                           "stragglers": int((~mask).sum()),
                           "decode_err": float(
                               DEC.err1(self.code.G[:, mask],
                                        DEC.default_rho(self.code.k,
                                                        int(mask.sum()),
                                                        self.code.s))
                               if live.decoder == "onestep" else
                               DEC.err(self.code.G[:, mask])) / self.code.k,
                           "n_workers": self.assignment.n,
                           "s": self.code.s,
                           "decoder": live.decoder}
                    if step_time is not None:
                        rec["step_time"] = float(step_time)
                        rec["sim_time"] = float(self.sim_time)
                    self.history.append(rec)

                if ckpt and t.ckpt_every and (step + 1) % t.ckpt_every == 0:
                    ckpt.save(step + 1, state, self._ckpt_metadata(step + 1))

                step += 1

        if ckpt:
            ckpt.close()
        return {"state": state, "history": self.history,
                "final_step": end}


def explicit_master_decode_grads(model: Model, params, trainer: CodedTrainer,
                                 step: int, mask: np.ndarray):
    """Reference implementation of the paper's master-side decode.

    Computes each worker's coded partial gradient SEPARATELY (sum over its
    assigned task shards with G coefficients), then combines them with the
    decode weights on the 'master' — the literal Algorithm-1/2 dataflow.
    Used by tests to prove the fused loss-reweighting path is identical.
    """
    t = trainer.tcfg
    asg = trainer.assignment
    w = trainer.decode_weights_for(mask)
    batch = trainer.pipeline.batch_for_step(step, np.ones(asg.n))
    T = t.rows_per_slot
    rows_per_worker = asg.slots * T

    def worker_loss(params, j):
        lo = j * rows_per_worker
        sl = {k: jnp.asarray(v[lo: lo + rows_per_worker])
              for k, v in batch.items()}
        # per-row coefficients G[i,j] / (k*T): the worker's coded combo
        coeff = np.repeat(
            np.where(asg.task_ids[j] >= 0, asg.coeffs[j], 0.0), T) / (asg.k * T)
        sl["loss_weight"] = jnp.asarray(coeff)  # f64 host-side; the model
        # casts at the device boundary (f32 unless x64 is enabled)
        loss, _ = model.loss_fn(params, sl)
        return loss

    partials = [jax.grad(worker_loss)(params, j) for j in range(asg.n)]
    # promote to at least fp32 but follow fp64 grads (x64 differential
    # tests compare the shard_map path against this oracle at 1e-10)
    flat = [jnp.concatenate(
        [g.reshape(-1).astype(jnp.promote_types(g.dtype, jnp.float32))
         for g in jax.tree_util.tree_leaves(p)])
            for p in partials]
    stacked = jnp.stack(flat)                      # [n, P]
    decoded = jnp.asarray(w, stacked.dtype) @ stacked
    return decoded, w
