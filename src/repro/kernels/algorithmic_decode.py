"""Algorithmic decoder (Lemma 12) Pallas kernels.

    u_t = u_{t-1} - A A^T u_{t-1} / nu,   u_0 = 1_k,   nu >= ||A||_2^2

||u_t||^2 decreases monotonically to err(A): t = 1 is the one-step
regime, t -> inf the optimal decode — the decoding-cost/accuracy dial of
the paper.  Realized as two fused masked matvec kernels per iterate
(A = G . diag(mask) is never materialized — the mask rides along):

    t = (G diag(m))^T u        [r-side reduction over k blocks]
    u' = u - (G diag(m)) t/nu  [k-side reduction over r blocks]

Each kernel streams G tile-by-tile through VMEM with an fp32 accumulator;
2 matvecs = 4 k*n FLOPs per iteration, bandwidth-bound like the one-step
decoder but iterated.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["algorithmic_decode", "algorithmic_iterate"]


def _atu_kernel(g_ref, m_ref, u_ref, o_ref, acc_ref, *, nk: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)           # [bk, bn]
    u = u_ref[...]                               # [1, bk]
    acc_ref[...] += jax.lax.dot_general(
        u, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [1, bn]

    @pl.when(i == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...] * m_ref[...]   # mask the straggler cols


def _axpy_kernel(g_ref, t_ref, u_ref, o_ref, acc_ref, *, nn: int, inv_nu: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)           # [bk, bn]
    t = t_ref[...]                               # [1, bn] (already masked)
    acc_ref[...] += jax.lax.dot_general(
        g, t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bk, 1]

    @pl.when(j == nn - 1)
    def _emit():
        o_ref[...] = u_ref[...].reshape(o_ref.shape) - inv_nu * acc_ref[...]


def algorithmic_iterate(G, mask, u, nu, *, bk=512, bn=512, interpret=False):
    """One Lemma-12 iterate u -> (I - A A^T / nu) u with A = G diag(mask)."""
    k, n = G.shape
    bk = min(bk, k)
    bn = min(bn, n)
    nk = math.ceil(k / bk)
    nn = math.ceil(n / bn)
    pk, pn = nk * bk - k, nn * bn - n
    g = jnp.pad(G.astype(jnp.float32), ((0, pk), (0, pn))) \
        if (pk or pn) else G.astype(jnp.float32)
    m = jnp.pad(mask.astype(jnp.float32), (0, pn))[None] if pn else \
        mask.astype(jnp.float32)[None]
    up = jnp.pad(u.astype(jnp.float32), (0, pk))[None] if pk else \
        u.astype(jnp.float32)[None]

    t = pl.pallas_call(
        functools.partial(_atu_kernel, nk=nk),
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda jj, ii: (ii, jj)),
            pl.BlockSpec((1, bn), lambda jj, ii: (0, jj)),
            pl.BlockSpec((1, bk), lambda jj, ii: (0, ii)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda jj, ii: (0, jj)),
        out_shape=jax.ShapeDtypeStruct((1, nn * bn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g, m, up)

    u_new = pl.pallas_call(
        functools.partial(_axpy_kernel, nn=nn, inv_nu=float(1.0 / nu)),
        grid=(nk, nn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda ii, jj: (ii, jj)),
            pl.BlockSpec((1, bn), lambda ii, jj: (0, jj)),
            pl.BlockSpec((1, bk), lambda ii, jj: (0, ii)),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda ii, jj: (ii, 0)),
        out_shape=jax.ShapeDtypeStruct((nk * bk, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g, t, up)
    return u_new[:k, 0]


@functools.partial(jax.jit,
                   static_argnames=("nu", "iters", "bk", "bn", "interpret"))
def algorithmic_decode(
    G: jax.Array,                 # [k, n]
    mask: jax.Array,              # [n]
    nu: float,
    iters: int,
    *,
    bk: int = 512,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """u_iters from u_0 = 1_k.  ||u_t||^2 upper-bounds err(A) (Lemma 12)."""
    k = G.shape[0]
    u = jnp.ones((k,), jnp.float32)
    for _ in range(iters):
        u = algorithmic_iterate(G, mask, u, nu, bk=bk, bn=bn,
                                interpret=interpret)
    return u
