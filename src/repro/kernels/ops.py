"""Jit'd public wrappers for the Pallas kernels.

`impl` convention (shared with ArchConfig.attn_impl / seq_impl):
    "xla"              : pure-jnp reference path (production CPU dry-run)
    "pallas"           : compiled Pallas kernel (TPU target)
    "pallas_interpret" : kernel body interpreted on CPU (tests / this box)

Every wrapper is shape/dtype-polymorphic and numerically validated
against repro.kernels.ref in tests/test_kernels_pallas.py.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .algorithmic_decode import algorithmic_decode as _algorithmic_pallas
from .batched_decode import (
    batched_algorithmic_decode as _batched_algorithmic_pallas,
    batched_masked_gram as _batched_masked_gram_pallas,
    batched_onestep_decode as _batched_onestep_pallas,
    batched_onestep_decode_ell as _batched_onestep_ell_pallas,
)
from .coded_accumulate import (
    coded_accumulate as _accumulate_pallas,
    coded_accumulate_batched as _accumulate_batched_pallas,
)
from .flash_attention import flash_attention as _flash_pallas
from .fused_decode_apply import fused_decode_apply as _fused_apply_pallas
from .onestep_decode import onestep_decode as _onestep_pallas
from .rglru_scan import rglru_scan as _rglru_pallas
from .rwkv6_wkv import rwkv6_wkv as _wkv_pallas

__all__ = [
    "attention", "rglru_scan", "rwkv6_wkv",
    "coded_accumulate", "coded_accumulate_batched", "fused_decode_apply",
    "onestep_decode", "algorithmic_decode",
    "batched_onestep_decode", "batched_onestep_decode_ell",
    "batched_algorithmic_decode", "batched_masked_gram",
]


def _interp(impl: str) -> bool:
    return impl == "pallas_interpret"


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, q_offset=0,
              impl="pallas", bq=128, bk=128):
    if impl == "xla":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  softcap=softcap, q_offset=q_offset)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         softcap=softcap, q_offset=q_offset,
                         bq=bq, bk=bk, interpret=_interp(impl))


def rglru_scan(u, log_a, h0=None, *, impl="pallas", chunk=128, bd=128):
    if impl == "xla":
        return _ref.rglru_scan_ref(u, log_a, h0)
    return _rglru_pallas(u, log_a, h0, chunk=chunk, bd=bd,
                         interpret=_interp(impl))


def rwkv6_wkv(r, k, v, w, u, s0=None, *, impl="pallas", chunk=32):
    if impl == "xla":
        return _ref.wkv_ref(r, k, v, w, u, s0)
    return _wkv_pallas(r, k, v, w, u, s0, chunk=chunk,
                       interpret=_interp(impl))


def coded_accumulate(grads, weights, *, impl="pallas", bp=2048):
    if impl == "xla":
        return _ref.coded_accumulate_ref(grads, weights)
    return _accumulate_pallas(grads, weights, bp=bp, interpret=_interp(impl))


def coded_accumulate_batched(grads, weights, *, impl="pallas",
                             bb=128, bk=512, bp=2048):
    """out [B, P] = weights [B, k] @ grads [k, P] — the coded
    all-reduce's on-device weighted accumulate over a weight-row batch."""
    if impl == "xla":
        return _ref.coded_accumulate_batched_ref(grads, weights)
    return _accumulate_batched_pallas(grads, weights, bb=bb, bk=bk, bp=bp,
                                      interpret=_interp(impl))


def fused_decode_apply(messages, masks, scales, *, impl="pallas",
                       bb=128, bl=512, bp=2048):
    """out [B, P] = diag(scales) (masks [B, L] @ messages [L, P]) — the
    one-step decode fused into the gradient accumulate: one pass over
    the worker messages, no [B, L] weight ensemble materialized."""
    if impl == "xla":
        return _ref.fused_decode_apply_ref(messages, masks, scales)
    return _fused_apply_pallas(messages, masks, scales, bb=bb, bl=bl, bp=bp,
                               interpret=_interp(impl))


def onestep_decode(G, mask, rho, *, impl="pallas", bk=512, bn=512):
    if impl == "xla":
        return _ref.onestep_decode_ref(G, mask, rho)
    return _onestep_pallas(G, mask, float(rho), bk=bk, bn=bn,
                           interpret=_interp(impl))


def algorithmic_decode(G, mask, nu, iters, *, impl="pallas", bk=512, bn=512):
    if impl == "xla":
        A = G * mask[None, :].astype(G.dtype)
        return _ref.algorithmic_decode_ref(A, float(nu), int(iters))
    return _algorithmic_pallas(G, mask, float(nu), int(iters), bk=bk, bn=bn,
                               interpret=_interp(impl))


def batched_onestep_decode(G, masks, rhos, *, impl="pallas",
                           bb=128, bk=256, bn=256):
    """V [B, k] = diag(rhos) (masks @ G^T): Algorithm 1 over a mask batch."""
    if impl == "xla":
        return _ref.batched_onestep_decode_ref(G, masks, rhos)
    return _batched_onestep_pallas(G, masks, rhos, bb=bb, bk=bk, bn=bn,
                                   interpret=_interp(impl))


def batched_onestep_decode_ell(ell_idx, ell_val, masks, rhos, *,
                               impl="pallas", bb=128, bk=512):
    """Sparse batched Algorithm 1 over the row-ELL packing of G."""
    if impl == "xla":
        gathered = masks.astype(jnp.float32)[:, ell_idx.reshape(-1)]
        B = masks.shape[0]
        v = (gathered.reshape(B, *ell_idx.shape)
             * ell_val.astype(jnp.float32)[None]).sum(axis=2)
        return rhos.astype(jnp.float32)[:, None] * v
    return _batched_onestep_ell_pallas(ell_idx, ell_val, masks, rhos,
                                       bb=bb, bk=bk, interpret=_interp(impl))


def batched_masked_gram(gram, masks, *, impl="pallas", bb=8, bi=128, bj=128):
    """Mg [B, n, n] = diag(m_b) Gram diag(m_b) — the normal-equations
    ensemble of the batched least-squares decoder (DecodeEngine optimal
    path on kernel backends)."""
    if impl == "xla":
        m = masks.astype(jnp.float32)
        return m[:, :, None] * m[:, None, :] * gram.astype(jnp.float32)[None]
    return _batched_masked_gram_pallas(gram, masks, bb=bb, bi=bi, bj=bj,
                                       interpret=_interp(impl))


def batched_algorithmic_decode(G, masks, nus, iters, *, impl="pallas",
                               bb=128, bk=256, bn=256,
                               return_weights=False):
    """U_iters [B, k] of the Lemma-12 iteration, one row per mask.

    return_weights=True additionally returns the decode weights [B, n].
    """
    if impl == "xla":
        Gf = G.astype(jnp.float32)
        m = masks.astype(jnp.float32)
        inv = jnp.where(nus > 0, 1.0 / nus, 1.0).astype(jnp.float32)[:, None]
        U = jnp.ones((m.shape[0], Gf.shape[0]), jnp.float32)
        X = jnp.zeros_like(m)
        for _ in range(int(iters)):
            T = (U @ Gf) * m
            X = X + T * inv
            U = U - (T @ Gf.T) * inv
        return (U, X) if return_weights else U
    return _batched_algorithmic_pallas(G, masks, nus, int(iters),
                                       bb=bb, bk=bk, bn=bn,
                                       interpret=_interp(impl),
                                       return_weights=return_weights)
