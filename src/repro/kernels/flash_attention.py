"""Flash attention Pallas TPU kernel (online softmax, blocked VMEM tiling).

Design for the TPU memory hierarchy (docs/architecture.md §5):
  * grid = (B, H, Sq/bq, Sk/bk); the last dim is sequential ("arbitrary")
    so the fp32 running max / denominator / accumulator for one q-block
    live in VMEM scratch across kv-block iterations;
  * q/k/v blocks are streamed HBM -> VMEM by the BlockSpec pipeline with
    MXU-aligned tiles (bq, bk multiples of 128 at production shapes;
    head_dim 64/128 rides the lane dimension);
  * GQA is expressed in the k/v index_map (h -> h // group) — no
    materialized head broadcast;
  * causal / sliding-window block skipping: fully-masked kv blocks are
    skipped via pl.when, halving prefill work at 32k.

Validated against ref.attention_ref in interpret mode on CPU (the TPU is
the target, not the runtime — per the brief).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, qpos_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, bq: int, bk: int, nk: int, sq: int, sk: int,
                 causal: bool, window: int, softcap: float, scale: float):
    """One (batch, head, q-block) x sequential kv-block program."""
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos_row = qpos_ref[0]                      # [bq] absolute positions
    qpos = jnp.broadcast_to(qpos_row[:, None], (bq, bk))
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: a kv block is dead if it is entirely in the causal
    # future or entirely outside the sliding window for every q row.
    q_lo, q_hi = qpos_row[0], qpos_row[bq - 1]
    k_lo = kj * bk
    live = jnp.asarray(True)
    if causal:
        live = k_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(
            live, kj * bk + bk - 1 >= q_lo - window + 1)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        ok = kpos < sk                                  # kv padding
        if causal:
            ok = jnp.logical_and(ok, qpos >= kpos)
        if window > 0:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]                             # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,                 # [B, Sq, H, dh]
    k: jax.Array,                 # [B, Sk, Kv, dh]
    v: jax.Array,                 # [B, Sk, Kv, dh]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int | jax.Array = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked online-softmax attention.  Returns [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    if H % Kv:
        raise ValueError(f"H {H} % Kv {Kv}")
    G = H // Kv
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))

    # layout: heads leading so blocks are contiguous [s, dh] tiles
    qt = jnp.moveaxis(q, 2, 1)                    # [B, H, Sq, dh]
    kt = jnp.moveaxis(k, 2, 1)                    # [B, Kv, Sk, dh]
    vt = jnp.moveaxis(v, 2, 1)

    nq = math.ceil(Sq / bq)
    nk = math.ceil(Sk / bk)
    pq, pk = nq * bq - Sq, nk * bk - Sk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))

    # absolute q positions as a dynamic input (supports traced decode
    # offsets); padded rows get positions past Sq — outputs are trimmed.
    qpos = (jnp.asarray(q_offset, jnp.int32)
            + jnp.arange(nq * bq, dtype=jnp.int32))[None]   # [1, nq*bq]

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, sq=Sq, sk=Sk, causal=causal,
        window=window, softcap=softcap, scale=dh ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # denominator l
            pltpu.VMEM((bq, dh), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, qpos)

    out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)                # [B, Sq, H, dh]
