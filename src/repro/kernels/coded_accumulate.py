"""Coded weighted-accumulate Pallas kernel.

A worker's message to the master is  sum_i G[i,j] * g_i  over its
assigned task gradients; the master's decode is  sum_j w_j * m_j  over
worker messages.  Both are the same primitive: a weighted reduction of k
stacked flat gradient chunks,

    out[p] = sum_i w[i] * grads[i, p].

TPU adaptation: realized as a [1, k] @ [k, bp] MXU matvec per parameter
tile — the weights tile stays resident in VMEM while gradient chunks
stream HBM -> VMEM (arithmetic intensity 2 FLOP / 4 bytes: purely
bandwidth-bound, so the tiling maximizes the streaming run length bp).

The batched variant (``coded_accumulate_batched``) is the coded
all-reduce's on-device hot path: one device holds its local workers'
messages [k, P] and combines them against a whole [B, k] ensemble of
decode-weight rows (every step of a trace, or every mask of a
Monte-Carlo cell) in one launch — a [bb, bk] @ [bk, bp] MXU tile per
grid cell, messages streamed once and reused across the weight batch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["coded_accumulate", "coded_accumulate_batched"]


def _acc_kernel(w_ref, g_ref, o_ref):
    w = w_ref[...]                           # [1, k]
    g = g_ref[...].astype(jnp.float32)       # [k, bp]
    o_ref[...] = jax.lax.dot_general(
        w, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [1, bp]


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def coded_accumulate(
    grads: jax.Array,             # [k, P] stacked flat task gradients
    weights: jax.Array,           # [k]
    *,
    bp: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """out = weights @ grads, tiled over the parameter dimension."""
    k, P = grads.shape
    bp = min(bp, P)
    np_ = math.ceil(P / bp)
    pad = np_ * bp - P
    g = jnp.pad(grads, ((0, 0), (0, pad))) if pad else grads
    w = weights.astype(jnp.float32)[None]    # [1, k]

    out = pl.pallas_call(
        _acc_kernel,
        grid=(np_,),
        in_specs=[
            pl.BlockSpec((1, k), lambda p: (0, 0)),
            pl.BlockSpec((k, bp), lambda p: (0, p)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda p: (0, p)),
        out_shape=jax.ShapeDtypeStruct((1, np_ * bp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w, g)
    return out[0, :P]


def _acc_batch_kernel(w_ref, g_ref, o_ref, acc_ref, *, nk: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]                           # [bb, bk]
    g = g_ref[...].astype(jnp.float32)       # [bk, bp]
    acc_ref[...] += jax.lax.dot_general(
        w, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [bb, bp]

    @pl.when(i == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def _pad2(x, r, c):
    pr, pc = r - x.shape[0], c - x.shape[1]
    return jnp.pad(x, ((0, pr), (0, pc))) if pr or pc else x


@functools.partial(jax.jit, static_argnames=("bb", "bk", "bp", "interpret"))
def coded_accumulate_batched(
    grads: jax.Array,             # [k, P] stacked flat task gradients
    weights: jax.Array,           # [B, k] one weight row per mask / step
    *,
    bb: int = 128,
    bk: int = 512,
    bp: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """out = weights @ grads: every weight row decodes the same stack.

    [B, P] fp32.  Grid (batch, param-tile, k-tile) with the contracted
    k dimension innermost/sequential into an fp32 VMEM accumulator —
    the gradient stack streams HBM -> VMEM once per param tile and is
    reused by the whole weight-row block.
    """
    k, Pp = grads.shape
    B = weights.shape[0]
    bb, bk, bp = min(bb, B), min(bk, k), min(bp, Pp)
    nb, nk, np_ = map(math.ceil, (B / bb, k / bk, Pp / bp))
    g = _pad2(grads.astype(jnp.float32), nk * bk, np_ * bp)
    w = _pad2(weights.astype(jnp.float32), nb * bb, nk * bk)

    out = pl.pallas_call(
        functools.partial(_acc_batch_kernel, nk=nk),
        grid=(nb, np_, nk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda b, p, i: (b, i)),
            pl.BlockSpec((bk, bp), lambda b, p, i: (i, p)),
        ],
        out_specs=pl.BlockSpec((bb, bp), lambda b, p, i: (b, p)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, np_ * bp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bp), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(w, g)
    return out[:B, :Pp]
