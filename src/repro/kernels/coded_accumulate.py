"""Coded weighted-accumulate Pallas kernel.

A worker's message to the master is  sum_i G[i,j] * g_i  over its
assigned task gradients; the master's decode is  sum_j w_j * m_j  over
worker messages.  Both are the same primitive: a weighted reduction of k
stacked flat gradient chunks,

    out[p] = sum_i w[i] * grads[i, p].

TPU adaptation: realized as a [1, k] @ [k, bp] MXU matvec per parameter
tile — the weights tile stays resident in VMEM while gradient chunks
stream HBM -> VMEM (arithmetic intensity 2 FLOP / 4 bytes: purely
bandwidth-bound, so the tiling maximizes the streaming run length bp).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["coded_accumulate"]


def _acc_kernel(w_ref, g_ref, o_ref):
    w = w_ref[...]                           # [1, k]
    g = g_ref[...].astype(jnp.float32)       # [k, bp]
    o_ref[...] = jax.lax.dot_general(
        w, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [1, bp]


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def coded_accumulate(
    grads: jax.Array,             # [k, P] stacked flat task gradients
    weights: jax.Array,           # [k]
    *,
    bp: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """out = weights @ grads, tiled over the parameter dimension."""
    k, P = grads.shape
    bp = min(bp, P)
    np_ = math.ceil(P / bp)
    pad = np_ * bp - P
    g = jnp.pad(grads, ((0, 0), (0, pad))) if pad else grads
    w = weights.astype(jnp.float32)[None]    # [1, k]

    out = pl.pallas_call(
        _acc_kernel,
        grid=(np_,),
        in_specs=[
            pl.BlockSpec((1, k), lambda p: (0, 0)),
            pl.BlockSpec((k, bp), lambda p: (0, p)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda p: (0, p)),
        out_shape=jax.ShapeDtypeStruct((1, np_ * bp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w, g)
    return out[0, :P]
