"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernel (run with
interpret=True on CPU, compiled on TPU) is asserted against in
tests/test_kernels_*.py.  They are deliberately written in the most
obvious O(n^2)/sequential form — clarity over speed.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref", "rglru_scan_ref", "wkv_ref",
    "coded_accumulate_ref", "coded_accumulate_batched_ref",
    "fused_decode_apply_ref",
    "onestep_decode_ref", "algorithmic_decode_ref",
    "batched_onestep_decode_ref", "batched_algorithmic_decode_ref",
]

_NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, q_offset: int = 0) -> jax.Array:
    """Naive GQA attention.  q [B,Sq,H,dh], k/v [B,Sk,Kv,dh] -> [B,Sq,H,dh]."""
    B, Sq, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, dh)
    s = jnp.einsum("bqngd,bknd->bngqk", qg, k,
                   preferred_element_type=jnp.float32) * dh ** -0.5
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = s + jnp.where(ok, 0.0, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngqk,bknd->bqngd", p, v)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def rglru_scan_ref(u: jax.Array, log_a: jax.Array,
                   h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential linear recurrence h_t = exp(log_a_t) * h_{t-1} + u_t.

    u, log_a: [B, S, D] float32; h0 optional [B, D].  Returns h [B, S, D].
    """
    B, S, D = u.shape
    h_init = jnp.zeros((B, D), jnp.float32) if h0 is None else h0

    def step(h, inp):
        la_t, u_t = inp
        h = jnp.exp(la_t) * h + u_t
        return h, h

    xs = (jnp.moveaxis(log_a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(u.astype(jnp.float32), 1, 0))
    _, hs = jax.lax.scan(step, h_init, xs)
    return jnp.moveaxis(hs, 0, 1)


def wkv_ref(r, k, v, w, u, s0=None):
    """Sequential RWKV6 WKV recurrence (see models.rwkv6.wkv_scan_ref).

    r,k,v,w: [B,T,H,dh]; u: [H,dh].  Returns (o [B,T,H,dh], s [B,H,dh,dh]).
    """
    B, T, H, dh = r.shape
    s = jnp.zeros((B, H, dh, dh), jnp.float32) if s0 is None else \
        s0.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), s


def coded_accumulate_ref(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """Sum_i w_i * g_i over stacked task gradients.  grads [k, P], w [k]."""
    return jnp.einsum("k,kp->p", weights.astype(jnp.float32),
                      grads.astype(jnp.float32))


def coded_accumulate_batched_ref(grads: jax.Array,
                                 weights: jax.Array) -> jax.Array:
    """weights @ grads per weight row.  grads [k, P], weights [B, k].

    Computes in fp32 like the kernel, but follows the inputs up to fp64
    when x64 is enabled (the differential oracle path).
    """
    dt = jnp.promote_types(jnp.promote_types(grads.dtype, weights.dtype),
                           jnp.float32)
    return jnp.einsum("bk,kp->bp", weights.astype(dt), grads.astype(dt))


def fused_decode_apply_ref(messages: jax.Array, masks: jax.Array,
                           scales: jax.Array) -> jax.Array:
    """out[b] = scales[b] * (masks[b] @ messages): the one-step decode
    folded into the accumulate.  messages [L, P], masks [B, L],
    scales [B] -> [B, P].

    Computes in fp32 like the kernel, but follows the inputs up to fp64
    when x64 is enabled (the differential oracle path).
    """
    dt = jnp.promote_types(jnp.promote_types(messages.dtype, scales.dtype),
                           jnp.float32)
    w = scales.astype(dt)[:, None] * masks.astype(dt)
    return w @ messages.astype(dt)


def onestep_decode_ref(G: jax.Array, mask: jax.Array, rho: float) -> jax.Array:
    """Algorithm 1: v = rho * A @ 1_r = rho * G @ mask.  G [k,n], mask [n]."""
    return rho * (G.astype(jnp.float32) @ mask.astype(jnp.float32))


def algorithmic_decode_ref(A: jax.Array, nu: float, iters: int) -> jax.Array:
    """Lemma 12 iterates: u_{t} = (I - A A^T / nu)^t 1_k.  Returns u_iters."""
    k = A.shape[0]
    u = jnp.ones((k,), jnp.float32)
    A = A.astype(jnp.float32)
    for _ in range(iters):
        u = u - A @ (A.T @ u) / nu
    return u


def batched_onestep_decode_ref(G: jax.Array, masks: jax.Array,
                               rhos: jax.Array) -> jax.Array:
    """V[b] = rho_b * G @ m_b.  G [k,n], masks [B,n], rhos [B] -> [B,k]."""
    V = masks.astype(jnp.float32) @ G.astype(jnp.float32).T
    return rhos.astype(jnp.float32)[:, None] * V


def batched_algorithmic_decode_ref(G: jax.Array, masks: jax.Array,
                                   nus: jax.Array, iters: int) -> jax.Array:
    """Per-mask Lemma-12 iterates.  Returns U [B, k]."""
    G = G.astype(jnp.float32)
    m = masks.astype(jnp.float32)
    inv = jnp.where(nus > 0, 1.0 / nus, 1.0).astype(jnp.float32)[:, None]
    U = jnp.ones((m.shape[0], G.shape[0]), jnp.float32)
    for _ in range(iters):
        T = (U @ G) * m
        U = U - (T @ G.T) * inv
    return U
