"""Pallas API compatibility shims.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(~0.5); support both so the kernels run on whichever jax the container
ships.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
