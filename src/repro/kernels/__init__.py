"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel is `pl.pallas_call` + explicit BlockSpec VMEM tiling,
validated in interpret mode against the pure-jnp oracles in ref.py:

    flash_attention     32k-prefill attention (online softmax, block skip)
    rglru_scan          RG-LRU diagonal linear recurrence (recurrentgemma)
    rwkv6_wkv           chunked data-dependent-decay WKV (rwkv6)
    coded_accumulate    worker-side sum_i G[i,j] g_i / master-side decode
    onestep_decode      Algorithm 1: v = rho * A 1_r (streaming row-sum)
    algorithmic_decode  Lemma 12 iterates u_t (decode accuracy/cost dial)
    batched_decode      the batched-grid variants of the two decoders
                        (one launch per [B, n] mask ensemble, dense and
                        row-ELL sparse) powering core.engine.DecodeEngine

Use via repro.kernels.ops with impl in {"xla", "pallas",
"pallas_interpret"}.
"""

from . import ops  # noqa: F401
from . import ref  # noqa: F401
from .tiles import DEFAULT_TILES, TileConfig  # noqa: F401
from .algorithmic_decode import algorithmic_decode, algorithmic_iterate  # noqa: F401
from .batched_decode import (  # noqa: F401
    batched_algorithmic_decode,
    batched_onestep_decode,
    batched_onestep_decode_ell,
)
from .coded_accumulate import coded_accumulate  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .onestep_decode import onestep_decode  # noqa: F401
from .rglru_scan import rglru_scan  # noqa: F401
from .rwkv6_wkv import rwkv6_wkv  # noqa: F401
