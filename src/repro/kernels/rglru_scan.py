"""RG-LRU diagonal linear recurrence Pallas kernel.

    h_t = exp(log_a_t) * h_{t-1} + u_t        (per channel)

TPU adaptation: the recurrence is diagonal, so channels are embarrassingly
parallel — the grid tiles (batch, channel/128) as "parallel" dims and
walks the sequence in chunks as the sequential ("arbitrary") dim, carrying
h in a VMEM scratch tile between chunk programs.  Inside a chunk the scan
runs on the VPU over a [chunk, 128] register tile; HBM traffic is exactly
one read of (u, log_a) and one write of h — the memory-optimal schedule
for a bandwidth-bound op (arithmetic intensity ~ 3 FLOP / 12 bytes).

The production train path uses the XLA associative scan (O(log T) depth);
this kernel is the fused-decode / long-sequence form where the carry
never leaves VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["rglru_scan"]


def _rglru_kernel(u_ref, la_ref, h0_ref, h_ref, carry_ref, *,
                  chunk: int, seq_len: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)    # [1, bd]

    u = u_ref[0].astype(jnp.float32)        # [chunk, bd]
    la = la_ref[0].astype(jnp.float32)      # [chunk, bd]
    a = jnp.exp(la)

    def step(t, carry):
        h_prev, out = carry
        h_t = a[t] * h_prev + u[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h_t, t, 0)
        return h_t, out

    h_last, out = jax.lax.fori_loop(
        0, chunk, step,
        (carry_ref[0], jnp.zeros((chunk, u.shape[1]), jnp.float32)))
    carry_ref[0] = h_last
    h_ref[0] = out.astype(h_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "bd", "interpret"))
def rglru_scan(
    u: jax.Array,                 # [B, S, D] gated input (fp32)
    log_a: jax.Array,             # [B, S, D] log decay (<= 0)
    h0: jax.Array | None = None,  # [B, D] carried state
    *,
    chunk: int = 128,
    bd: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked linear recurrence.  Returns h [B, S, D] (fp32)."""
    B, S, D = u.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    chunk = min(chunk, S)
    bd = min(bd, D)
    ns = math.ceil(S / chunk)
    nd = math.ceil(D / bd)
    ps, pd = ns * chunk - S, nd * bd - D
    uf = u.astype(jnp.float32)
    laf = log_a.astype(jnp.float32)
    if ps or pd:
        uf = jnp.pad(uf, ((0, 0), (0, ps), (0, pd)))
        laf = jnp.pad(laf, ((0, 0), (0, ps), (0, pd)))
    h0f = jnp.pad(h0.astype(jnp.float32), ((0, 0), (0, pd)))[:, None]

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk, seq_len=S),
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, 1, bd), lambda b, d, s: (b, 0, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, ns * chunk, nd * bd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(uf, laf, h0f)
    return out[:, :S, :D]
