"""One-step decoder (Algorithm 1) Pallas kernel.

    v = rho * A @ 1_r = rho * G @ mask      (mask = non-straggler indicator)

This is the paper's linear-time decoder: a masked row-sum over the
function-assignment matrix.  The kernel tiles G into [bk, bn] VMEM blocks
and reduces over the worker dimension sequentially in an fp32 VMEM
accumulator — it never materializes the submatrix A (the paper's
"streaming" property: Section 2, one-step decoding "allows us to avoid
putting the entire matrix A into memory").
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["onestep_decode"]


def _onestep_kernel(g_ref, m_ref, o_ref, acc_ref, *, nn: int, rho: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)       # [bk, bn]
    m = m_ref[...]                           # [1, bn]
    acc_ref[...] += jax.lax.dot_general(
        g, m, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # [bk, 1]

    @pl.when(j == nn - 1)
    def _emit():
        o_ref[...] = rho * acc_ref[...]


@functools.partial(jax.jit, static_argnames=("rho", "bk", "bn", "interpret"))
def onestep_decode(
    G: jax.Array,                 # [k, n] assignment matrix
    mask: jax.Array,              # [n] bool/0-1 non-straggler indicator
    rho: float,
    *,
    bk: int = 512,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """v = rho * G @ mask.  Returns [k] fp32."""
    k, n = G.shape
    bk = min(bk, k)
    bn = min(bn, n)
    nk = math.ceil(k / bk)
    nn = math.ceil(n / bn)
    pk, pn = nk * bk - k, nn * bn - n
    g = jnp.pad(G.astype(jnp.float32), ((0, pk), (0, pn))) \
        if (pk or pn) else G.astype(jnp.float32)
    m = jnp.pad(mask.astype(jnp.float32), (0, pn)) if pn else \
        mask.astype(jnp.float32)
    m = m[None]                              # [1, n]

    out = pl.pallas_call(
        functools.partial(_onestep_kernel, nn=nn, rho=float(rho)),
        grid=(nk, nn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nk * bk, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g, m)
    return out[:k, 0]
