"""Batched decode Pallas kernels: one launch, B straggler masks.

The scalar kernels (onestep_decode.py / algorithmic_decode.py) decode a
single mask per launch — fine for a training step, wasteful for the
Monte-Carlo ensembles behind Figs. 2-5 and the delta-sweeps, where the
same G is decoded against thousands of masks.  These kernels add a
leading batch grid dimension so every mask in a [B, n] ensemble is
decoded in one launch:

    batched_onestep_decode      V = diag(rho) * M G^T          [B, k]
    batched_onestep_decode_ell  same, via the row-ELL packing of G
                                (reads B*k*rmax mask entries instead of
                                streaming B*k*n dense zeros)
    batched_algorithmic_decode  U_t per mask, Lemma-12 iterates [B, k]
    batched_masked_gram         diag(m_b) Gram diag(m_b)       [B, n, n]
                                (the normal-equations ensemble feeding
                                the batched least-squares decoder)

All kernels tile (batch, k) in parallel and reduce sequentially over
the contracted dimension in an fp32 VMEM accumulator; G is never
replicated per mask — the mask rides along as a [bb, bn] block, exactly
the streaming property the paper claims for one-step decoding, amortized
across the batch.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = [
    "batched_onestep_decode",
    "batched_onestep_decode_ell",
    "batched_algorithmic_decode",
    "batched_algorithmic_iterate",
    "batched_masked_gram",
]


def _pad2(x, r, c):
    pr, pc = r - x.shape[0], c - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


# --------------------------------------------------------------------------
# dense batched one-step:  V[b, i] = rho_b * sum_j G[i, j] m[b, j]
# --------------------------------------------------------------------------

def _onestep_batch_kernel(m_ref, g_ref, r_ref, o_ref, acc_ref, *, nn: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m = m_ref[...]                               # [bb, bn]
    g = g_ref[...].astype(jnp.float32)           # [bk, bn]
    acc_ref[...] += jax.lax.dot_general(
        m, g, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bb, bk]

    @pl.when(j == nn - 1)
    def _emit():
        o_ref[...] = acc_ref[...] * r_ref[...]   # [bb, 1] rho broadcast


@functools.partial(jax.jit, static_argnames=("bb", "bk", "bn", "interpret"))
def batched_onestep_decode(
    G: jax.Array,          # [k, n]
    masks: jax.Array,      # [B, n] bool/0-1
    rhos: jax.Array,       # [B]
    *,
    bb: int = 128,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """V[b] = rho_b * G @ m_b for every mask in the batch.  [B, k] fp32."""
    k, n = G.shape
    B = masks.shape[0]
    bb, bk, bn = min(bb, B), min(bk, k), min(bn, n)
    nb, nk, nn = map(math.ceil, (B / bb, k / bk, n / bn))
    g = _pad2(G.astype(jnp.float32), nk * bk, nn * bn)
    m = _pad2(masks.astype(jnp.float32), nb * bb, nn * bn)
    r = _pad2(rhos.astype(jnp.float32)[:, None], nb * bb, 1)

    out = pl.pallas_call(
        functools.partial(_onestep_batch_kernel, nn=nn),
        grid=(nb, nk, nn),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda b, i, j: (b, j)),
            pl.BlockSpec((bk, bn), lambda b, i, j: (i, j)),
            pl.BlockSpec((bb, 1), lambda b, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bk), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, nk * bk), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bk), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(m, g, r)
    return out[:B, :k]


# --------------------------------------------------------------------------
# ELL batched one-step: gather the masks at each row's support instead of
# streaming the dense zero entries of G.
# --------------------------------------------------------------------------

def _onestep_ell_kernel(m_ref, i_ref, v_ref, r_ref, o_ref):
    m = m_ref[...]                               # [bb, n]
    idx = i_ref[...]                             # [bk, rmax] int32
    val = v_ref[...].astype(jnp.float32)         # [bk, rmax]
    bk, rmax = idx.shape
    gathered = jnp.take(m, idx.reshape(-1), axis=1)        # [bb, bk*rmax]
    gathered = gathered.reshape(m.shape[0], bk, rmax)
    v = jnp.sum(gathered * val[None, :, :], axis=2)        # [bb, bk]
    o_ref[...] = v * r_ref[...]


@functools.partial(jax.jit, static_argnames=("bb", "bk", "interpret"))
def batched_onestep_decode_ell(
    ell_idx: jax.Array,    # [k, rmax] int32 column indices (0-padded)
    ell_val: jax.Array,    # [k, rmax] coefficients (0-padded)
    masks: jax.Array,      # [B, n]
    rhos: jax.Array,       # [B]
    *,
    bb: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Sparse batched Algorithm 1 via the GradientCode.ell() packing.

    Padding rows carry (idx 0, val 0), so they add exactly 0.  The mask
    block spans the full worker dimension (n is at most a few thousand —
    the paper's regime — so a [bb, n] tile fits VMEM comfortably).
    """
    k, rmax = ell_idx.shape
    B, n = masks.shape
    bb, bk = min(bb, B), min(bk, k)
    nb, nk = math.ceil(B / bb), math.ceil(k / bk)
    idx = _pad2(ell_idx.astype(jnp.int32), nk * bk, rmax)
    val = _pad2(ell_val.astype(jnp.float32), nk * bk, rmax)
    m = _pad2(masks.astype(jnp.float32), nb * bb, n)
    r = _pad2(rhos.astype(jnp.float32)[:, None], nb * bb, 1)

    out = pl.pallas_call(
        _onestep_ell_kernel,
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((bb, n), lambda b, i: (b, 0)),
            pl.BlockSpec((bk, rmax), lambda b, i: (i, 0)),
            pl.BlockSpec((bk, rmax), lambda b, i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bk), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, nk * bk), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(m, idx, val, r)
    return out[:B, :k]


# --------------------------------------------------------------------------
# batched masked Gram: Mg[b] = diag(m_b) Gram diag(m_b), the per-mask
# normal-equation matrices of the least-squares decoder.  Pure VPU
# (elementwise outer masking) — the O(k n^2) Gram contraction happens
# ONCE outside the kernel, so the ensemble costs O(B n^2) reads/writes.
# --------------------------------------------------------------------------

def _masked_gram_kernel(mi_ref, mj_ref, g_ref, o_ref):
    mi = mi_ref[...]                             # [bb, bi]
    mj = mj_ref[...]                             # [bb, bj]
    g = g_ref[...].astype(jnp.float32)           # [bi, bj]
    o_ref[...] = mi[:, :, None] * mj[:, None, :] * g[None, :, :]


@functools.partial(jax.jit, static_argnames=("bb", "bi", "bj", "interpret"))
def batched_masked_gram(
    gram: jax.Array,       # [n, n] = G^T G (precomputed once per code)
    masks: jax.Array,      # [B, n] bool/0-1
    *,
    bb: int = 8,
    bi: int = 128,
    bj: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Mg[b] = m_b m_b^T ⊙ Gram for every mask in the batch.  [B, n, n]
    fp32.  Straggler rows/columns come out exactly zero; the solver adds
    the ridge/unit diagonal on the host (core.decoding.solve_masked_gram).
    """
    n = gram.shape[0]
    B = masks.shape[0]
    bb, bi, bj = min(bb, B), min(bi, n), min(bj, n)
    nb, ni, nj = map(math.ceil, (B / bb, n / bi, n / bj))
    pad_m = max(ni * bi, nj * bj)
    g = _pad2(gram.astype(jnp.float32), ni * bi, nj * bj)
    m = _pad2(masks.astype(jnp.float32), nb * bb, pad_m)

    out = pl.pallas_call(
        _masked_gram_kernel,
        grid=(nb, ni, nj),
        in_specs=[
            pl.BlockSpec((bb, bi), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, bj), lambda b, i, j: (b, j)),
            pl.BlockSpec((bi, bj), lambda b, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bi, bj), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, ni * bi, nj * bj),
                                       jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(m, m, g)
    return out[:B, :n, :n]


# --------------------------------------------------------------------------
# batched algorithmic decoder: U_t = U_{t-1} - (A_b A_b^T / nu_b) U_{t-1}
# per mask, realized as two fused masked matmul kernels per iterate.
# --------------------------------------------------------------------------

def _batched_atu_kernel(u_ref, g_ref, m_ref, o_ref, acc_ref, *, nk: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = u_ref[...]                               # [bb, bk]
    g = g_ref[...].astype(jnp.float32)           # [bk, bn]
    acc_ref[...] += jax.lax.dot_general(
        u, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bb, bn]

    @pl.when(i == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...] * m_ref[...]   # mask straggler columns


def _batched_axpy_kernel(t_ref, g_ref, u_ref, inv_ref, o_ref, acc_ref,
                         *, nn: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[...]                               # [bb, bn] (masked)
    g = g_ref[...].astype(jnp.float32)           # [bk, bn]
    acc_ref[...] += jax.lax.dot_general(
        t, g, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # [bb, bk]

    @pl.when(j == nn - 1)
    def _emit():
        o_ref[...] = u_ref[...] - acc_ref[...] * inv_ref[...]


def batched_algorithmic_iterate(G, masks, U, inv_nus, *, bb=128, bk=256,
                                bn=256, interpret=False):
    """One Lemma-12 iterate for every mask: U -> U - (A A^T U) / nu.

    G [k, n], masks [B, n] already float32 (possibly padded), U [B, k],
    inv_nus [B, 1].  Shapes must be pre-padded to block multiples.
    Returns (U_new, T) with T = (U G) * masks — the masked A^T u term,
    whose running sum / nu is the decode-weight iterate x_t (Lemma 12),
    accumulated by the caller.
    """
    B, k = U.shape
    n = G.shape[1]
    nb, nk, nn = B // bb, k // bk, n // bn

    T = pl.pallas_call(
        functools.partial(_batched_atu_kernel, nk=nk),
        grid=(nb, nn, nk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda b, j, i: (b, i)),
            pl.BlockSpec((bk, bn), lambda b, j, i: (i, j)),
            pl.BlockSpec((bb, bn), lambda b, j, i: (b, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(U, G, masks)

    U_new = pl.pallas_call(
        functools.partial(_batched_axpy_kernel, nn=nn),
        grid=(nb, nk, nn),
        in_specs=[
            pl.BlockSpec((bb, bn), lambda b, i, j: (b, j)),
            pl.BlockSpec((bk, bn), lambda b, i, j: (i, j)),
            pl.BlockSpec((bb, bk), lambda b, i, j: (b, i)),
            pl.BlockSpec((bb, 1), lambda b, i, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bk), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bk), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(T, G, U, inv_nus)
    return U_new, T


@functools.partial(jax.jit,
                   static_argnames=("iters", "bb", "bk", "bn", "interpret",
                                    "return_weights"))
def batched_algorithmic_decode(
    G: jax.Array,          # [k, n]
    masks: jax.Array,      # [B, n]
    nus: jax.Array,        # [B] per-mask nu >= ||A_b||_2^2
    iters: int,
    *,
    bb: int = 128,
    bk: int = 256,
    bn: int = 256,
    interpret: bool = False,
    return_weights: bool = False,
):
    """U_iters from U_0 = 1 for every mask in the batch.  [B, k] fp32.

    With return_weights=True also returns the decode weights
    X = sum_t T_t / nu (masked), as (U, X [B, n]).
    """
    k, n = G.shape
    B = masks.shape[0]
    bb, bk, bn = min(bb, B), min(bk, k), min(bn, n)
    nb, nk, nn = map(math.ceil, (B / bb, k / bk, n / bn))
    g = _pad2(G.astype(jnp.float32), nk * bk, nn * bn)
    m = _pad2(masks.astype(jnp.float32), nb * bb, nn * bn)
    # padded batch rows get nu = 1 (harmless: their masks are all-zero)
    inv = jnp.where(nus > 0, 1.0 / nus, 1.0).astype(jnp.float32)[:, None]
    inv = jnp.pad(inv, ((0, nb * bb - B), (0, 0)), constant_values=1.0)
    U = jnp.zeros((nb * bb, nk * bk), jnp.float32) \
        .at[:, :k].set(1.0)  # padded k entries stay 0
    X = jnp.zeros_like(m)
    for _ in range(iters):
        U, T = batched_algorithmic_iterate(g, m, U, inv, bb=bb, bk=bk, bn=bn,
                                           interpret=interpret)
        if return_weights:
            X = X + T * inv
    if return_weights:
        return U[:B, :k], X[:B, :n]
    return U[:B, :k]
