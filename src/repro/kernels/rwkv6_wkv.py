"""RWKV6 (Finch) WKV Pallas kernel: chunked-parallel time mix with
data-dependent per-channel decay.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU adaptation (docs/architecture.md §5): the sequential recurrence is
re-factored into per-chunk dense algebra so the MXU does all heavy work —
intra-chunk interactions become a decay-weighted lower-triangular
[c, c] @ [c, dh] matmul pair, and the [dh, dh] state is carried across
chunk programs in VMEM scratch (never touches HBM).  Grid:
(B*H "parallel", T/c "arbitrary").

Exponents are bounded by the caller's decay clamp (log w in [-5, -6e-6],
c = 16..64 -> max exponent c*5 < log(f32 max)), matching
models.rwkv6.wkv_chunked.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["rwkv6_wkv"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_out_ref,
                state_ref, *, chunk: int, nt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)        # [c, dh]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # [1, dh]
    s = state_ref[...]                      # [dh, dh]

    logw = jnp.log(jnp.maximum(w, 1e-12))
    ci = jnp.cumsum(logw, axis=0)           # inclusive  prod_{j<=t}
    ce = ci - logw                          # exclusive  prod_{j<t}

    r_dec = r * jnp.exp(ce)
    # state entering the chunk
    o = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk strictly-lower pairs
    k_dec = k * jnp.exp(-ci)
    scores = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    c = scores.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(row > col, scores, 0.0)
    o = o + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal u-bonus
    bonus = jnp.sum(r * (u * k), axis=-1, keepdims=True)
    o = o + bonus * v
    o_ref[0] = o.astype(o_ref.dtype)

    # carry: S_out = diag(prod w) S_in + sum_j (prod_{l>j} w_l) k_j^T v_j
    total = ci[-1:]                          # [1, dh]
    k_carry = k * jnp.exp(total - ci)
    s_new = jnp.exp(total).T * s + jax.lax.dot_general(
        k_carry, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_ref[...] = s_new

    @pl.when(ti == nt - 1)
    def _emit_state():
        s_out_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(
    r: jax.Array,                 # [B, T, H, dh]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,                 # decay in (0, 1)
    u: jax.Array,                 # [H, dh] bonus
    s0: jax.Array | None = None,  # [B, H, dh, dh]
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    """Chunked WKV.  Returns (o [B,T,H,dh], s_T [B,H,dh,dh])."""
    B, T, H, dh = r.shape
    chunk = min(chunk, T)
    nt = math.ceil(T / chunk)
    pt = nt * chunk - T

    def prep(t):
        t = jnp.moveaxis(t, 2, 1).reshape(B * H, T, dh)
        if pt:
            t = jnp.pad(t, ((0, 0), (0, pt), (0, 0)))
        return t

    rt, kt, vt = prep(r), prep(k), prep(v)
    wt = jnp.moveaxis(w, 2, 1).reshape(B * H, T, dh)
    if pt:
        # pad decay with ones (no-op steps), k/v with zeros
        wt = jnp.pad(wt, ((0, 0), (0, pt), (0, 0)), constant_values=1.0)
    uu = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, 1, dh)
    s0f = (jnp.zeros((B * H, dh, dh), jnp.float32) if s0 is None
           else s0.astype(jnp.float32).reshape(B * H, dh, dh))

    o, s_out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, nt=nt),
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nt * chunk, dh), r.dtype),
            jax.ShapeDtypeStruct((B * H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, uu, s0f)

    o = o[:, :T].reshape(B, H, T, dh)
    o = jnp.moveaxis(o, 1, 2)
    s_out = s_out.reshape(B, H, dh, dh)
    return o, s_out
