"""Fused decode-apply Pallas kernel: masks -> decoded gradients, one pass.

The weights-then-psum composition decodes in two passes: DecodeEngine
materializes the [B, n] weight ensemble (plus its error reduction), then
``coded_accumulate_batched`` contracts it against the worker messages.
For the one-step decoder the weights are a rank-1 function of the mask
(w_b = s_b * m_b, with s_b the per-mask rho or its renormalized form),
so the decode can ride the accumulate itself:

    out[b, p] = s_b * sum_j m[b, j] * msgs[j, p]

One [bb, bl] @ [bl, bp] MXU tile per grid cell — the mask tile plays
the role of the weight tile and the scalar scale is applied once at
emission, so the [B, n] weight ensemble is never built and the messages
stream HBM -> VMEM exactly once per param tile (same arithmetic
intensity as coded_accumulate_batched, one fewer pass over the batch).

The contracted worker dimension is innermost/sequential into an fp32
VMEM accumulator; scales ride along as a [bb, 1] block exactly like the
rhos of ``batched_decode._onestep_batch_kernel``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["fused_decode_apply"]


def _fused_kernel(m_ref, g_ref, s_ref, o_ref, acc_ref, *, nl: int):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m = m_ref[...]                           # [bb, bl] mask tile (0/1 f32)
    g = g_ref[...].astype(jnp.float32)       # [bl, bp] message tile
    acc_ref[...] += jax.lax.dot_general(
        m, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [bb, bp]

    @pl.when(i == nl - 1)
    def _emit():
        o_ref[...] = acc_ref[...] * s_ref[...]   # [bb, 1] scale broadcast


def _pad2(x, r, c):
    pr, pc = r - x.shape[0], c - x.shape[1]
    return jnp.pad(x, ((0, pr), (0, pc))) if pr or pc else x


@functools.partial(jax.jit, static_argnames=("bb", "bl", "bp", "interpret"))
def fused_decode_apply(
    messages: jax.Array,          # [L, P] per-worker coded messages
    masks: jax.Array,             # [B, L] bool/0-1 non-straggler masks
    scales: jax.Array,            # [B] per-mask one-step decode scale
    *,
    bb: int = 128,
    bl: int = 512,
    bp: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """out[b] = scales[b] * (masks[b] @ messages).  [B, P] fp32."""
    L, P = messages.shape
    B = masks.shape[0]
    bb, bl, bp = min(bb, B), min(bl, L), min(bp, P)
    nb, nl, np_ = map(math.ceil, (B / bb, L / bl, P / bp))
    g = _pad2(messages.astype(jnp.float32), nl * bl, np_ * bp)
    m = _pad2(masks.astype(jnp.float32), nb * bb, nl * bl)
    s = _pad2(scales.astype(jnp.float32)[:, None], nb * bb, 1)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, nl=nl),
        grid=(nb, np_, nl),
        in_specs=[
            pl.BlockSpec((bb, bl), lambda b, p, i: (b, i)),
            pl.BlockSpec((bl, bp), lambda b, p, i: (i, p)),
            pl.BlockSpec((bb, 1), lambda b, p, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bp), lambda b, p, i: (b, p)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, np_ * bp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bb, bp), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(m, g, s)
    return out[:B, :P]
