"""Approximate gradient coding via sparse random graphs — core library.

Implements the paper's contribution and its follow-ups: gradient-code
constructions (frc / bgc / rbgc / sregular / sbm / expander / cyclic /
uncoded), decoders (one-step / optimal incl. masked-Gram / algorithmic),
adversarial straggler analysis, closed-form theory, the batched
DecodeEngine (mask ensembles -> weights/errors, docs/architecture.md
§5), the declarative scheme registry (docs/families.md), the
Monte-Carlo simulation engine, and the assignment layer that couples a
code to a physical data-parallel batch.
"""

from .codes import (  # noqa: F401
    CODE_REGISTRY,
    GradientCode,
    bgc,
    cyclic_repetition,
    frc,
    make_code,
    rbgc,
    spectral_gap,
    sregular,
    uncoded,
)
from .decoding import (  # noqa: F401
    algorithmic_error_curve,
    algorithmic_weights,
    apply_weights,
    decode_weights,
    default_rho,
    err,
    err1,
    onestep_decode,
    onestep_weights,
    optimal_decode,
    optimal_weights,
)
from .assignment import CodedAssignment, build_assignment  # noqa: F401
from .engine import BatchDecode, DecodeEngine  # noqa: F401
from .registry import CodeFamily  # noqa: F401
from .certify import SpectralCertificate, adversarial_err1_bound  # noqa: F401
from . import adversary, certify, registry, simulate, theory  # noqa: F401
