"""Decoders: reconstruct (approximately) 1_k from the non-straggler matrix A.

Three decoders from the paper:

* one-step (Algorithm 1): v = rho * A @ 1_r.  O(nnz(A)), streaming.
* optimal  (Algorithm 2): v = A @ argmin_x ||A x - 1_k||^2.  Least squares.
* algorithmic (Lemma 12): u_t = (I - A A^T / nu) u_{t-1}, u_0 = 1_k.
  ||u_t||^2 decreases monotonically to err(A); each iterate costs two
  matvecs, interpolating between one-step and optimal decoding.

All of these produce *decode weights* w in R^n (zero at stragglers) such
that the master's reconstruction is  v = G @ w  and the decoded gradient
is  sum_j w_j * (coded partial of worker j).  The training path consumes
the weights; the error analyses consume v.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "err",
    "err1",
    "onestep_weights",
    "onestep_decode",
    "optimal_weights",
    "optimal_decode",
    "algorithmic_weights",
    "algorithmic_error_curve",
    "decode_weights",
    "exact_decode_renorm",
    "apply_weights",
    # batched (mask-ensemble) variants — consumed by core.engine
    "err1_batch",
    "err_batch",
    "onestep_weights_batch",
    "optimal_weights_batch",
    "normal_eq_weights_batch",
    "solve_masked_gram",
    "algorithmic_weights_batch",
    "algorithmic_error_curve_batch",
    "spectral_norm_sq_batch",
]


def _as2d(A: np.ndarray) -> np.ndarray:
    A = np.asarray(A, dtype=np.float64)
    if A.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got shape {A.shape}")
    return A


def err(A: np.ndarray) -> float:
    """Optimal decoding error err(A) = min_x ||A x - 1_k||_2^2 (Def. 1)."""
    A = _as2d(A)
    k = A.shape[0]
    ones = np.ones(k)
    if A.shape[1] == 0:
        return float(k)
    x, _, _, _ = np.linalg.lstsq(A, ones, rcond=None)
    res = A @ x - ones
    return float(res @ res)


def err1(A: np.ndarray, rho: float) -> float:
    """One-step decoding error err_1(A) = ||rho * A 1_r - 1_k||_2^2 (Def. 2)."""
    A = _as2d(A)
    k = A.shape[0]
    v = rho * A.sum(axis=1) - np.ones(k)
    return float(v @ v)


def default_rho(k: int, r: int, s: int) -> float:
    """The paper's canonical rho = k / (r s)."""
    if r == 0:
        return 0.0
    return k / (r * s)


def onestep_weights(G: np.ndarray, mask: np.ndarray, rho: Optional[float] = None,
                    s: Optional[int] = None) -> np.ndarray:
    """Decode weights for Algorithm 1: w_j = rho if j is a non-straggler.

    rho defaults to k/(r s) with s inferred from G's mean column degree
    if not given.
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    r = int(mask.sum())
    if rho is None:
        if s is None:
            s = max(1, int(round((G != 0).sum() / max(n, 1))))
        rho = default_rho(k, r, s)
    return rho * mask.astype(np.float64)


def onestep_decode(G: np.ndarray, mask: np.ndarray, rho: Optional[float] = None,
                   s: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
    """(v, w): reconstruction v = G @ w and the weights, Algorithm 1."""
    w = onestep_weights(G, mask, rho=rho, s=s)
    return _as2d(G) @ w, w


def optimal_weights(G: np.ndarray, mask: np.ndarray, ridge: float = 0.0) -> np.ndarray:
    """Decode weights for Algorithm 2 embedded in R^n (zeros at stragglers).

    Solves min_x ||A x - 1_k||^2 (+ ridge ||x||^2) over the non-straggler
    columns A.  With ridge=0 this is the pseudo-inverse solution
    x = A^+ 1_k; a tiny ridge stabilizes ill-conditioned A (the paper
    notes one-step decoding is preferred exactly when A is
    ill-conditioned).
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    A = G[:, mask]
    w = np.zeros(n)
    if A.shape[1] == 0:
        return w
    ones = np.ones(k)
    if ridge > 0.0:
        r = A.shape[1]
        x = np.linalg.solve(A.T @ A + ridge * np.eye(r), A.T @ ones)
    else:
        x, _, _, _ = np.linalg.lstsq(A, ones, rcond=None)
    w[mask] = x
    return w


def optimal_decode(G: np.ndarray, mask: np.ndarray, ridge: float = 0.0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(v, w) for Algorithm 2."""
    w = optimal_weights(G, mask, ridge=ridge)
    return _as2d(G) @ w, w


def _spectral_norm_sq(A: np.ndarray) -> float:
    if min(A.shape) == 0:
        return 1.0
    return float(np.linalg.norm(A, 2) ** 2)


def algorithmic_weights(G: np.ndarray, mask: np.ndarray, iters: int,
                        nu: Optional[float] = None) -> np.ndarray:
    """Decode weights after `iters` steps of the Lemma-12 iteration.

    u_t = (I - A A^T/nu) u_{t-1};  the reconstruction after t steps is
    v_t = 1_k - u_t = A x_t  with  x_t = (1/nu) sum_{j<t} A^T u_j,  so the
    weights are x_t scattered into R^n.  iters=1 with nu = r s^2 / k
    recovers (a scaled) one-step decode; iters -> inf recovers optimal.
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    A = G[:, mask]
    w = np.zeros(n)
    if A.shape[1] == 0 or iters <= 0:
        return w
    if nu is None:
        nu = _spectral_norm_sq(A)
    u = np.ones(k)
    x = np.zeros(A.shape[1])
    for _ in range(iters):
        x = x + (A.T @ u) / nu
        u = u - (A @ (A.T @ u)) / nu
    w[mask] = x
    return w


def algorithmic_error_curve(A: np.ndarray, iters: int, nu: Optional[float] = None
                            ) -> np.ndarray:
    """[||u_0||^2, ..., ||u_iters||^2] — the Fig.-5 curve (monotone to err(A))."""
    A = _as2d(A)
    k = A.shape[0]
    if nu is None:
        nu = _spectral_norm_sq(A)
    u = np.ones(k)
    out = [float(u @ u)]
    for _ in range(iters):
        if A.shape[1]:
            u = u - (A @ (A.T @ u)) / nu
        out.append(float(u @ u))
    return np.asarray(out)


# --------------------------------------------------------------------------
# Batched (mask-ensemble) decoders.
#
# All of these take a [B, n] boolean batch of non-straggler masks and
# return [B, n] weights (and [B] errors where noted), replacing the
# Python trial loops in the Monte-Carlo engine.  Zero terms contribute
# exactly 0.0 to float sums, so the masked full-width linear algebra
# below reproduces the per-mask submatrix results exactly (onestep) or
# to solver/BLAS rounding (optimal, algorithmic).
# --------------------------------------------------------------------------


def _as_masks(masks: np.ndarray, n: int) -> np.ndarray:
    masks = np.asarray(masks, dtype=bool)
    if masks.ndim == 1:
        masks = masks[None]
    if masks.ndim != 2 or masks.shape[1] != n:
        raise ValueError(f"masks shape {masks.shape} != (B, {n})")
    return masks


def _infer_s(G: np.ndarray) -> int:
    return max(1, int(round((G != 0).sum() / max(G.shape[1], 1))))


def _default_rhos(k: int, rs: np.ndarray, s: int) -> np.ndarray:
    """Vectorized default_rho: k/(r s), 0 where r == 0."""
    out = np.zeros(len(rs))
    nz = rs > 0
    out[nz] = k / (rs[nz] * s)
    return out


def _batch_chunks(B: int, k: int, n: int, budget_elems: int = 1 << 26):
    """Yield slices covering range(B), bounding k*n*chunk work arrays."""
    step = max(1, budget_elems // max(k * n, 1))
    for lo in range(0, B, step):
        yield slice(lo, min(lo + step, B))


def err1_batch(G: np.ndarray, masks: np.ndarray,
               rhos: np.ndarray) -> np.ndarray:
    """err_1 per mask: ||rho_b * G m_b - 1_k||^2.  Returns [B]."""
    G = _as2d(G)
    masks = _as_masks(masks, G.shape[1])
    V = np.asarray(rhos)[:, None] * (masks @ G.T)
    return ((V - 1.0) ** 2).sum(axis=1)


def err_batch(G: np.ndarray, W: np.ndarray) -> np.ndarray:
    """Residual ||G w_b - 1_k||^2 for given decode weights.  Returns [B]."""
    G = _as2d(G)
    V = W @ G.T
    return ((V - 1.0) ** 2).sum(axis=1)


def onestep_weights_batch(G: np.ndarray, masks: np.ndarray,
                          rho: Optional[float] = None,
                          s: Optional[int] = None) -> np.ndarray:
    """Batched Algorithm 1 weights: w_b = rho_b * m_b.  Returns [B, n]."""
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    if rho is None:
        if s is None:
            s = _infer_s(G)
        rhos = _default_rhos(k, masks.sum(axis=1), s)
    else:
        rhos = np.full(masks.shape[0], float(rho))
    return rhos[:, None] * masks


def optimal_weights_batch(G: np.ndarray, masks: np.ndarray,
                          ridge: float = 0.0) -> np.ndarray:
    """Batched Algorithm 2 weights embedded in R^n.  Returns [B, n].

    ridge == 0 takes the min-norm LS solution via batched pinv of the
    column-masked G (zeroed columns contribute zero weights, matching
    the per-mask submatrix lstsq).  ridge > 0 goes through the masked
    normal equations (normal_eq_weights_batch), whose off-support rows
    reduce to w_j = 0.  Work is chunked over B to bound memory.
    """
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    if ridge > 0.0:
        return normal_eq_weights_batch(G, masks, ridge=ridge)
    B = masks.shape[0]
    ones = np.ones(k)
    W = np.zeros((B, n))
    for sl in _batch_chunks(B, k, n):
        m = masks[sl].astype(np.float64)
        A = G[None, :, :] * m[:, None, :]                    # [b, k, n]
        W[sl] = (np.linalg.pinv(A) @ ones) * m
    return W


def solve_masked_gram(masked_gram: np.ndarray, masks: np.ndarray,
                      rhs0: np.ndarray, ridge: float) -> np.ndarray:
    """Solve the [B] regularized normal-equation systems and return
    weights [B, n].

    ``masked_gram[b] = diag(m_b) G^T G diag(m_b)`` (the Gram ensemble —
    from numpy or the Pallas batched Gram kernel), ``rhs0 = G^T 1``.
    Straggler rows are all-zero in the masked Gram; the unit added to
    their diagonal pins x_j = 0, and ``ridge`` stabilizes the on-support
    block (rank-deficient supports — duplicated FRC/SBM columns — tend
    to the min-norm solution as ridge -> 0).
    """
    masks = np.asarray(masks, dtype=bool)
    B, n = masks.shape
    M = np.array(masked_gram, dtype=np.float64)   # copy: diagonal is edited
    idx = np.arange(n)
    M[:, idx, idx] += np.where(masks, ridge, 1.0)
    rhs = masks * rhs0[None, :]
    x = np.linalg.solve(M, rhs[..., None])[..., 0]
    return x * masks


def normal_eq_weights_batch(G: np.ndarray, masks: np.ndarray,
                            ridge: float = 1e-8,
                            gram: Optional[np.ndarray] = None,
                            rhs0: Optional[np.ndarray] = None) -> np.ndarray:
    """Batched least-squares weights via the masked-Gram identity.

    Since A_b = G diag(m_b), the per-mask Gram matrix is
    ``A_b^T A_b = diag(m_b) (G^T G) diag(m_b)`` — the FULL Gram G^T G
    masked on rows and columns.  So G^T G is formed once (O(k n^2)) and
    each mask costs an O(n^2) masking plus one LAPACK batched solve,
    never a per-mask pinv/SVD: the decoder path that makes batched
    optimal decoding of [B, n] ensembles (sbm / expander frontiers)
    cheap.  Returns [B, n]; exact zeros at stragglers.

    Long-lived callers (DecodeEngine) pass their cached ``gram`` /
    ``rhs0`` so repeated decodes skip even the one-time contraction.
    """
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    if ridge <= 0.0:
        raise ValueError("normal_eq_weights_batch needs ridge > 0; use "
                         "optimal_weights_batch for the exact min-norm path")
    B = masks.shape[0]
    if gram is None:
        gram = G.T @ G                                       # [n, n] once
    if rhs0 is None:
        rhs0 = G.sum(axis=0)                                 # G^T 1_k
    W = np.zeros((B, n))
    for sl in _batch_chunks(B, n, n):
        m = masks[sl].astype(np.float64)
        Mg = gram[None, :, :] * m[:, :, None] * m[:, None, :]
        W[sl] = solve_masked_gram(Mg, masks[sl], rhs0, ridge)
    return W


def spectral_norm_sq_batch(G: np.ndarray, masks: np.ndarray) -> np.ndarray:
    """||A_b||_2^2 per mask (A_b = column-masked G).  Returns [B].

    Degenerate masks (empty A) map to 1.0, matching _spectral_norm_sq.
    """
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    out = np.ones(masks.shape[0])
    for sl in _batch_chunks(masks.shape[0], k, n):
        A = G[None, :, :] * masks[sl].astype(np.float64)[:, None, :]
        sv = np.linalg.svd(A, compute_uv=False)[:, 0]
        nz = sv > 0
        out[sl] = np.where(nz, sv ** 2, 1.0)
    return out


def algorithmic_weights_batch(G: np.ndarray, masks: np.ndarray, iters: int,
                              nu: Optional[np.ndarray] = None,
                              return_errors: bool = False):
    """Batched Lemma-12 weights after `iters` iterations.  Returns
    [B, n] (and [B] final ||u_t||^2 errors when return_errors=True).

    nu may be a scalar, a [B] array, or None (per-mask spectral norm,
    matching the scalar path).
    """
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    B = masks.shape[0]
    W = np.zeros((B, n))
    if iters <= 0:
        if return_errors:
            return W, np.full(B, float(k))
        return W
    if nu is None:
        nus = spectral_norm_sq_batch(G, masks)
    else:
        nus = np.broadcast_to(np.asarray(nu, dtype=np.float64), (B,)).copy()
    nus[nus <= 0] = 1.0
    m = masks.astype(np.float64)
    U = np.ones((B, k))
    X = np.zeros((B, n))
    inv = (1.0 / nus)[:, None]
    for _ in range(iters):
        T = (U @ G) * m                # [B, n] = A^T u, masked
        X += T * inv
        U = U - (T @ G.T) * inv        # u - A A^T u / nu
    W = X * m                          # exact zeros at stragglers
    if return_errors:
        return W, (U ** 2).sum(axis=1)
    return W


def algorithmic_error_curve_batch(G: np.ndarray, masks: np.ndarray,
                                  iters: int,
                                  nu: Optional[np.ndarray] = None
                                  ) -> np.ndarray:
    """[B, iters+1] of ||u_t||^2 per mask (batched Fig.-5 curves)."""
    G = _as2d(G)
    k, n = G.shape
    masks = _as_masks(masks, n)
    B = masks.shape[0]
    if nu is None:
        nus = spectral_norm_sq_batch(G, masks)
    else:
        nus = np.broadcast_to(np.asarray(nu, dtype=np.float64), (B,)).copy()
    nus[nus <= 0] = 1.0
    m = masks.astype(np.float64)
    U = np.ones((B, k))
    inv = (1.0 / nus)[:, None]
    out = np.empty((B, iters + 1))
    out[:, 0] = (U ** 2).sum(axis=1)
    for t in range(iters):
        T = (U @ G) * m
        U = U - (T @ G.T) * inv
        out[:, t + 1] = (U ** 2).sum(axis=1)
    return out


def decode_weights(G: np.ndarray, mask: np.ndarray, method: str = "onestep",
                   **kw) -> np.ndarray:
    """Unified entry point used by the training runtime."""
    if method == "onestep":
        return onestep_weights(G, mask, **kw)
    if method == "optimal":
        return optimal_weights(G, mask, **kw)
    if method == "algorithmic":
        return algorithmic_weights(G, mask, **kw)
    if method == "ignore":  # ignore-stragglers baseline: average what arrived
        mask = np.asarray(mask, dtype=bool)
        G = _as2d(G)
        k = G.shape[0]
        # scale so that E[v] ~ 1_k when row coverage is uniform
        cover = (G[:, mask] != 0).sum()
        return mask * (k / max(cover, 1))
    raise ValueError(f"unknown decode method {method!r}")


def exact_decode_renorm(G: np.ndarray, W: np.ndarray) -> np.ndarray:
    """Rescale decode weights so sum(G @ w) == k (unbiased-ish decode).

    THE renorm rule shared by the fused trainer (scalar w) and the coded
    all-reduce trace path ([S, n] ensembles) — one implementation so the
    two weight streams cannot drift.  Rows whose decode sum is tiny
    (all-straggler masks) are returned unchanged.
    """
    G = _as2d(G)
    k = G.shape[0]
    W = np.asarray(W, dtype=np.float64)
    if W.ndim == 1:
        tot = float((G @ W).sum())
        return W * (k / tot) if tot > 1e-6 else W
    tot = (G @ W.T).sum(axis=0)
    scale = np.where(tot > 1e-6, k / np.where(tot > 1e-6, tot, 1.0), 1.0)
    return W * scale[:, None]


def apply_weights(partials: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Master-side reference decode: partials (n, d) -> sum_j w_j partials_j.

    This is the explicit 'gather to master then combine' path the tests
    compare against the all-reduce-fused training implementation.
    """
    partials = np.asarray(partials)
    return np.tensordot(w, partials, axes=(0, 0))
