"""Decoders: reconstruct (approximately) 1_k from the non-straggler matrix A.

Three decoders from the paper:

* one-step (Algorithm 1): v = rho * A @ 1_r.  O(nnz(A)), streaming.
* optimal  (Algorithm 2): v = A @ argmin_x ||A x - 1_k||^2.  Least squares.
* algorithmic (Lemma 12): u_t = (I - A A^T / nu) u_{t-1}, u_0 = 1_k.
  ||u_t||^2 decreases monotonically to err(A); each iterate costs two
  matvecs, interpolating between one-step and optimal decoding.

All of these produce *decode weights* w in R^n (zero at stragglers) such
that the master's reconstruction is  v = G @ w  and the decoded gradient
is  sum_j w_j * (coded partial of worker j).  The training path consumes
the weights; the error analyses consume v.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "err",
    "err1",
    "onestep_weights",
    "onestep_decode",
    "optimal_weights",
    "optimal_decode",
    "algorithmic_weights",
    "algorithmic_error_curve",
    "decode_weights",
    "apply_weights",
]


def _as2d(A: np.ndarray) -> np.ndarray:
    A = np.asarray(A, dtype=np.float64)
    if A.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got shape {A.shape}")
    return A


def err(A: np.ndarray) -> float:
    """Optimal decoding error err(A) = min_x ||A x - 1_k||_2^2 (Def. 1)."""
    A = _as2d(A)
    k = A.shape[0]
    ones = np.ones(k)
    if A.shape[1] == 0:
        return float(k)
    x, _, _, _ = np.linalg.lstsq(A, ones, rcond=None)
    res = A @ x - ones
    return float(res @ res)


def err1(A: np.ndarray, rho: float) -> float:
    """One-step decoding error err_1(A) = ||rho * A 1_r - 1_k||_2^2 (Def. 2)."""
    A = _as2d(A)
    k = A.shape[0]
    v = rho * A.sum(axis=1) - np.ones(k)
    return float(v @ v)


def default_rho(k: int, r: int, s: int) -> float:
    """The paper's canonical rho = k / (r s)."""
    if r == 0:
        return 0.0
    return k / (r * s)


def onestep_weights(G: np.ndarray, mask: np.ndarray, rho: Optional[float] = None,
                    s: Optional[int] = None) -> np.ndarray:
    """Decode weights for Algorithm 1: w_j = rho if j is a non-straggler.

    rho defaults to k/(r s) with s inferred from G's mean column degree
    if not given.
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    r = int(mask.sum())
    if rho is None:
        if s is None:
            s = max(1, int(round((G != 0).sum() / max(n, 1))))
        rho = default_rho(k, r, s)
    return rho * mask.astype(np.float64)


def onestep_decode(G: np.ndarray, mask: np.ndarray, rho: Optional[float] = None,
                   s: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
    """(v, w): reconstruction v = G @ w and the weights, Algorithm 1."""
    w = onestep_weights(G, mask, rho=rho, s=s)
    return _as2d(G) @ w, w


def optimal_weights(G: np.ndarray, mask: np.ndarray, ridge: float = 0.0) -> np.ndarray:
    """Decode weights for Algorithm 2 embedded in R^n (zeros at stragglers).

    Solves min_x ||A x - 1_k||^2 (+ ridge ||x||^2) over the non-straggler
    columns A.  With ridge=0 this is the pseudo-inverse solution
    x = A^+ 1_k; a tiny ridge stabilizes ill-conditioned A (the paper
    notes one-step decoding is preferred exactly when A is
    ill-conditioned).
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    A = G[:, mask]
    w = np.zeros(n)
    if A.shape[1] == 0:
        return w
    ones = np.ones(k)
    if ridge > 0.0:
        r = A.shape[1]
        x = np.linalg.solve(A.T @ A + ridge * np.eye(r), A.T @ ones)
    else:
        x, _, _, _ = np.linalg.lstsq(A, ones, rcond=None)
    w[mask] = x
    return w


def optimal_decode(G: np.ndarray, mask: np.ndarray, ridge: float = 0.0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """(v, w) for Algorithm 2."""
    w = optimal_weights(G, mask, ridge=ridge)
    return _as2d(G) @ w, w


def _spectral_norm_sq(A: np.ndarray) -> float:
    if min(A.shape) == 0:
        return 1.0
    return float(np.linalg.norm(A, 2) ** 2)


def algorithmic_weights(G: np.ndarray, mask: np.ndarray, iters: int,
                        nu: Optional[float] = None) -> np.ndarray:
    """Decode weights after `iters` steps of the Lemma-12 iteration.

    u_t = (I - A A^T/nu) u_{t-1};  the reconstruction after t steps is
    v_t = 1_k - u_t = A x_t  with  x_t = (1/nu) sum_{j<t} A^T u_j,  so the
    weights are x_t scattered into R^n.  iters=1 with nu = r s^2 / k
    recovers (a scaled) one-step decode; iters -> inf recovers optimal.
    """
    G = _as2d(G)
    mask = np.asarray(mask, dtype=bool)
    k, n = G.shape
    A = G[:, mask]
    w = np.zeros(n)
    if A.shape[1] == 0 or iters <= 0:
        return w
    if nu is None:
        nu = _spectral_norm_sq(A)
    u = np.ones(k)
    x = np.zeros(A.shape[1])
    for _ in range(iters):
        x = x + (A.T @ u) / nu
        u = u - (A @ (A.T @ u)) / nu
    w[mask] = x
    return w


def algorithmic_error_curve(A: np.ndarray, iters: int, nu: Optional[float] = None
                            ) -> np.ndarray:
    """[||u_0||^2, ..., ||u_iters||^2] — the Fig.-5 curve (monotone to err(A))."""
    A = _as2d(A)
    k = A.shape[0]
    if nu is None:
        nu = _spectral_norm_sq(A)
    u = np.ones(k)
    out = [float(u @ u)]
    for _ in range(iters):
        if A.shape[1]:
            u = u - (A @ (A.T @ u)) / nu
        out.append(float(u @ u))
    return np.asarray(out)


def decode_weights(G: np.ndarray, mask: np.ndarray, method: str = "onestep",
                   **kw) -> np.ndarray:
    """Unified entry point used by the training runtime."""
    if method == "onestep":
        return onestep_weights(G, mask, **kw)
    if method == "optimal":
        return optimal_weights(G, mask, **kw)
    if method == "algorithmic":
        return algorithmic_weights(G, mask, **kw)
    if method == "ignore":  # ignore-stragglers baseline: average what arrived
        mask = np.asarray(mask, dtype=bool)
        G = _as2d(G)
        k = G.shape[0]
        # scale so that E[v] ~ 1_k when row coverage is uniform
        cover = (G[:, mask] != 0).sum()
        return mask * (k / max(cover, 1))
    raise ValueError(f"unknown decode method {method!r}")


def apply_weights(partials: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Master-side reference decode: partials (n, d) -> sum_j w_j partials_j.

    This is the explicit 'gather to master then combine' path the tests
    compare against the all-reduce-fused training implementation.
    """
    partials = np.asarray(partials)
    return np.tensordot(w, partials, axes=(0, 0))
