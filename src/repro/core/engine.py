"""DecodeEngine: the one subsystem turning straggler masks into decode
weights, shared by the Monte-Carlo simulator, the coded training loop,
and the benchmarks.

The paper's pitch is that one-step decoding of sparse-graph codes is
cheap enough to run everywhere; this engine makes that true *at scale*
by decoding a whole ``[B, n]`` ensemble of masks per call instead of a
Python loop over trials:

  * ``decode_batch(masks)`` -> ``[B, n]`` weights + ``[B]`` errors for
    the one-step (Algorithm 1), ridge/optimal (Algorithm 2) and
    algorithmic (Lemma 12) decoders, plus the ignore-stragglers
    baseline.  The optimal decoder has two strategies
    (``optimal_impl``): the masked-Gram normal equations —
    ``A_b^T A_b = diag(m_b) (G^T G) diag(m_b)``, so the Gram forms once
    per code and each mask costs O(n^2) + a batched LAPACK solve (the
    default, and the fast path for the sbm/expander least-squares
    frontiers) — and exact batched pinv, the explicit opt-in
    scalar-oracle path for numpy/ridge=0 exactness tests.
  * ``decode_apply_batch(masks, messages)`` fuses the one-step decode
    into the gradient accumulate itself: ``diag(scales) masks @
    messages`` in one pass, never materializing the ``[B, n]`` weight
    ensemble (the kernels.fused_decode_apply hot path used by
    CodedAllReduce's pipelined aggregation).
  * backends: ``numpy`` (BLAS batched, float64 — the CPU master path),
    ``xla`` / ``pallas`` / ``pallas_interpret`` (the batched-grid Pallas
    kernels in kernels.batched_decode; fp32).  The Pallas one-step path
    automatically switches to the row-ELL packing of G
    (``GradientCode.ell()``) when the code is sparse enough that
    gathering beats streaming dense zeros.
  * ``decode(mask)`` -> ``[n]`` weights through a mask->weights LRU
    cache, so regimes that repeat masks (adversarial stragglers, stable
    deadline cohorts) decode once per distinct mask.

See docs/architecture.md §5 for how this slots between core.decoding (scalar
oracles), core.simulate (mask ensembles) and training.train_loop
(per-step decode).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

import numpy as np

from . import decoding
from .codes import GradientCode

__all__ = ["BatchDecode", "DecodeEngine"]

_BACKENDS = ("numpy", "xla", "pallas", "pallas_interpret")
DECODERS = ("onestep", "optimal", "algorithmic", "ignore")


@dataclasses.dataclass(frozen=True)
class BatchDecode:
    """Result of one batched decode: per-mask weights and errors."""

    weights: np.ndarray      # [B, n] decode weights (zero at stragglers)
    errors: np.ndarray       # [B] decoding error (err_1 / err / ||u_t||^2)

    @property
    def batch(self) -> int:
        return int(self.weights.shape[0])


class DecodeEngine:
    """Owns a GradientCode and decodes mask ensembles against it.

    Construction is cheap; the ELL packing and per-code constants are
    derived lazily.  One engine per live code — the training loop
    rebuilds it on elastic re-coding, the simulator builds one per
    (scheme, delta) cell.
    """

    def __init__(self, code: GradientCode, *, backend: str = "numpy",
                 rho: Optional[float] = None, s: Optional[int] = None,
                 ridge: float = 0.0, iters: int = 8, sparse: str = "auto",
                 optimal_impl: str = "auto", cache_size: int = 512,
                 tiles=None):
        if backend not in _BACKENDS:
            raise ValueError(f"backend {backend!r} not in {_BACKENDS}")
        if sparse not in ("auto", "always", "never"):
            raise ValueError(f"sparse {sparse!r}")
        if optimal_impl not in ("auto", "pinv", "gram"):
            raise ValueError(f"optimal_impl {optimal_impl!r} not in "
                             f"('auto', 'pinv', 'gram')")
        self.code = code
        self.backend = backend
        self.rho = rho                  # None -> per-mask k/(r s)
        self.ridge = ridge
        self.iters = iters
        self.sparse = sparse
        # least-squares strategy: 'gram' = masked-Gram normal equations
        # (one O(k n^2) Gram, O(n^2)/mask — the fast path for large
        # ensembles, ridge-regularized); 'pinv' = exact min-norm batched
        # pinv (matches decoding.optimal_weights to solver rounding —
        # the explicit opt-in for numpy/ridge=0 exact-oracle tests);
        # 'auto' = gram (E10's speedup[optimal] gate pins this default)
        self.optimal_impl = optimal_impl
        self._gram = None               # lazy G^T G / G^T 1 for 'gram'
        # s in rho = k/(r s): the caller's nominal tasks/worker when
        # given (the paper's calibration — simulate passes it), else
        # inferred from G's density exactly like decoding.onestep_weights
        self._s = s if s is not None else decoding._infer_s(code.G)
        # kernel tile override (kernels.TileConfig or None).  None means
        # "whatever the committed autotune table pins for the active
        # backend" — the ops-layer default; the numpy backend never
        # launches a kernel, so tiles are simply unused there.
        self.tiles = tiles
        self._cache: OrderedDict = OrderedDict()
        self._cache_size = cache_size
        self.cache_hits = 0
        self.cache_misses = 0
        # number of decode_batch invocations — ClusterSim's tests assert
        # one batched decode per (scheme, policy) run against this
        self.batch_calls = 0
        # number of fused decode-apply scale computations (decode_batch
        # is NOT incremented on the fused path: no weight ensemble)
        self.fused_calls = 0

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------

    @property
    def k(self) -> int:
        return self.code.k

    @property
    def n(self) -> int:
        return self.code.n

    def rhos_for(self, masks: np.ndarray) -> np.ndarray:
        """Per-mask one-step scaling: the fixed rho, or k/(r_b s)."""
        masks = decoding._as_masks(masks, self.n)
        if self.rho is not None:
            return np.full(masks.shape[0], float(self.rho))
        return decoding._default_rhos(self.k, masks.sum(axis=1), self._s)

    def _use_ell(self) -> bool:
        if self.sparse == "never":
            return False
        idx, _ = self.code.ell()
        rmax = idx.shape[1]
        # gather wins when the packed row is meaningfully narrower than
        # the dense worker dimension
        return self.sparse == "always" or 4 * rmax <= self.n

    # ------------------------------------------------------------------
    # batched decode
    # ------------------------------------------------------------------

    def decode_batch(self, masks: np.ndarray, method: str = "onestep", *,
                     iters: Optional[int] = None) -> BatchDecode:
        """Decode a [B, n] mask ensemble -> weights [B, n], errors [B]."""
        masks = decoding._as_masks(masks, self.n)
        self.batch_calls += 1
        if method == "onestep":
            return self._onestep_batch(masks)
        if method == "optimal":
            return self._optimal_batch(masks)
        if method == "algorithmic":
            return self._algorithmic_batch(
                masks, self.iters if iters is None else iters)
        if method == "ignore":
            return self._ignore_batch(masks)
        raise ValueError(f"unknown decode method {method!r}; "
                         f"have {DECODERS}")

    def errors_batch(self, masks: np.ndarray, method: str = "onestep", *,
                     iters: Optional[int] = None) -> np.ndarray:
        """[B] decoding errors only (what the Monte-Carlo cells consume)."""
        return self.decode_batch(masks, method, iters=iters).errors

    def _onestep_batch(self, masks: np.ndarray) -> BatchDecode:
        G = self.code.G
        rhos = self.rhos_for(masks)
        W = rhos[:, None] * masks
        if self.backend == "numpy":
            errs = decoding.err1_batch(G, masks, rhos)
            return BatchDecode(weights=W, errors=errs)
        V = self._kernel_onestep(masks, rhos)
        errs = ((V - 1.0) ** 2).sum(axis=1)
        return BatchDecode(weights=W, errors=errs)

    def _kernel_onestep(self, masks: np.ndarray,
                        rhos: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        from ..kernels import ops
        m = jnp.asarray(masks)
        r = jnp.asarray(rhos.astype(np.float32))
        if self._use_ell():
            idx, val = self.code.ell()
            V = ops.batched_onestep_decode_ell(
                jnp.asarray(idx), jnp.asarray(val), m, r,
                impl=self.backend, tiles=self.tiles)
        else:
            V = ops.batched_onestep_decode(
                jnp.asarray(self.code.G.astype(np.float32)), m, r,
                impl=self.backend, tiles=self.tiles)
        return np.asarray(V, dtype=np.float64)

    def _optimal_batch(self, masks: np.ndarray) -> BatchDecode:
        G = self.code.G
        mode = self.optimal_impl
        if mode == "auto":
            mode = "gram"
        if mode == "pinv":
            # exact min-norm batched pinv (the scalar-oracle-equivalent
            # reference path; numpy only)
            W = decoding.optimal_weights_batch(G, masks, ridge=self.ridge)
        else:
            W = self._gram_weights(masks)
        errs = decoding.err_batch(G, W)
        return BatchDecode(weights=W, errors=errs)

    def _gram_weights(self, masks: np.ndarray) -> np.ndarray:
        """Masked-Gram normal-equations least squares (docs/families.md).

        The [B, n, n] Gram ensemble comes from the batched Pallas kernel
        on kernel backends and from numpy on the numpy backend; for 0/1
        support matrices the Gram entries are small integers, so the
        kernel's fp32 ensemble is EXACT and the backends agree.  The
        batched LAPACK solve always runs in fp64 with a shared ridge
        floor (normal equations square the condition number; on
        rank-deficient supports the weights approach the min-norm
        solution as ridge -> 0 while the decode *errors* match the pinv
        path far tighter than the weights do).
        """
        ridge = max(self.ridge, 1e-6)
        if self._gram is None:
            G = self.code.G
            self._gram = (G.T @ G, G.sum(axis=0))
        gram, rhs0 = self._gram
        if self.backend == "numpy":
            return decoding.normal_eq_weights_batch(self.code.G, masks,
                                                    ridge=ridge,
                                                    gram=gram, rhs0=rhs0)
        import jax.numpy as jnp

        from ..kernels import ops
        gram_dev = jnp.asarray(gram.astype(np.float32))   # once per call
        W = np.zeros(masks.shape)
        for sl in decoding._batch_chunks(masks.shape[0], self.n, self.n):
            Mg = np.asarray(ops.batched_masked_gram(
                gram_dev, jnp.asarray(masks[sl]), impl=self.backend,
                tiles=self.tiles))
            W[sl] = decoding.solve_masked_gram(Mg, masks[sl], rhs0, ridge)
        return W

    def _algorithmic_batch(self, masks: np.ndarray,
                           iters: int) -> BatchDecode:
        G = self.code.G
        if self.backend == "numpy":
            W, errs = decoding.algorithmic_weights_batch(
                G, masks, iters, return_errors=True)
            return BatchDecode(weights=W, errors=errs)
        import jax.numpy as jnp

        from ..kernels import ops
        nus = decoding.spectral_norm_sq_batch(G, masks)
        U, X = ops.batched_algorithmic_decode(
            jnp.asarray(G.astype(np.float32)), jnp.asarray(masks),
            jnp.asarray(nus.astype(np.float32)), int(iters),
            impl=self.backend, tiles=self.tiles, return_weights=True)
        W = np.asarray(X, dtype=np.float64) * masks
        errs = (np.asarray(U, dtype=np.float64) ** 2).sum(axis=1)
        return BatchDecode(weights=W, errors=errs)

    def _ignore_batch(self, masks: np.ndarray) -> BatchDecode:
        G = self.code.G
        colnnz = (G != 0).sum(axis=0).astype(np.float64)
        cover = np.maximum(masks @ colnnz, 1.0)
        W = masks * (self.k / cover)[:, None]
        errs = decoding.err_batch(G, W)
        return BatchDecode(weights=W, errors=errs)

    # ------------------------------------------------------------------
    # fused decode-apply (one-step decode folded into the accumulate)
    # ------------------------------------------------------------------

    def onestep_scales(self, masks: np.ndarray, *,
                       renorm: bool = False) -> np.ndarray:
        """[B] per-mask scalar s_b with one-step weights w_b = s_b m_b.

        renorm=False gives the raw rho_b = k/(r_b s); renorm=True folds
        ``decoding.exact_decode_renorm`` in analytically: the renormed
        one-step weight is ``w * k / sum(G w)`` and for w = rho*m the
        rho cancels, leaving ``k / (m @ colsum(G))`` — with the same
        tot <= 1e-6 skip rule (all-straggler rows keep the raw rho).
        """
        masks = decoding._as_masks(masks, self.n)
        self.fused_calls += 1
        rhos = self.rhos_for(masks)
        if not renorm:
            return rhos
        denom = masks.astype(np.float64) @ self.code.G.sum(axis=0)
        tot = rhos * denom
        return np.where(tot > 1e-6, self.k / np.where(denom == 0, 1.0, denom),
                        rhos)

    def decode_apply_batch(self, masks: np.ndarray, messages: np.ndarray, *,
                           renorm: bool = False,
                           impl: Optional[str] = None) -> np.ndarray:
        """One-step decode fused into the apply: [B, P] decoded grads.

        Equivalent to ``decode_batch(masks, 'onestep').weights @
        messages`` (with optional exact renorm) but in a single pass
        over the [L, P] worker messages — no weight ensemble, no error
        reduction.  ``impl`` overrides the kernel impl (defaults to the
        engine backend; numpy computes in fp64 BLAS).
        """
        masks = decoding._as_masks(masks, self.n)
        scales = self.onestep_scales(masks, renorm=renorm)
        backend = self.backend if impl is None else impl
        if backend == "numpy":
            W = scales[:, None] * masks
            return W @ np.asarray(messages, dtype=np.float64)
        import jax.numpy as jnp

        from ..kernels import ops
        out = ops.fused_decode_apply(
            jnp.asarray(np.asarray(messages, dtype=np.float32)),
            jnp.asarray(masks), jnp.asarray(scales.astype(np.float32)),
            impl=backend, tiles=self.tiles)
        return np.asarray(out, dtype=np.float64)

    # ------------------------------------------------------------------
    # single-mask decode with LRU cache (training hot path)
    # ------------------------------------------------------------------

    def decode(self, mask: np.ndarray, method: str = "onestep", *,
               iters: Optional[int] = None) -> np.ndarray:
        """[n] decode weights for one mask, memoized on the mask bytes.

        Adversarial and deadline straggler regimes repeat masks across
        steps; each distinct (mask, method) decodes exactly once.
        """
        mask = np.asarray(mask, dtype=bool)
        it = self.iters if iters is None else iters
        key = (method, it, mask.tobytes())
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            self._cache.move_to_end(key)
            return hit
        self.cache_misses += 1
        w = self.decode_batch(mask[None], method, iters=it).weights[0]
        w.setflags(write=False)   # cached array is shared — freeze it
        self._cache[key] = w
        if len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)
        return w

    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "size": len(self._cache), "maxsize": self._cache_size}

    def clear_cache(self) -> None:
        self._cache.clear()
        self.cache_hits = self.cache_misses = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"DecodeEngine(code={self.code.name!r}, k={self.k}, "
                f"n={self.n}, backend={self.backend!r})")
