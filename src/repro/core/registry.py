"""Scheme registry: the one declarative table of gradient-code families.

Before this module, every layer that switched on a scheme name (the
Monte-Carlo engine, ClusterSim, the frontier sweep, the trainer, the
coded all-reduce, the CLI, the benchmarks) carried its own hardcoded
``{frc, bgc, cyclic}``-style tuple, so adding a code family meant a
seven-file change.  Now a family is ONE record:

    register(CodeFamily(
        name="sbm",
        constructor=codes.sbm,
        decoders=("onestep", "optimal", "algorithmic", "ignore"),
        randomized=True,            # Monte-Carlo resamples code draws
        adversary="greedy",         # worst-case straggler profile
        param_grid={"s": (2, 5, 10), "blocks": (2, 4, 8)},
    ))

and every consumer resolves through :func:`get` / :func:`names` /
:func:`make`:

  * ``core.simulate`` asks ``randomized`` instead of RESAMPLED_SCHEMES;
  * ``sim.cluster`` / ``sim.frontier`` build codes by name and check
    the requested decoder against ``decoders``;
  * ``training.train_loop`` validates (scheme, decoder) pairs up front;
  * ``launch.train`` derives its CLI choices from ``names()``;
  * the benchmarks sweep ``families()`` filtered by capability.

See docs/families.md for the contract and the one-file recipe for adding
a family.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from . import codes as codes_lib
from . import theory
from .codes import GradientCode

__all__ = [
    "DECODERS",
    "CodeFamily",
    "register",
    "get",
    "find",
    "families",
    "names",
    "make",
    "randomized_schemes",
]

# decoder surface of core.engine.DecodeEngine / core.decoding
DECODERS = ("onestep", "optimal", "algorithmic", "ignore")

def _lb_err_frac(k: int, n: int, s: int, delta: float) -> float:
    """Fundamental lower bound on err/k at straggler fraction delta,
    evaluated with the fixed-survivor-count (hypergeometric) form —
    the weaker of the two forms, so the floor never over-rejects."""
    r = max(0, min(n, int(round((1.0 - delta) * n))))
    return theory.fundamental_err_lower_bound(k, s, r, n) / k


# adversary profiles (paper Sec. 4): "block" = the linear-time FRC
# block-killing adversary applies structurally; "greedy" = only the
# generic poly-time greedy/random-search adversaries; "none" = no
# redundancy to attack (uncoded)
ADVERSARY_PROFILES = ("block", "greedy", "none")


@dataclasses.dataclass(frozen=True)
class CodeFamily:
    """Declarative record for one gradient-code family.

    ``constructor(k, n, s, rng=..., **params)`` must return a
    :class:`~repro.core.codes.GradientCode` whose ``name`` equals this
    record's name (``with_workers`` elasticity rebuilds through it).
    ``validate`` returns a human-readable reason when (k, n, s) is not
    constructible, else None — the registry's pre-flight check that
    turns constructor tracebacks into actionable errors.
    """

    name: str
    constructor: Callable[..., GradientCode]
    description: str = ""
    decoders: Tuple[str, ...] = DECODERS
    randomized: bool = False          # MC averages over code draws too
    adversary: str = "greedy"         # block | greedy | none
    deterministic_rng_free: bool = False  # constructor ignores rng
    param_grid: Mapping[str, Tuple] = dataclasses.field(
        default_factory=dict)     # declarative sweep defaults (metadata)
    validate: Optional[Callable[[int, int, int], Optional[str]]] = None

    def __post_init__(self):
        unknown = set(self.decoders) - set(DECODERS)
        if unknown:
            raise ValueError(f"family {self.name!r} declares unknown "
                             f"decoders {sorted(unknown)}; have {DECODERS}")
        if self.adversary not in ADVERSARY_PROFILES:
            raise ValueError(f"family {self.name!r} adversary profile "
                             f"{self.adversary!r} not in {ADVERSARY_PROFILES}")

    # ------------------------------------------------------------------
    # capability queries
    # ------------------------------------------------------------------

    def supports_decoder(self, decoder: str) -> bool:
        return decoder in self.decoders

    def require_decoder(self, decoder: str) -> None:
        """Raise the one canonical incompatibility error (shared by the
        MC engine, ClusterSim and the trainer — one message format)."""
        if decoder not in self.decoders:
            raise ValueError(f"family {self.name!r} does not declare "
                             f"decoder {decoder!r}; supported: "
                             f"{self.decoders}")

    def check(self, k: int, n: int, s: int) -> Optional[str]:
        """None when (k, n, s) is constructible, else the reason."""
        if k <= 0 or n <= 0:
            return f"k={k}, n={n} must be positive"
        if not (1 <= s <= k):
            return f"s={s} must be in [1, k={k}]"
        if self.validate is not None:
            return self.validate(k, n, s)
        return None

    def legal_s(self, k: int, n: int, lo: int = 1,
                hi: Optional[int] = None, *,
                delta: Optional[float] = None,
                error_budget: Optional[float] = None) -> Tuple[int, ...]:
        """All s in [lo, hi] this family can construct at (k, n).

        The ragged-size test harness picks from this instead of
        special-casing divisibility rules (FRC needs s | k, s-regular
        needs k*s even) per family.

        With ``delta=`` and ``error_budget=`` the ladder is additionally
        filtered by the Wang et al. fundamental limit: rungs whose
        lower bound already exceeds the budget (err/k) at straggler
        fraction delta are budget-infeasible for EVERY code and decoder,
        so no amount of calibration can admit them.
        """
        hi = k if hi is None else min(hi, k)
        rungs = tuple(s for s in range(max(lo, 1), hi + 1)
                      if self.check(k, n, s) is None)
        if error_budget is None:
            return rungs
        if delta is None:
            raise ValueError("error_budget= requires delta= (the straggler "
                             "fraction the budget must hold at)")
        return tuple(s for s in rungs
                     if _lb_err_frac(k, n, s, delta) <= error_budget)

    def s_floor(self, k: int, n: int, *, delta: float,
                error_budget: float) -> int:
        """Smallest constructible s whose fundamental lower bound fits
        the err/k budget at straggler fraction delta.

        Derived from theory.fundamental_err_lower_bound (Wang et al.),
        which holds for every assignment matrix of column sparsity s and
        every decoder — below this floor the budget is information-
        theoretically impossible, not merely uncalibrated.  Raises
        ValueError when no legal s fits.
        """
        feasible = self.legal_s(k, n, delta=delta, error_budget=error_budget)
        if not feasible:
            best = self.legal_s(k, n)
            detail = ""
            if best:
                lb = _lb_err_frac(k, n, best[-1], delta)
                detail = (f" (even s={best[-1]} has fundamental lower "
                          f"bound err/k >= {lb:.4g})")
            raise ValueError(
                f"no s in [1, {k}] lets family {self.name!r} meet "
                f"err/k <= {error_budget:g} at delta={delta:g} for "
                f"(k={k}, n={n}){detail}; raise the error budget, lower "
                f"delta, or add workers")
        return feasible[0]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    def make(self, k: int, n: int, s: int,
             rng: Optional[np.random.Generator] = None,
             seed: Optional[int] = None, *,
             delta: Optional[float] = None,
             error_budget: Optional[float] = None,
             **params) -> GradientCode:
        """Build a code, optionally enforcing the fundamental-limit floor.

        With ``delta=`` and ``error_budget=`` the requested s is checked
        against the Wang et al. lower bound and rejected (with the
        feasible floor named) when the budget is provably unreachable.
        """
        reason = self.check(k, n, s)
        if reason is not None:
            raise ValueError(
                f"cannot construct {self.name!r} at (k={k}, n={n}, s={s}): "
                f"{reason}; legal s at this size: "
                f"{self.legal_s(k, n, hi=min(k, 64))}")
        if error_budget is not None:
            if delta is None:
                raise ValueError("error_budget= requires delta= (the "
                                 "straggler fraction the budget must hold "
                                 "at)")
            lb = _lb_err_frac(k, n, s, delta)
            if lb > error_budget:
                floor = self.s_floor(k, n, delta=delta,
                                     error_budget=error_budget)
                raise ValueError(
                    f"s={s} is below the fundamental-limit floor for "
                    f"{self.name!r} at (k={k}, n={n}): the Wang et al. "
                    f"lower bound gives err/k >= {lb:.4g} > budget "
                    f"{error_budget:g} at delta={delta:g} for EVERY code "
                    f"of this sparsity and every decoder; smallest "
                    f"feasible s is {floor} (raise s, raise the budget, "
                    f"or lower delta)")
        if rng is None:
            rng = np.random.default_rng(0 if seed is None else seed)
        return self.constructor(k, n, s, rng=rng, **params)


_REGISTRY: Dict[str, CodeFamily] = {}


def register(family: CodeFamily, *, overwrite: bool = False) -> CodeFamily:
    """Add a family to the registry (the one-file extension point)."""
    if family.name in _REGISTRY and not overwrite:
        raise ValueError(f"code family {family.name!r} already registered; "
                         f"pass overwrite=True to replace it")
    _REGISTRY[family.name] = family
    return family


def get(name: str) -> CodeFamily:
    fam = _REGISTRY.get(name)
    if fam is None:
        raise KeyError(
            f"unknown code family {name!r}; registered families: "
            f"{sorted(_REGISTRY)}. Add one with "
            f"repro.core.registry.register(CodeFamily(name={name!r}, "
            f"constructor=...)) — see docs/families.md.")
    return fam


def find(name: str) -> Optional[CodeFamily]:
    """Non-raising lookup (for codes built outside the registry)."""
    return _REGISTRY.get(name)


def families() -> Tuple[CodeFamily, ...]:
    """All registered families, in registration order."""
    return tuple(_REGISTRY.values())


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def make(name: str, k: int, n: int, s: int,
         rng: Optional[np.random.Generator] = None,
         seed: Optional[int] = None, *,
         delta: Optional[float] = None,
         error_budget: Optional[float] = None, **params) -> GradientCode:
    """The factory every scheme-switch resolves through.

    ``delta=`` + ``error_budget=`` opt into the fundamental-limit floor
    (reject s the Wang et al. bound proves budget-infeasible)."""
    return get(name).make(k, n, s, rng=rng, seed=seed, delta=delta,
                          error_budget=error_budget, **params)


def randomized_schemes() -> Tuple[str, ...]:
    """Families whose construction is random (MC resamples code draws)."""
    return tuple(f.name for f in _REGISTRY.values() if f.randomized)


# --------------------------------------------------------------------------
# built-in families (paper + follow-up literature)
# --------------------------------------------------------------------------


def _square(k: int, n: int, s: int) -> Optional[str]:
    if n != k:
        return f"requires n == k (got k={k}, n={n})"
    return None


def _frc_check(k: int, n: int, s: int) -> Optional[str]:
    if n != k:
        return f"FRC requires n == k (got k={k}, n={n})"
    if k % s != 0:
        return f"FRC requires s | k (got k={k}, s={s})"
    return None


def _sregular_check(k: int, n: int, s: int) -> Optional[str]:
    if n != k:
        return f"s-regular code requires n == k (got k={k}, n={n})"
    if (k * s) % 2 != 0:
        return f"s-regular graph needs k*s even (k={k}, s={s})"
    if s >= k:
        return f"need s < k (s={s}, k={k})"
    return None


register(CodeFamily(
    name="frc",
    constructor=codes_lib.frc,
    description="Fractional repetition (block-diagonal 1_{sxs}); best "
                "average error, worst adversarial case (Thm 10)",
    adversary="block",
    param_grid={"s": (2, 5, 10)},
    validate=_frc_check,
))

register(CodeFamily(
    name="bgc",
    constructor=codes_lib.bgc,
    description="Bernoulli gradient code G_ij ~ Bern(s/k) (paper Sec. 5)",
    randomized=True,
    param_grid={"s": (2, 5, 10)},
))

register(CodeFamily(
    name="rbgc",
    constructor=codes_lib.rbgc,
    description="Regularized BGC: column degree capped at 2s (Alg. 3)",
    randomized=True,
    param_grid={"s": (2, 5, 10)},
))

register(CodeFamily(
    name="sregular",
    constructor=codes_lib.sregular,
    description="Random s-regular graph adjacency (Raviv et al. expander "
                "baseline)",
    randomized=True,
    param_grid={"s": (4, 6, 10)},
    validate=_sregular_check,
))

register(CodeFamily(
    name="sbm",
    constructor=codes_lib.sbm,
    description="Stochastic-block-model code: intra/inter-cluster "
                "Bernoulli densities (Charles & Papailiopoulos)",
    randomized=True,
    param_grid={"s": (2, 5, 10), "blocks": (2, 4, 8),
                "intra": (0.5, 0.7, 0.9)},
))

register(CodeFamily(
    name="expander",
    constructor=codes_lib.expander,
    description="(s, ns/k)-biregular random bipartite code; least-squares "
                "decoding beats one-step at equal replication "
                "(Glasgow & Wootters)",
    randomized=True,
    param_grid={"s": (2, 5, 10)},
))

register(CodeFamily(
    name="cyclic",
    constructor=codes_lib.cyclic_repetition,
    description="Cyclic repetition support (Tandon et al. pattern, "
                "all-ones coefficients)",
    deterministic_rng_free=True,
    param_grid={"s": (2, 5, 10)},
))

register(CodeFamily(
    name="uncoded",
    constructor=codes_lib.uncoded,
    description="Identity assignment, no redundancy",
    adversary="none",
    deterministic_rng_free=True,
    param_grid={"s": (1,)},
    validate=_square,
))
