"""Task <-> example assignment: how a gradient code meets a physical batch.

The global (physical) batch of B rows is laid out as

    [n workers] x [slots tasks/worker] x [T rows/task-slot]

with B = n * slots * T.  Each slot of worker j holds one of the worker's
assigned tasks (column support of G), so the same *unique* task data is
replicated across all workers assigned that task.  k unique tasks cover
B_unique = k * T distinct examples; redundancy = B / B_unique.

For decode weights w (from repro.core.decoding), the per-slot loss weight

    weight[j, t] = w_j * G[task(j,t), j] / (k * T)

makes  sum_{j,t,rows} weight * loss_row  ==  (decoded approximation of)
the mean loss over the k*T unique examples.  This identity — decode as
loss reweighting — is what lets the whole scheme run inside a vanilla
data-parallel all-reduce (docs/architecture.md §2.1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .codes import GradientCode

__all__ = ["CodedAssignment", "build_assignment"]


@dataclasses.dataclass(frozen=True)
class CodedAssignment:
    """Static (per-run) assignment tables, all numpy, all host-side."""

    code_name: str
    k: int                  # number of tasks
    n: int                  # number of workers (DP groups)
    slots: int              # task slots per worker (max column degree)
    task_ids: np.ndarray    # (n, slots) int32, -1 = empty slot
    coeffs: np.ndarray      # (n, slots) float32, G[task, worker] (0 if empty)
    G: np.ndarray           # (k, n) the code matrix

    @property
    def replication(self) -> float:
        return float((self.task_ids >= 0).sum()) / self.k

    def slot_weights(self, w: np.ndarray, rows_per_slot: int) -> np.ndarray:
        """Per-slot loss weights for decode weights w (n,).

        Normalized so an exact decode (G @ w == 1_k) yields exactly the
        mean loss over the k * rows_per_slot unique examples.
        """
        w = np.asarray(w, dtype=np.float64)
        if w.shape != (self.n,):
            raise ValueError(f"w shape {w.shape} != ({self.n},)")
        denom = float(self.k * rows_per_slot)
        # stays float64: the G coefficients are exact (0/1 codes) and the
        # consumers cast at the device boundary — the fp64 differential
        # tests need the host-side weights unrounded
        sw = (w[:, None] * self.coeffs.astype(np.float64)) / denom
        return np.where(self.task_ids >= 0, sw, 0.0)

    def row_weights(self, w: np.ndarray, rows_per_slot: int) -> np.ndarray:
        """Flat per-row weights of shape (n * slots * rows_per_slot,)."""
        sw = self.slot_weights(w, rows_per_slot)
        return np.repeat(sw.reshape(-1), rows_per_slot)

    def unique_row_of_slot(self, rows_per_slot: int) -> np.ndarray:
        """(n*slots*rows_per_slot,) index into the unique-example space
        [0, k*rows_per_slot) — identifies replicated rows; -1 for padding."""
        base = self.task_ids.reshape(-1).astype(np.int64)
        out = np.empty((self.n * self.slots, rows_per_slot), dtype=np.int64)
        for idx, t in enumerate(base):
            if t < 0:
                out[idx] = -1
            else:
                out[idx] = np.arange(rows_per_slot) + t * rows_per_slot
        return out.reshape(-1)


def build_assignment(code: GradientCode, slots: Optional[int] = None
                     ) -> CodedAssignment:
    """Pack a code's column supports into fixed-width slot tables."""
    G = code.G
    k, n = G.shape
    degrees = (G != 0).sum(axis=0)
    min_slots = int(degrees.max()) if n else 0
    if slots is None:
        slots = max(min_slots, 1)
    if slots < min_slots:
        raise ValueError(f"slots={slots} < max column degree {min_slots}")
    task_ids = np.full((n, slots), -1, dtype=np.int32)
    coeffs = np.zeros((n, slots), dtype=np.float32)
    for j in range(n):
        support = np.flatnonzero(G[:, j])
        task_ids[j, : len(support)] = support
        coeffs[j, : len(support)] = G[support, j]
    return CodedAssignment(
        code_name=code.name, k=k, n=n, slots=slots,
        task_ids=task_ids, coeffs=coeffs, G=G,
    )
