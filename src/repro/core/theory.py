"""Closed-form results from the paper, used to validate Monte-Carlo runs.

Every function cites its theorem.  Combinatorial quantities use exact
integer arithmetic (math.comb) and return floats.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "thm5_expected_err1_frc",
    "thm5_expected_err1_frc_exact",
    "thm6_expected_err_frc",
    "thm6_expected_err_frc_as_printed",
    "thm7_tail_frc",
    "thm8_s_threshold",
    "cor9_s_zero_error",
    "thm10_frc_worstcase_err",
    "thm3_expander_err1_bound",
    "thm21_bgc_err1_bound",
    "thm24_rbgc_err1_bound",
    "lemma4_expected_gram_frc",
    "expected_err1_bgc_exact",
]


def thm5_expected_err1_frc(k: int, s: int, delta: float) -> float:
    """Theorem 5: E[err_1(A_frac)] with rho = k/(rs), r = (1-delta)k.

    E = delta*k / ((1-delta)*s) - (1/(1-delta)) * (s-1)/s
    """
    if not (0 <= delta < 1):
        raise ValueError("delta in [0,1)")
    return delta * k / ((1 - delta) * s) - (s - 1) / (s * (1 - delta))


def thm5_expected_err1_frc_exact(k: int, s: int, r: int) -> float:
    """Corrected (exact) version of Theorem 5.

    The paper's Lemma 4 states P(a_j duplicates a_i) = (s-1)/k, but under
    *without replacement* column sampling the exact probability is
    (s-1)/(k-1) — there are s-1 duplicates among the k-1 remaining
    columns.  Propagating through the Theorem-5 algebra:

        E[err_1] = (k^2/(r^2 s^2)) * ( r s + r (r-1) s (s-1) / (k-1) ) - k.

    Monte Carlo matches this form to sampling error (see
    tests/test_theory_mc.py); the paper's stated formula is its k -> inf
    limit and understates the error by Theta(1) for finite k (documented
    in EXPERIMENTS.md).
    """
    if r == 0:
        return float(k)
    return (k**2 / (r**2 * s**2)) * (r * s + r * (r - 1) * s * (s - 1) / (k - 1)) - k


def thm6_expected_err_frc(k: int, s: int, r: int) -> float:
    """Theorem 6 (corrected): E[err(A_frac)] = k * C(k-s, r) / C(k, r).

    The paper prints C(k-s, r-s)/C(k, r), but P(block i fully straggled)
    = P(all r non-stragglers drawn from the other k-s columns)
    = C(k-s, r)/C(k, r) — which is also what the paper's own Theorem 7
    uses with alpha+1 = 1.  Monte Carlo and the exact inclusion-exclusion
    pmf (frc_err_distribution) confirm the corrected form; see
    EXPERIMENTS.md errata."""
    if k - s < r:
        return 0.0
    return k * math.comb(k - s, r) / math.comb(k, r)


def thm6_expected_err_frc_as_printed(k: int, s: int, r: int) -> float:
    """The formula exactly as printed in the paper (for the errata bench)."""
    if r < s:
        return float(k)
    return k * math.comb(k - s, r - s) / math.comb(k, r)


def thm7_tail_frc(k: int, s: int, r: int, alpha: int) -> float:
    """Theorem 7: upper bound on P(err(A_frac) > alpha*s).

    P <= C(k/s, alpha+1) * C(k-(alpha+1)s, r) / C(k, r).
    """
    if k % s:
        raise ValueError("FRC needs s | k")
    top = k - (alpha + 1) * s
    if top < r:
        return 0.0
    bound = math.comb(k // s, alpha + 1) * math.comb(top, r) / math.comb(k, r)
    return min(1.0, bound)


def thm8_s_threshold(k: int, delta: float, alpha: int) -> float:
    """Theorem 8: s >= (1 + 1/(1+alpha)) log(k)/(1-delta) gives
    P(err > alpha*s) <= 1/k."""
    return (1 + 1 / (1 + alpha)) * math.log(k) / (1 - delta)


def cor9_s_zero_error(k: int, delta: float) -> float:
    """Corollary 9: s >= 2 log(k)/(1-delta) gives P(err > 0) <= 1/k."""
    return 2 * math.log(k) / (1 - delta)


def thm10_frc_worstcase_err(k: int, r: int) -> float:
    """Theorem 10: adversarial optimal-decoding error of FRC is k - r."""
    return float(k - r)


def thm3_expander_err1_bound(k: int, s: int, delta: float, lam: float) -> float:
    """Raviv et al. bound (as stated in Sec. 6):
    err_1(A) <= (lam(G)^2 / s^2) * delta*k / (1-delta), for any delta*k
    stragglers (worst case)."""
    return (lam**2 / s**2) * delta * k / (1 - delta)


def thm21_bgc_err1_bound(k: int, s: int, delta: float, c: float = 1.0) -> float:
    """Theorem 21 shape: err_1(A) <= C^2 k / ((1-delta) s), s >= log k.

    C is the universal constant from concentration (Lemma 18); pass the
    empirically calibrated value via `c` when comparing to Monte Carlo.
    """
    return c**2 * k / ((1 - delta) * s)


def thm24_rbgc_err1_bound(k: int, s: int, delta: float, alpha: float = 1.0,
                          c: float = 1.0) -> float:
    """Theorem 24 shape: err_1(A') <= C^2 alpha^3 k / ((1-delta) s), all s>=1."""
    return c**2 * alpha**3 * k / ((1 - delta) * s)


def lemma4_expected_gram_frc(k: int, s: int) -> tuple[float, float]:
    """Lemma 4: E[a_i . a_j] = s (i==j) and s^2/k - s/k (i != j)."""
    return float(s), s**2 / k - s / k


def expected_err1_bgc_exact(k: int, s: int, r: int) -> float:
    """Exact E[err_1(A)] for the (unregularized) BGC with rho = k/(rs).

    Derivation (not in the paper; used to sanity-check simulations):
    entries iid Bernoulli(p), p = s/k.  With v = rho * A 1_r,
    E[||v - 1||^2] = k * (rho^2 * (r*p*(1-p) + (r*p)^2) - 2*rho*r*p + 1).
    """
    p = s / k
    if r == 0:
        return float(k)
    rho = k / (r * s)
    m2 = r * p * (1 - p) + (r * p) ** 2  # E[(row sum)^2]
    return k * (rho**2 * m2 - 2 * rho * r * p + 1)


def frc_err_distribution(k: int, s: int, r: int, max_alpha: int | None = None
                         ) -> np.ndarray:
    """Exact pmf of err(A_frac)/s = number of missing blocks (inclusion-
    exclusion over the k/s blocks under without-replacement sampling).

    P(exactly m blocks missing) = C(B, m) * sum_{j} (-1)^j C(B-m, j)
        * C(k-(m+j)s, r) / C(k, r),   B = k/s.
    """
    if k % s:
        raise ValueError("s | k required")
    B = k // s
    max_alpha = B if max_alpha is None else min(max_alpha, B)
    denom = math.comb(k, r)
    pmf = np.zeros(max_alpha + 1)
    for m in range(max_alpha + 1):
        acc = 0.0
        for j in range(B - m + 1):
            top = k - (m + j) * s
            if top < r:
                break
            acc += (-1) ** j * math.comb(B - m, j) * math.comb(top, r) / denom
        pmf[m] = math.comb(B, m) * acc
    return np.clip(pmf, 0.0, 1.0)
