"""Closed-form results from the paper, used to validate Monte-Carlo runs.

Every function cites its theorem.  Combinatorial quantities use exact
integer arithmetic (math.comb) and return floats.

Beyond the source paper this module carries the *fundamental limit* of
approximate gradient coding (Wang, Liu & Shroff, arXiv:1901.08166): a
computation-load/error lower bound that every code family — not just
the paper's constructions — can be measured against.  See
docs/theory.md for the full theorem -> function -> source-paper map,
and core.certify for the spectral-gap certificates built on top.
"""

from __future__ import annotations

import functools
import math

import numpy as np

__all__ = [
    "thm5_expected_err1_frc",
    "thm5_expected_err1_frc_exact",
    "thm6_expected_err_frc",
    "thm6_expected_err_frc_as_printed",
    "thm7_tail_frc",
    "thm8_s_threshold",
    "cor9_s_zero_error",
    "thm10_frc_worstcase_err",
    "thm3_expander_err1_bound",
    "thm21_bgc_err1_bound",
    "thm24_rbgc_err1_bound",
    "lemma4_expected_gram_frc",
    "expected_err1_bgc_exact",
    "fundamental_err_lower_bound",
    "fundamental_err_lower_bound_load",
    "gap_to_optimal",
]


def thm5_expected_err1_frc(k: int, s: int, delta: float) -> float:
    """Theorem 5: E[err_1(A_frac)] with rho = k/(rs), r = (1-delta)k.

    E = delta*k / ((1-delta)*s) - (1/(1-delta)) * (s-1)/s
    """
    if not (0 <= delta < 1):
        raise ValueError("delta in [0,1)")
    return delta * k / ((1 - delta) * s) - (s - 1) / (s * (1 - delta))


def thm5_expected_err1_frc_exact(k: int, s: int, r: int) -> float:
    """Corrected (exact) version of Theorem 5.

    The paper's Lemma 4 states P(a_j duplicates a_i) = (s-1)/k, but under
    *without replacement* column sampling the exact probability is
    (s-1)/(k-1) — there are s-1 duplicates among the k-1 remaining
    columns.  Propagating through the Theorem-5 algebra:

        E[err_1] = (k^2/(r^2 s^2)) * ( r s + r (r-1) s (s-1) / (k-1) ) - k.

    Monte Carlo matches this form to sampling error (see
    tests/test_theory_mc.py); the paper's stated formula is its k -> inf
    limit and understates the error by Theta(1) for finite k (documented
    in EXPERIMENTS.md).
    """
    if r == 0:
        return float(k)
    return (k**2 / (r**2 * s**2)) * (r * s + r * (r - 1) * s * (s - 1) / (k - 1)) - k


def thm6_expected_err_frc(k: int, s: int, r: int) -> float:
    """Theorem 6 (corrected): E[err(A_frac)] = k * C(k-s, r) / C(k, r).

    The paper prints C(k-s, r-s)/C(k, r), but P(block i fully straggled)
    = P(all r non-stragglers drawn from the other k-s columns)
    = C(k-s, r)/C(k, r) — which is also what the paper's own Theorem 7
    uses with alpha+1 = 1.  Monte Carlo and the exact inclusion-exclusion
    pmf (frc_err_distribution) confirm the corrected form; see
    EXPERIMENTS.md errata."""
    if k - s < r:
        return 0.0
    return k * math.comb(k - s, r) / math.comb(k, r)


def thm6_expected_err_frc_as_printed(k: int, s: int, r: int) -> float:
    """The formula exactly as printed in the paper (for the errata bench)."""
    if r < s:
        return float(k)
    return k * math.comb(k - s, r - s) / math.comb(k, r)


def thm7_tail_frc(k: int, s: int, r: int, alpha: int) -> float:
    """Theorem 7: upper bound on P(err(A_frac) > alpha*s).

    P <= C(k/s, alpha+1) * C(k-(alpha+1)s, r) / C(k, r).
    """
    if k % s:
        raise ValueError("FRC needs s | k")
    top = k - (alpha + 1) * s
    if top < r:
        return 0.0
    bound = math.comb(k // s, alpha + 1) * math.comb(top, r) / math.comb(k, r)
    return min(1.0, bound)


def thm8_s_threshold(k: int, delta: float, alpha: int) -> float:
    """Theorem 8: s >= (1 + 1/(1+alpha)) log(k)/(1-delta) gives
    P(err > alpha*s) <= 1/k."""
    return (1 + 1 / (1 + alpha)) * math.log(k) / (1 - delta)


def cor9_s_zero_error(k: int, delta: float) -> float:
    """Corollary 9: s >= 2 log(k)/(1-delta) gives P(err > 0) <= 1/k."""
    return 2 * math.log(k) / (1 - delta)


def thm10_frc_worstcase_err(k: int, r: int) -> float:
    """Theorem 10: adversarial optimal-decoding error of FRC is k - r."""
    return float(k - r)


def thm3_expander_err1_bound(k: int, s: int, delta: float, lam: float) -> float:
    """Raviv et al. bound (as stated in Sec. 6):
    err_1(A) <= (lam(G)^2 / s^2) * delta*k / (1-delta), for any delta*k
    stragglers (worst case)."""
    return (lam**2 / s**2) * delta * k / (1 - delta)


def thm21_bgc_err1_bound(k: int, s: int, delta: float, c: float = 1.0) -> float:
    """Theorem 21 shape: err_1(A) <= C^2 k / ((1-delta) s), s >= log k.

    C is the universal constant from concentration (Lemma 18); pass the
    empirically calibrated value via `c` when comparing to Monte Carlo.
    """
    return c**2 * k / ((1 - delta) * s)


def thm24_rbgc_err1_bound(k: int, s: int, delta: float, alpha: float = 1.0,
                          c: float = 1.0) -> float:
    """Theorem 24 shape: err_1(A') <= C^2 alpha^3 k / ((1-delta) s), all s>=1."""
    return c**2 * alpha**3 * k / ((1 - delta) * s)


def lemma4_expected_gram_frc(k: int, s: int) -> tuple[float, float]:
    """Lemma 4: E[a_i . a_j] = s (i==j) and s^2/k - s/k (i != j)."""
    return float(s), s**2 / k - s / k


def expected_err1_bgc_exact(k: int, s: int, r: int) -> float:
    """Exact E[err_1(A)] for the (unregularized) BGC with rho = k/(rs).

    Derivation (not in the paper; used to sanity-check simulations):
    entries iid Bernoulli(p), p = s/k.  With v = rho * A 1_r,
    E[||v - 1||^2] = k * (rho^2 * (r*p*(1-p) + (r*p)^2) - 2*rho*r*p + 1).
    """
    p = s / k
    if r == 0:
        return float(k)
    rho = k / (r * s)
    m2 = r * p * (1 - p) + (r * p) ** 2  # E[(row sum)^2]
    return k * (rho**2 * m2 - 2 * rho * r * p + 1)


@functools.lru_cache(maxsize=65536)
def fundamental_err_lower_bound(k: int, s: int, r: int, n: int | None = None
                                ) -> float:
    """Wang-Liu-Shroff fundamental limit (arXiv:1901.08166, Thm 1 shape).

    For ANY assignment matrix G in {0,1}^{k x n} whose total computation
    load is at most n*s (column degree <= s on average), and ANY decoder,
    the expected squared error under a uniformly random set of r
    survivors satisfies

        E[err] >= min over degree profiles d_1..d_k, sum d_i <= n*s of
                  sum_i C(n - d_i, r) / C(n, r),

    because a task whose d_i assigned workers all straggle is *uncovered*
    and contributes at least 1 to ||G m w - 1||^2 for every weight vector
    w (the task's row of the decoded sum is exactly 0, the target is 1).
    f(d) = C(n-d, r)/C(n, r) is convex in d (its successive ratio
    (n-d-r)/(n-d) is decreasing), so the minimum splits the n*s replica
    budget as evenly as integer degrees allow:

        d_lo = floor(n*s/k),  k_hi = n*s - k*d_lo  tasks get  d_lo + 1.

        LB = (k - k_hi) * f(d_lo) + k_hi * f(d_lo + 1).

    Equality holds for FRC under optimal decoding (Theorem 6:
    thm6_expected_err_frc(k, s, r) == LB when n == k and s | k), which
    makes FRC *optimal* among all codes of the same load — the reference
    point for gap_to_optimal.  Returns the unnormalized error in [0, k];
    divide by k for the err/k convention used by the frontier.
    """
    n = k if n is None else n
    if not (0 <= r <= n):
        raise ValueError(f"need 0 <= r <= n, got r={r}, n={n}")
    if k <= 0 or s < 0:
        raise ValueError("k >= 1 and s >= 0 required")
    if r == 0:
        return float(k)
    denom = math.comb(n, r)

    def f(d: int) -> float:
        d = min(d, n)
        return math.comb(n - d, r) / denom if n - d >= r else 0.0

    budget = n * s
    d_lo = budget // k
    k_hi = budget - k * d_lo
    return (k - k_hi) * f(d_lo) + k_hi * f(d_lo + 1)


def fundamental_err_lower_bound_load(k: int, s: int, delta: float,
                                     n: int | None = None) -> float:
    """Normalized-load (iid-straggler) form of the fundamental limit.

    When each worker straggles independently with probability delta, a
    task of degree d is uncovered with probability delta**d, so

        E[err] >= (k - k_hi) * delta**d_lo + k_hi * delta**(d_lo + 1)

    with the same even integer split of the n*s replica budget
    (delta**d is convex in d).  Note the fixed-r hypergeometric form is
    tighter at the same mean load: C(n-d, r)/C(n, r) <= (1 - r/n)**d,
    so use `fundamental_err_lower_bound` when the survivor *count* is
    fixed and this form when workers straggle independently (the
    ClusterSim deadline policies are closer to the iid model).
    Returns the unnormalized error in [0, k].
    """
    n = k if n is None else n
    if not (0.0 <= delta <= 1.0):
        raise ValueError(f"delta in [0, 1] required, got {delta}")
    if k <= 0 or s < 0:
        raise ValueError("k >= 1 and s >= 0 required")
    budget = n * s
    d_lo = budget // k
    k_hi = budget - k * d_lo
    return (k - k_hi) * delta**d_lo + k_hi * delta ** (d_lo + 1)


def gap_to_optimal(measured_err: float, k: int, s: int, *,
                   r: int | None = None, delta: float | None = None,
                   n: int | None = None) -> float:
    """Ratio of a measured error to the fundamental lower bound.

    Pass `r` for the fixed-survivor-count (hypergeometric) bound or
    `delta` for the iid-straggler bound — exactly one of the two.
    A gap of 1.0 means the family sits on the fundamental limit (FRC
    with optimal decoding); larger means headroom.  Returns inf when
    the bound is 0 (e.g. delta == 0) but error was measured, and 1.0
    when both are (numerically) zero.
    """
    if (r is None) == (delta is None):
        raise ValueError("pass exactly one of r= or delta=")
    if r is not None:
        lb = fundamental_err_lower_bound(k, s, r, n)
    else:
        lb = fundamental_err_lower_bound_load(k, s, delta, n)
    if lb <= 0.0:
        return 1.0 if measured_err <= 1e-12 else math.inf
    return max(0.0, measured_err) / lb


def frc_err_distribution(k: int, s: int, r: int, max_alpha: int | None = None
                         ) -> np.ndarray:
    """Exact pmf of err(A_frac)/s = number of missing blocks (inclusion-
    exclusion over the k/s blocks under without-replacement sampling).

    P(exactly m blocks missing) = C(B, m) * sum_{j} (-1)^j C(B-m, j)
        * C(k-(m+j)s, r) / C(k, r),   B = k/s.
    """
    if k % s:
        raise ValueError("s | k required")
    B = k // s
    max_alpha = B if max_alpha is None else min(max_alpha, B)
    denom = math.comb(k, r)
    pmf = np.zeros(max_alpha + 1)
    for m in range(max_alpha + 1):
        acc = 0.0
        for j in range(B - m + 1):
            top = k - (m + j) * s
            if top < r:
                break
            acc += (-1) ** j * math.comb(B - m, j) * math.comb(top, r) / denom
        pmf[m] = math.comb(B, m) * acc
    return np.clip(pmf, 0.0, 1.0)
