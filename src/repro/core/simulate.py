"""Monte-Carlo simulation engine for decoding errors (paper Sec. 6).

Reproduces the quantities in Figs. 2-5: average err_1(A)/k and err(A)/k
over random straggler draws, and the algorithmic-decoder curve ||u_t||^2/k.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from . import codes as codes_lib
from . import decoding

__all__ = [
    "sample_straggler_mask",
    "MCResult",
    "monte_carlo_error",
    "sweep_delta",
    "algorithmic_curve_mc",
]


def sample_straggler_mask(n: int, num_stragglers: int, rng: np.random.Generator
                          ) -> np.ndarray:
    """Uniform without-replacement straggler draw -> boolean keep-mask."""
    mask = np.ones(n, dtype=bool)
    if num_stragglers > 0:
        mask[rng.choice(n, size=num_stragglers, replace=False)] = False
    return mask


@dataclasses.dataclass
class MCResult:
    scheme: str
    decoder: str
    k: int
    n: int
    s: int
    delta: float
    trials: int
    mean: float  # mean err/k
    std: float
    q05: float
    q95: float
    p_zero: float  # fraction of trials with (near-)zero error


def _one_trial_error(G: np.ndarray, mask: np.ndarray, decoder: str, s: int,
                     iters: int = 8) -> float:
    k = G.shape[0]
    A = G[:, mask]
    r = int(mask.sum())
    if decoder == "onestep":
        return decoding.err1(A, decoding.default_rho(k, r, s))
    if decoder == "optimal":
        return decoding.err(A)
    if decoder == "algorithmic":
        return float(decoding.algorithmic_error_curve(A, iters)[-1])
    raise ValueError(decoder)


def monte_carlo_error(
    scheme: str,
    k: int,
    n: int,
    s: int,
    delta: float,
    trials: int,
    decoder: str = "onestep",
    seed: int = 0,
    resample_code: bool = True,
    iters: int = 8,
) -> MCResult:
    """Average decoding error over `trials` random straggler draws.

    resample_code=True redraws the (random) code each trial, matching the
    paper's averaging over both code and straggler randomness; FRC/cyclic
    are deterministic so this only matters for bgc/rbgc/sregular.
    """
    rng = np.random.default_rng(seed)
    num_straggle = int(round(delta * n))
    code = codes_lib.make_code(scheme, k=k, n=n, s=s, rng=rng)
    errs = np.empty(trials)
    for t in range(trials):
        if resample_code and scheme in ("bgc", "rbgc", "sregular"):
            code = codes_lib.make_code(scheme, k=k, n=n, s=s, rng=rng)
        mask = sample_straggler_mask(n, num_straggle, rng)
        errs[t] = _one_trial_error(code.G, mask, decoder, s, iters=iters)
    errs = errs / k
    return MCResult(
        scheme=scheme, decoder=decoder, k=k, n=n, s=s, delta=delta,
        trials=trials, mean=float(errs.mean()), std=float(errs.std()),
        q05=float(np.quantile(errs, 0.05)), q95=float(np.quantile(errs, 0.95)),
        p_zero=float((errs < 1e-9).mean()),
    )


def sweep_delta(
    schemes: Sequence[str],
    deltas: Sequence[float],
    k: int,
    s: int,
    trials: int,
    decoder: str = "onestep",
    seed: int = 0,
) -> List[MCResult]:
    out: List[MCResult] = []
    for scheme in schemes:
        for d in deltas:
            out.append(monte_carlo_error(scheme, k=k, n=k, s=s, delta=d,
                                         trials=trials, decoder=decoder,
                                         seed=seed))
    return out


def algorithmic_curve_mc(
    scheme: str,
    k: int,
    s: int,
    delta: float,
    trials: int,
    iters: int,
    seed: int = 0,
) -> np.ndarray:
    """Mean ||u_t||^2/k curve, t = 0..iters (Fig. 5)."""
    rng = np.random.default_rng(seed)
    num_straggle = int(round(delta * k))
    acc = np.zeros(iters + 1)
    for _ in range(trials):
        code = codes_lib.make_code(scheme, k=k, n=k, s=s, rng=rng)
        mask = sample_straggler_mask(k, num_straggle, rng)
        acc += decoding.algorithmic_error_curve(code.G[:, mask], iters)
    return acc / (trials * k)
