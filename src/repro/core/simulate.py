"""Monte-Carlo simulation engine for decoding errors (paper Sec. 6).

Reproduces the quantities in Figs. 2-5: average err_1(A)/k and err(A)/k
over random straggler draws, and the algorithmic-decoder curve ||u_t||^2/k.

Batched architecture: each (scheme, delta, decoder) cell samples ALL of
its trial masks up front (`sample_straggler_masks`) and hands them to a
DecodeEngine as one [trials, n] ensemble — one batched decode per cell
instead of a Python loop over trials.  Schemes the registry declares
randomized (bgc / rbgc / sregular / sbm / expander) additionally average
over `code_draws` independent code draws, splitting the trials across
them (one batched decode per draw); deterministic schemes use a single
draw.  Scheme names resolve through core.registry.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from . import decoding
from . import registry
from .engine import DecodeEngine

__all__ = [
    "sample_straggler_mask",
    "sample_straggler_masks",
    "MCResult",
    "monte_carlo_error",
    "sweep_delta",
    "algorithmic_curve_mc",
    "RESAMPLED_SCHEMES",
]


def _resampled() -> tuple:
    """Schemes whose construction is random: the paper averages over
    code AND straggler randomness for these.  Declared per-family in
    the registry (CodeFamily.randomized), not hardcoded here."""
    return registry.randomized_schemes()


# legacy alias (module-load snapshot); prefer registry.randomized_schemes()
RESAMPLED_SCHEMES = _resampled()


def sample_straggler_mask(n: int, num_stragglers: int, rng: np.random.Generator
                          ) -> np.ndarray:
    """Uniform without-replacement straggler draw -> boolean keep-mask."""
    mask = np.ones(n, dtype=bool)
    if num_stragglers > 0:
        mask[rng.choice(n, size=num_stragglers, replace=False)] = False
    return mask


def sample_straggler_masks(n: int, num_stragglers: int, trials: int,
                           rng: np.random.Generator) -> np.ndarray:
    """[trials, n] boolean keep-masks, each an independent uniform
    without-replacement draw of `num_stragglers` stragglers.

    Vectorized: rank one uniform matrix per trial instead of `trials`
    calls to rng.choice.
    """
    masks = np.ones((trials, n), dtype=bool)
    if num_stragglers <= 0:
        return masks
    u = rng.random((trials, n))
    idx = np.argpartition(u, num_stragglers - 1, axis=1)[:, :num_stragglers]
    masks[np.arange(trials)[:, None], idx] = False
    return masks


@dataclasses.dataclass
class MCResult:
    scheme: str
    decoder: str
    k: int
    n: int
    s: int
    delta: float
    trials: int
    mean: float  # mean err/k
    std: float
    q05: float
    q95: float
    p_zero: float  # fraction of trials with (near-)zero error


def _trial_groups(trials: int, groups: int) -> List[int]:
    """Split `trials` into `groups` near-equal positive chunk sizes."""
    groups = max(1, min(groups, trials))
    base, rem = divmod(trials, groups)
    return [base + (1 if g < rem else 0) for g in range(groups)]


def monte_carlo_error(
    scheme: str,
    k: int,
    n: int,
    s: int,
    delta: float,
    trials: int,
    decoder: str = "onestep",
    seed: int = 0,
    resample_code: bool = True,
    iters: int = 8,
    code_draws: int = 16,
    backend: str = "numpy",
) -> MCResult:
    """Average decoding error over `trials` random straggler draws.

    resample_code=True averages over the code randomness as well
    (matching the paper): `code_draws` independent codes are drawn and
    the trials are split across them, so the decode stays batched.
    FRC/cyclic/uncoded are deterministic and always use a single code.
    """
    fam = registry.get(scheme)
    fam.require_decoder(decoder)
    rng = np.random.default_rng(seed)
    num_straggle = int(round(delta * n))
    draws = code_draws if (resample_code and fam.randomized) else 1
    errs = np.empty(trials)
    lo = 0
    for chunk in _trial_groups(trials, draws):
        code = fam.make(k=k, n=n, s=s, rng=rng)
        masks = sample_straggler_masks(n, num_straggle, chunk, rng)
        # nominal s, NOT inferred from G's density: the paper's
        # rho = k/(r s) calibration uses the construction parameter.
        # pinv keeps the MC error curves on the exact least-squares
        # oracle (the golden pins predate the gram default).
        eng = DecodeEngine(code, backend=backend, iters=iters, s=s,
                           optimal_impl="pinv")
        errs[lo: lo + chunk] = eng.errors_batch(masks, decoder)
        lo += chunk
    errs = errs / k
    return MCResult(
        scheme=scheme, decoder=decoder, k=k, n=n, s=s, delta=delta,
        trials=trials, mean=float(errs.mean()), std=float(errs.std()),
        q05=float(np.quantile(errs, 0.05)), q95=float(np.quantile(errs, 0.95)),
        p_zero=float((errs < 1e-9).mean()),
    )


def sweep_delta(
    schemes: Sequence[str],
    deltas: Sequence[float],
    k: int,
    s: int,
    trials: int,
    decoder: str = "onestep",
    seed: int = 0,
    backend: str = "numpy",
) -> List[MCResult]:
    out: List[MCResult] = []
    for scheme in schemes:
        for d in deltas:
            out.append(monte_carlo_error(scheme, k=k, n=k, s=s, delta=d,
                                         trials=trials, decoder=decoder,
                                         seed=seed, backend=backend))
    return out


def algorithmic_curve_mc(
    scheme: str,
    k: int,
    s: int,
    delta: float,
    trials: int,
    iters: int,
    seed: int = 0,
    code_draws: int = 16,
) -> np.ndarray:
    """Mean ||u_t||^2/k curve, t = 0..iters (Fig. 5), batched per draw."""
    fam = registry.get(scheme)
    rng = np.random.default_rng(seed)
    num_straggle = int(round(delta * k))
    draws = code_draws if fam.randomized else 1
    acc = np.zeros(iters + 1)
    for chunk in _trial_groups(trials, draws):
        code = fam.make(k=k, n=k, s=s, rng=rng)
        masks = sample_straggler_masks(k, num_straggle, chunk, rng)
        curves = decoding.algorithmic_error_curve_batch(code.G, masks, iters)
        acc += curves.sum(axis=0)
    return acc / (trials * k)
