"""Gradient-code constructions (assignment matrices G).

The paper's objects: a k x n *function assignment matrix* G whose column j
supports the tasks computed by worker j, with entries giving the linear
combination the worker returns.  All constructions here are O(k * n) or
better, which is the paper's selling point versus Ramanujan/expander
constructions.

Conventions
-----------
* G has shape (k, n): k tasks (gradient partitions), n workers.
* Column sparsity ~ s tasks per worker.
* All constructions are deterministic given a seed.
* Matrices are small (k, n <= a few thousand) and kept as dense float64
  numpy arrays; the training path consumes them as constants.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

__all__ = [
    "GradientCode",
    "frc",
    "bgc",
    "rbgc",
    "sregular",
    "sbm",
    "expander",
    "cyclic_repetition",
    "uncoded",
    "make_code",
    "CODE_REGISTRY",
    "spectral_gap",
]


@dataclasses.dataclass(frozen=True)
class GradientCode:
    """An assignment matrix plus the metadata the runtime needs."""

    name: str
    G: np.ndarray  # (k, n)
    s: int  # nominal tasks/worker (column sparsity target)
    seed: Optional[int] = None
    # family construction params beyond (k, n, s) — e.g. sbm's
    # blocks/intra — as (key, value) pairs so the elastic rebuild
    # (with_workers) reconstructs the SAME variant, not the defaults
    params: Tuple[Tuple[str, object], ...] = ()

    @property
    def k(self) -> int:
        return int(self.G.shape[0])

    @property
    def n(self) -> int:
        return int(self.G.shape[1])

    @property
    def max_col_degree(self) -> int:
        return int((self.G != 0).sum(axis=0).max())

    @property
    def col_degrees(self) -> np.ndarray:
        return (self.G != 0).sum(axis=0)

    @property
    def row_degrees(self) -> np.ndarray:
        return (self.G != 0).sum(axis=1)

    def nonstraggler_submatrix(self, mask: np.ndarray) -> np.ndarray:
        """A = columns of G belonging to the non-stragglers (mask==True)."""
        mask = np.asarray(mask, dtype=bool)
        if mask.shape != (self.n,):
            raise ValueError(f"mask shape {mask.shape} != ({self.n},)")
        return self.G[:, mask]

    @property
    def density(self) -> float:
        """nnz(G) / (k n) — the paper's s/k sparsity for column-regular G."""
        return float((self.G != 0).sum()) / max(self.k * self.n, 1)

    def ell(self) -> Tuple[np.ndarray, np.ndarray]:
        """Row-major ELL packing of G: (col_idx [k, rmax] int32,
        vals [k, rmax] float32), zero-padded to the max row degree.

        Row i's nonzero columns sit left-justified in col_idx[i] with
        their coefficients in vals[i]; padding entries have idx 0 and
        val 0 so gather-and-accumulate kernels can ignore them.  The
        decoders only ever form G @ (masked weights), so the row packing
        is the kernel-facing view of the paper's column sparsity
        (row degree ~ n s / k = s when n = k): a batched one-step decode
        reads B*k*rmax mask entries instead of streaming B*k*n dense
        zeros.  Cached after the first call (G is immutable).
        """
        cached = self.__dict__.get("_ell")
        if cached is None:
            nz = self.G != 0
            deg = nz.sum(axis=1)
            rmax = max(int(deg.max()) if deg.size else 0, 1)
            idx = np.zeros((self.k, rmax), dtype=np.int32)
            val = np.zeros((self.k, rmax), dtype=np.float32)
            for i in range(self.k):
                cols = np.flatnonzero(nz[i])
                idx[i, : len(cols)] = cols
                val[i, : len(cols)] = self.G[i, cols]
            cached = (idx, val)
            object.__setattr__(self, "_ell", cached)  # frozen dataclass
        return cached

    def with_workers(self, n: int, rng: np.random.Generator) -> "GradientCode":
        """Rebuild the same family for a different worker count (elastic).

        Family params (sbm blocks/intra, ...) carry over so the rebuilt
        code is the same VARIANT, not the family defaults.
        """
        fam = self.name.split("(")[0]
        return make_code(fam, k=n, n=n, s=self.s, rng=rng,
                        **dict(self.params))


def _check(k: int, n: int, s: int) -> None:
    if k <= 0 or n <= 0:
        raise ValueError(f"k={k}, n={n} must be positive")
    if not (1 <= s <= k):
        raise ValueError(f"s={s} must be in [1, k={k}]")


def frc(k: int, n: int, s: int, rng: Optional[np.random.Generator] = None) -> GradientCode:
    """Fractional Repetition Code (paper Sec. 3, from Tandon et al.).

    Block-diagonal 1_{s x s} blocks: k tasks and n=k workers, s | k.  Block
    b's s workers each compute the same s tasks.  A random column
    permutation is applied when an rng is provided (the adversarial
    analysis in Sec. 4.1 is permutation-invariant; tests exercise both).
    """
    _check(k, n, s)
    if n != k:
        raise ValueError(f"FRC requires n == k (got k={k}, n={n})")
    if k % s != 0:
        raise ValueError(f"FRC requires s | k (got k={k}, s={s})")
    G = np.zeros((k, n), dtype=np.float64)
    for b in range(k // s):
        G[b * s : (b + 1) * s, b * s : (b + 1) * s] = 1.0
    if rng is not None:
        G = G[:, rng.permutation(n)]
    return GradientCode(name="frc", G=G, s=s, seed=None)


def bgc(k: int, n: int, s: int, rng: np.random.Generator) -> GradientCode:
    """Bernoulli Gradient Code (paper Sec. 5): G_ij ~ Bernoulli(s/k)."""
    _check(k, n, s)
    G = (rng.random((k, n)) < (s / k)).astype(np.float64)
    return GradientCode(name="bgc", G=G, s=s)


def rbgc(k: int, n: int, s: int, rng: np.random.Generator) -> GradientCode:
    """Regularized BGC (paper Algorithm 3).

    Draw Bernoulli(s/k) entries; any column with degree > 2s is pruned
    (random edges removed) until its degree is exactly s.  Guarantees
    max column degree <= 2s so Thm 24's bound applies for all s >= 1.
    """
    _check(k, n, s)
    G = (rng.random((k, n)) < (s / k)).astype(np.float64)
    for j in range(n):
        d = int(G[:, j].sum())
        if d > 2 * s:
            support = np.flatnonzero(G[:, j])
            drop = rng.choice(support, size=d - s, replace=False)
            G[drop, j] = 0.0
    return GradientCode(name="rbgc", G=G, s=s)


def sregular(k: int, n: int, s: int, rng: np.random.Generator) -> GradientCode:
    """Random s-regular graph adjacency code (Raviv et al. baseline).

    G = adjacency matrix of a random simple s-regular graph on k vertices
    (k == n).  Random regular graphs are expanders with high probability
    (lambda -> 2 sqrt(s-1), near-Ramanujan) so this is the efficient
    stand-in for the expander-code baseline, exactly as in the paper's
    simulations (Sec. 6).
    """
    _check(k, n, s)
    if n != k:
        raise ValueError(f"s-regular code requires n == k (got k={k}, n={n})")
    if (k * s) % 2 != 0:
        raise ValueError(f"s-regular graph needs k*s even (k={k}, s={s})")
    if s >= k:
        raise ValueError(f"need s < k (s={s}, k={k})")
    import networkx as nx

    g = nx.random_regular_graph(d=s, n=k, seed=int(rng.integers(2**31 - 1)))
    G = nx.to_numpy_array(g, dtype=np.float64)
    return GradientCode(name="sregular", G=G, s=s)


def block_ids(count: int, blocks: int) -> np.ndarray:
    """[count] int block id per index, contiguous near-equal blocks.

    The one partition rule shared by the SBM code construction and the
    clustered-straggler trace source, so a clustered trace's failing
    blocks line up with the code's worker blocks.
    """
    blocks = max(1, min(blocks, count))
    ids = np.empty(count, dtype=np.int64)
    for b, chunk in enumerate(np.array_split(np.arange(count), blocks)):
        ids[chunk] = b
    return ids


def sbm(k: int, n: int, s: int, rng: np.random.Generator, *,
        blocks: int = 4, intra: float = 0.7) -> GradientCode:
    """Stochastic-block-model code (Charles & Papailiopoulos 2017).

    Tasks and workers are partitioned into `blocks` contiguous clusters
    and G_ij ~ Bernoulli(p_in) when task i and worker j share a cluster,
    Bernoulli(p_out) otherwise.  `intra` is the fraction of a worker's
    expected s tasks drawn from its own cluster; densities are
    calibrated per worker so E[column degree] == s regardless of ragged
    block sizes.  blocks=1 (or intra such that p_in == p_out) recovers
    the BGC; high `intra` concentrates redundancy inside clusters, the
    regime where clustered (pod-correlated) stragglers separate the
    families.
    """
    _check(k, n, s)
    if not (0.0 <= intra <= 1.0):
        raise ValueError(f"intra={intra} must be in [0, 1]")
    # both sides must share ONE block count or the membership lookup
    # below misaligns (k < blocks <= n would index past tasks_in)
    blocks = max(1, min(blocks, k, n))
    t_id = block_ids(k, blocks)
    w_id = block_ids(n, blocks)
    tasks_in = np.bincount(t_id, minlength=blocks).astype(np.float64)
    k_in = tasks_in[w_id]                           # [n] own-cluster tasks
    k_out = k - k_in
    # per-worker expected-degree budgets: intra*s own-cluster, the rest
    # cross-cluster.  A side that saturates (expected degree would need
    # p > 1, e.g. small own-cluster at high intra) SPILLS its excess to
    # the other side rather than dropping it, so E[column degree] == s
    # holds at every ragged block size (s <= k guarantees capacity) and
    # the paper's rho = k/(r s) calibration stays valid.
    want_in = np.full(n, intra * s)
    want_out = np.full(n, (1.0 - intra) * s)
    eff_in = np.minimum(want_in, k_in)
    eff_out = np.minimum(want_out + (want_in - eff_in), k_out)
    eff_in = np.minimum(eff_in + (want_out + (want_in - eff_in) - eff_out),
                        k_in)
    p_in = np.divide(eff_in, k_in, out=np.zeros(n), where=k_in > 0)
    p_out = np.divide(eff_out, k_out, out=np.zeros(n), where=k_out > 0)
    same = t_id[:, None] == w_id[None, :]           # [k, n]
    P = np.where(same, p_in[None, :], p_out[None, :])
    G = (rng.random((k, n)) < P).astype(np.float64)
    return GradientCode(name="sbm", G=G, s=s,
                        params=(("blocks", blocks), ("intra", intra)))


def expander(k: int, n: int, s: int, rng: np.random.Generator) -> GradientCode:
    """Regular random bipartite code (Glasgow & Wootters 2021).

    Every worker computes exactly s tasks and every task is replicated
    ⌊ns/k⌋ or ⌈ns/k⌉ times — the (s, ns/k)-biregular support whose
    least-squares decoding beats one-step decoding at the same
    replication.  Sampled by degree-balanced random selection: each
    column picks the s least-replicated tasks with random tie-breaking,
    which keeps both sides regular at every ragged (k, n, s) and is a
    random near-regular bipartite graph (an expander w.h.p., like the
    configuration model).
    """
    _check(k, n, s)
    G = np.zeros((k, n), dtype=np.float64)
    row_deg = np.zeros(k, dtype=np.float64)
    for j in rng.permutation(n):
        pick = np.argsort(row_deg + rng.random(k), kind="stable")[:s]
        G[pick, j] = 1.0
        row_deg[pick] += 1.0
    return GradientCode(name="expander", G=G, s=s)


def cyclic_repetition(k: int, n: int, s: int, rng: Optional[np.random.Generator] = None) -> GradientCode:
    """Cyclic support code: worker j computes tasks {j, j+1, ..., j+s-1} mod k.

    The support pattern of Tandon et al.'s cyclic codes with all-ones
    coefficients; a deterministic, load-balanced baseline whose one-step
    decoding behaves like a circulant smoothing operator.
    """
    _check(k, n, s)
    G = np.zeros((k, n), dtype=np.float64)
    cols = np.arange(n)
    for off in range(s):
        G[(cols * k // n + off) % k, cols] = 1.0
    return GradientCode(name="cyclic", G=G, s=s)


def uncoded(k: int, n: Optional[int] = None, s: int = 1,
            rng: Optional[np.random.Generator] = None) -> GradientCode:
    """Identity assignment: worker j computes task j only (no redundancy)."""
    n = k if n is None else n
    if n != k:
        raise ValueError("uncoded requires n == k")
    return GradientCode(name="uncoded", G=np.eye(k, dtype=np.float64), s=1)


# Raw constructor table, kept for direct access; the declarative layer
# (decoder compatibilities, param grids, adversary profiles, validation)
# lives in core.registry, which is the factory every scheme-switch in
# the repo resolves through.
CODE_REGISTRY: Dict[str, Callable[..., GradientCode]] = {
    "frc": frc,
    "bgc": bgc,
    "rbgc": rbgc,
    "sregular": sregular,
    "sbm": sbm,
    "expander": expander,
    "cyclic": cyclic_repetition,
    "uncoded": uncoded,
}


def make_code(
    name: str,
    k: int,
    n: int,
    s: int,
    rng: Optional[np.random.Generator] = None,
    seed: Optional[int] = None,
    **params,
) -> GradientCode:
    """Factory used by configs / CLI: make_code('bgc', k=128, n=128, s=5).

    Delegates to core.registry (the authoritative scheme table) so
    unknown names raise the registry's actionable error and family
    extras (e.g. sbm's blocks/intra) pass through.
    """
    from . import registry  # deferred: registry imports this module

    return registry.make(name, k=k, n=n, s=s, rng=rng, seed=seed, **params)


def spectral_gap(code: GradientCode) -> float:
    """Second-largest singular value of G (= max(|lambda_2|, |lambda_k|)
    for symmetric square G).

    For a symmetric adjacency matrix (sregular) this is the classic
    expander gap used by theory.thm3_expander_err1_bound.  For the
    general bipartite k x n case (expander/sbm at ragged sizes) the
    right generalization is sigma_2 of the biadjacency matrix: the
    eigenvalues of the symmetric square [[0, G], [G^T, 0]] are exactly
    {+-sigma_i} plus |k - n| zeros, so sigma_2(G) IS the second-largest
    |eigenvalue| of the bipartite graph's adjacency matrix, and for
    symmetric nonnegative G it coincides with max(|lambda_2|,
    |lambda_k|) (Perron: lambda_1 dominates).  core.certify turns this
    into an adversarial-erasure error certificate.
    """
    G = code.G
    if G.shape[0] == G.shape[1] and np.allclose(G, G.T):
        lam = np.linalg.eigvalsh(G)
        return float(max(abs(lam[0]), abs(lam[-2])))
    sig = np.linalg.svd(G, compute_uv=False)
    if sig.size < 2:
        raise ValueError("spectral_gap needs min(k, n) >= 2")
    return float(sig[1])
