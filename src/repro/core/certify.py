"""Spectral-gap certificates for graph-structured gradient codes.

Raviv, Tamo, Tandon & Dimakis ("Gradient Coding from Cyclic MDS Codes
and Expander Graphs", arXiv:1707.03858) bound the one-step decoding
error of an expander-based code by its spectral gap — a *worst-case*
(adversarial-erasure) guarantee, unlike the in-expectation bounds in
core.theory.  This module generalizes that argument to every bipartite
k x n assignment matrix in the registry, including irregular ones.

Derivation (self-contained; reduces exactly to the paper's Theorem for
k = n biregular G):

With one-step decoding, v = rho_r * G m where m in {0,1}^n is the
survivor mask, |m| = r, rho_r = k/(r s).  Split m = (r/n) 1 + m_perp
and center G per-row:  E = G - (1/n) (G 1) 1^T,  so E 1 = 0 and
E m = E m_perp.  Then

    v - 1 = [ (k/(n s)) G 1 - 1 ]  +  rho_r * E m_perp
            '--- irregularity ---'    '--- spectral term ---'

and since ||m_perp||_2^2 = r(n - r)/n for EVERY mask with r survivors,

    err_1 = ||v - 1||_2^2  <=  ( b_irr + b_spec )^2,

    b_irr  = || (k/(n s)) G 1 - 1 ||_2          (0 for biregular G),
    b_spec = (k/(r s)) * sigma~ * sqrt(r (n-r)/n)
           = (k * sigma~ / s) * sqrt(delta / ((1 - delta) n)),

with sigma~ = ||E||_2 and delta = 1 - r/n.  For k = n biregular G,
sigma~ = lambda(G) (the centering removes exactly the Perron direction)
and the bound collapses to theory.thm3_expander_err1_bound:
(lambda^2/s^2) * delta k/(1-delta).

The certificate holds for EVERY survivor set of size >= r (adversarial
stragglers), and optimal/least-squares decoding can only do better on
the same mask, so it certifies both `onestep` and `optimal`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional

import numpy as np

from .codes import GradientCode

__all__ = [
    "SpectralCertificate",
    "certify",
    "adversarial_err1_bound",
    "certified_err_frac",
]


def adversarial_err1_bound(k: int, n: int, s: int, delta: float,
                           lam: float, irregularity: float = 0.0) -> float:
    """Worst-case one-step error over all masks with >= (1-delta)*n
    survivors: (b_irr + b_spec)^2, unnormalized (in units of err, not
    err/k).  `lam` is ||G - (1/n)(G 1)1^T||_2, `irregularity` is
    ||(k/(ns)) G 1 - 1||_2."""
    if not (0.0 <= delta < 1.0):
        raise ValueError(f"delta in [0, 1) required, got {delta}")
    if min(k, n, s) <= 0:
        raise ValueError("k, n, s >= 1 required")
    b_spec = (k * lam / s) * math.sqrt(delta / ((1.0 - delta) * n))
    return (irregularity + b_spec) ** 2


@dataclass(frozen=True)
class SpectralCertificate:
    """An adversarial-erasure error certificate for one assignment matrix.

    Fields are mask-independent; err1_bound(delta) instantiates the
    guarantee at a straggler fraction.  `lam` is the centered operator
    norm sigma~ (== the expander gap lambda(G) for biregular G);
    `irregularity` is the degree-imbalance term (0 for biregular G).
    """

    k: int
    n: int
    s: int
    lam: float
    irregularity: float
    sigma1: float  # top singular value of raw G, for diagnostics

    def err1_bound(self, delta: float) -> float:
        """Worst-case err_1 over every mask with >= (1-delta)*n
        survivors (unnormalized, certifies onestep AND optimal)."""
        return adversarial_err1_bound(self.k, self.n, self.s, delta,
                                      self.lam, self.irregularity)

    def err_frac_bound(self, delta: float) -> float:
        """err/k form, clipped to the trivial bound: err/k <= 1 always
        holds for one-step decoding only when rho G m has no overshoot,
        so we clip at the uncoded worst case k (err/k = 1 means 'the
        certificate says nothing better than losing every task')."""
        return min(1.0, self.err1_bound(delta) / self.k)

    def certifies(self, delta: float, err_frac_budget: float) -> bool:
        """True iff the theorem alone guarantees err/k <= budget at
        straggler fraction delta — for every adversarial mask."""
        return self.err_frac_bound(delta) <= err_frac_budget


def certify(code: GradientCode, s: Optional[int] = None) -> SpectralCertificate:
    """Compute the spectral certificate of a concrete assignment matrix.

    Works for any k x n binary G (square or ragged, regular or not).
    The one-step rho uses s = column sparsity; pass `s` explicitly if
    the code object's nominal s differs from the realized mean degree
    (bgc's Bernoulli columns — the certificate is for the realized G).
    """
    G = np.asarray(code.G, dtype=np.float64)
    k, n = G.shape
    s_eff = int(s if s is not None else code.s)
    if s_eff <= 0:
        raise ValueError("s >= 1 required")
    row = G.sum(axis=1)  # G 1, per-task replication counts
    E = G - np.outer(row, np.ones(n)) / n
    sig = np.linalg.svd(G, compute_uv=False)
    lam = float(np.linalg.norm(E, ord=2))
    irr = float(np.linalg.norm((k / (n * s_eff)) * row - 1.0))
    return SpectralCertificate(k=k, n=n, s=s_eff, lam=lam,
                               irregularity=irr, sigma1=float(sig[0]))


@lru_cache(maxsize=4096)
def _representative_cert(family: str, k: int, n: int, s: int,
                         seed: int) -> Optional[SpectralCertificate]:
    """Certificate of a pinned representative draw of a registry family.

    For deterministic families (frc/cyclic/uncoded/sregular at fixed
    seed) this IS the deployed matrix.  For randomized families the
    certificate is for one representative draw; the spectral gap of
    sparse random graphs concentrates (O(sqrt(s)) fluctuations around
    2 sqrt(s-1)), so it tracks any same-parameter draw closely — the
    honest contract is documented in docs/adaptive.md.  Returns None
    when the family can't build at (k, n, s).
    """
    from . import registry  # deferred: keep certify importable standalone

    try:
        code = registry.make(family, k=k, n=n, s=s, seed=seed)
    except (ValueError, KeyError):
        return None
    return certify(code, s=s)


def certified_err_frac(family: str, k: int, n: int, s: int, delta: float,
                       seed: int = 0) -> Optional[float]:
    """err/k certificate for a registry family at an operating point, or
    None when unavailable (family can't build, or the bound is vacuous
    i.e. >= 1).  Cached per (family, k, n, s, seed); delta is applied to
    the cached mask-independent certificate."""
    cert = _representative_cert(family, k, n, s, seed)
    if cert is None:
        return None
    frac = cert.err_frac_bound(delta)
    return frac if frac < 1.0 else None
