"""Adversarial straggler selection (paper Sec. 4).

* FRC worst case (Thm 10): kill whole repetition blocks; err = k - r,
  findable in O(k) with knowledge of the layout and O(k^2) from G alone
  (column dedup).
* General adversarial selection (r-ASP) is NP-hard (Thm 11, reduction from
  Densest-k-Subgraph).  We implement the reduction object itself (for the
  tests that check Eq. 4.2/4.3) plus two poly-time *heuristic* adversaries
  (greedy column removal, random search) that model what a realistic
  adversary could do against BGC/rBGC.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import decoding

__all__ = [
    "frc_adversarial_mask",
    "greedy_adversarial_mask",
    "random_search_adversarial_mask",
    "DkSReduction",
    "build_dks_reduction",
    "densest_k_subgraph_greedy",
]


def frc_adversarial_mask(G: np.ndarray, num_stragglers: int) -> np.ndarray:
    """Worst-case straggler set for an FRC (Thm 10), from G alone.

    Groups identical columns (the repetition blocks survive any column
    permutation), then kills entire blocks until the straggler budget is
    spent.  Runtime O(k * n) via hashing — better than the paper's O(k^2)
    column-compare bound.  Returns a boolean non-straggler mask.
    """
    G = np.asarray(G)
    k, n = G.shape
    groups: dict[bytes, list[int]] = {}
    for j in range(n):
        groups.setdefault(G[:, j].tobytes(), []).append(j)
    # kill the largest whole blocks first (each fully-killed block of size
    # s adds s to err); prefer blocks that fit in the remaining budget.
    blocks = sorted(groups.values(), key=len, reverse=True)
    mask = np.ones(n, dtype=bool)
    budget = num_stragglers
    for blk in blocks:
        if len(blk) <= budget:
            mask[blk] = False
            budget -= len(blk)
    if budget > 0:  # spend leftovers on partial blocks (adds no error, but
        for j in range(n):  # the adversary must pick exactly num_stragglers)
            if budget == 0:
                break
            if mask[j]:
                mask[j] = False
                budget -= 1
    return mask


def greedy_adversarial_mask(
    G: np.ndarray,
    num_stragglers: int,
    objective: str = "optimal",
    rho: Optional[float] = None,
) -> np.ndarray:
    """Greedy poly-time adversary: repeatedly remove the worker whose
    removal maximizes the decoding error.  O(num_stragglers * n) decodes.

    objective: 'optimal' -> err(A), 'onestep' -> err_1(A).
    """
    G = np.asarray(G, dtype=np.float64)
    k, n = G.shape
    s = max(1, int(round((G != 0).sum() / n)))
    mask = np.ones(n, dtype=bool)

    def score(m: np.ndarray) -> float:
        A = G[:, m]
        if objective == "optimal":
            return decoding.err(A)
        r = int(m.sum())
        return decoding.err1(A, rho if rho is not None else decoding.default_rho(k, r, s))

    for _ in range(num_stragglers):
        best_j, best_v = -1, -np.inf
        for j in np.flatnonzero(mask):
            mask[j] = False
            v = score(mask)
            mask[j] = True
            if v > best_v:
                best_j, best_v = j, v
        mask[best_j] = False
    return mask


def random_search_adversarial_mask(
    G: np.ndarray,
    num_stragglers: int,
    trials: int,
    rng: np.random.Generator,
    objective: str = "optimal",
) -> np.ndarray:
    """Best-of-`trials` random straggler sets (the weakest adversary)."""
    G = np.asarray(G, dtype=np.float64)
    k, n = G.shape
    s = max(1, int(round((G != 0).sum() / n)))
    best_mask, best_v = None, -np.inf
    for _ in range(trials):
        mask = np.ones(n, dtype=bool)
        mask[rng.choice(n, size=num_stragglers, replace=False)] = False
        A = G[:, mask]
        if objective == "optimal":
            v = decoding.err(A)
        else:
            r = n - num_stragglers
            v = decoding.err1(A, decoding.default_rho(k, r, s))
        if v > best_v:
            best_mask, best_v = mask, v
    return best_mask


# --------------------------------------------------------------------------
# Thm 11: the DkS -> r-ASP reduction, as a concrete constructible object.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DkSReduction:
    """The matrix C and bookkeeping of the Theorem-11 reduction.

    Given a d-regular graph (V, E) with |V| = nv and a target subgraph
    size kq, solving r-ASP on C with r = kq + (|E| - nv) is equivalent to
    finding the densest kq-subgraph.  `objective(x)` evaluates
    ||rho C x - 1||^2 for the selection x = [y; z] (Eq. 4.2);
    `predicted_objective(edges_in_S, a)` evaluates the closed form
    2 rho^2 e(S) + d rho^2 a - 2 rho d a + |E| used in the proof (with the
    corrected |E| = nv*d/2 edge count; see build_dks_reduction).
    """

    C: np.ndarray  # (ne, ne)
    adjacency: np.ndarray  # (nv, nv)
    d: int
    kq: int
    rho: float

    @property
    def nv(self) -> int:
        return self.adjacency.shape[0]

    @property
    def ne(self) -> int:
        return self.C.shape[0]

    @property
    def r(self) -> int:
        return self.kq + (self.ne - self.nv)

    def objective(self, x: np.ndarray) -> float:
        m = self.C.shape[0]
        v = self.rho * (self.C @ x) - np.ones(m)
        return float(v @ v)

    def predicted_objective(self, edges_in_s: int, a: int) -> float:
        return (2 * self.rho**2 * edges_in_s
                + self.d * self.rho**2 * a
                - 2 * self.rho * self.d * a
                + self.ne)


def build_dks_reduction(adjacency: np.ndarray, kq: int, rho: float = 0.5
                        ) -> DkSReduction:
    """Construct C = [B | 0] from the unsigned incidence matrix B of a
    d-regular graph (Thm 11 proof).  Requires rho in (0, 2/3)."""
    M = np.asarray(adjacency, dtype=np.float64)
    nv = M.shape[0]
    deg = M.sum(axis=1)
    d = int(deg[0])
    if not np.all(deg == d):
        raise ValueError("Thm 11 reduction requires a d-regular graph")
    if not (0 < rho < 2 / 3):
        raise ValueError("rho must lie in (0, 2/3)")
    edges = [(i, j) for i in range(nv) for j in range(i + 1, nv) if M[i, j]]
    ne = len(edges)
    if ne != nv * d // 2:
        raise ValueError("inconsistent adjacency")
    if ne < nv:
        raise ValueError("reduction needs |E| >= |V| (d >= 2)")
    # Standard unsigned incidence: B^T B = M + d I and 1^T B = d 1^T, which
    # is exactly what the Thm-11 proof uses.  (The paper states |E| = nd; a
    # d-regular graph has nd/2 undirected edges — the factor-2 miscount
    # does not affect the argument, only the padding width.  We build the
    # corrected ne x ne square C.)
    B = np.zeros((ne, nv))
    for e, (i, j) in enumerate(edges):
        B[e, i] = 1.0
        B[e, j] = 1.0
    C = np.concatenate([B, np.zeros((ne, ne - nv))], axis=1)
    return DkSReduction(C=C, adjacency=M, d=d, kq=kq, rho=rho)


def densest_k_subgraph_greedy(adjacency: np.ndarray, kq: int) -> np.ndarray:
    """Greedy peeling heuristic for DkS: repeatedly delete the minimum-
    degree vertex until kq remain.  Poly-time (the NP-hardness of the
    exact problem is the paper's point); returns vertex index array."""
    M = np.asarray(adjacency).copy().astype(np.float64)
    nv = M.shape[0]
    alive = np.ones(nv, dtype=bool)
    for _ in range(nv - kq):
        deg = M[alive][:, alive].sum(axis=1)
        idx = np.flatnonzero(alive)
        alive[idx[np.argmin(deg)]] = False
    return np.flatnonzero(alive)
