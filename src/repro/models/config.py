"""Architecture configuration.

One dataclass covers the whole assigned pool: dense / MoE / hybrid
(RG-LRU + local attention) / SSM (RWKV6) / encoder-decoder (Whisper) /
VLM-backbone.  Exact dimension sets live in repro/configs/<arch>.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "ArchConfig", "reduce_for_smoke"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # always-on shared experts (fine-grained MoE)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # 'ep'  : shard experts across the model axis (E % axis == 0)
    # 'tp'  : shard each expert's d_ff across the model axis
    expert_shard: str = "ep"
    # 'global'  : capacity dispatch over the whole (sharded) batch — one
    #             global sort; GSPMD materializes replicated [E, C, d]
    #             buffers (the paper-faithful naive port; baseline).
    # 'grouped' : per-sequence dispatch (vmapped over batch) — sort,
    #             gather and scatter stay local to the data shard; the
    #             Sec-Perf optimization (EXPERIMENTS.md).
    dispatch: str = "global"
    # Pad expert STORAGE to this count with zero-routed dummy experts so
    # the expert dim divides the 'model' axis (granite: 40 -> 48 on a
    # 16-way axis => clean EP; Sec-Perf iteration 2).  0 = no padding.
    pad_experts_to: int = 0

    @property
    def e_padded(self) -> int:
        return max(self.pad_experts_to, self.num_experts)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads

    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0        # 0 = global; >0 = sliding-window attention
    logit_softcap: float = 0.0

    # --- block composition ---
    # repeating pattern of block kinds; "attn" | "rec" (RG-LRU) | "rwkv"
    block_pattern: Tuple[str, ...] = ("attn",)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | geglu | gelu
    tie_embeddings: bool = False

    # --- family extras ---
    moe: Optional[MoEConfig] = None
    encoder_layers: int = 0      # >0 -> encoder-decoder
    frontend: str = "embed"      # embed | frames (audio stub) | patches (vlm stub)
    frontend_tokens: int = 0     # prefix length fed by the stub frontend
    rnn_width: int = 0           # RG-LRU recurrence width (0 -> d_model)
    conv_width: int = 4          # temporal conv kernel in recurrent blocks

    # --- distribution overrides (see dist.sharding.rules_for) ---
    batch_shard_model: bool = False  # attn-free: 'model' axis as extra DP
    fsdp_params: bool = False        # shard a replicated param dim on 'data'

    # --- numerics / runtime ---
    param_dtype: str = "float32"
    norm_io: str = "f32"         # f32 | bf16: dtype of norm outputs (fp32
                                 # reduction internals either way)
    loss_chunk: int = 0          # >0: head+CE in seq chunks (no full
                                 # [B,S,V] fp32 materialization)
    compute_dtype: str = "bfloat16"
    remat: str = "dots"          # none | dots | full
    scan_layers: bool = True
    attn_impl: str = "xla_chunked"   # xla_chunked | xla_naive | pallas | pallas_interpret
    seq_impl: str = "auto"           # recurrence impl: auto | scan | chunked
    vocab_pad_to: int = 256

    # --- optimizer schedule hint (minicpm uses WSD) ---
    schedule: str = "cosine"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        if self.n_heads and self.n_kv and self.n_heads % self.n_kv:
            raise ValueError(f"{self.name}: n_heads {self.n_heads} % n_kv {self.n_kv}")
        if self.family in ("encdec",) and self.encoder_layers <= 0:
            raise ValueError("encdec family needs encoder_layers > 0")

    # ----- derived sizes -----
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad_to)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv * self.d_head

    @property
    def d_rnn(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def pattern_counts(self) -> Tuple[int, int]:
        """(n_full_pattern_groups, n_remainder_layers)."""
        p = len(self.block_pattern)
        return self.n_layers // p, self.n_layers % p

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dff, V = self.d_model, self.d_ff, self.padded_vocab
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        n_up = 2 if self.act in ("swiglu", "geglu") else 1
        per_mlp = (n_up + 1) * d * dff
        per_moe = 0
        if self.moe is not None:
            m = self.moe
            per_moe = (m.num_experts + m.num_shared) * (n_up + 1) * d * m.d_ff_expert \
                + d * m.num_experts
        per_rec = 0
        if "rec" in self.block_pattern:
            dr = self.d_rnn
            per_rec = 2 * d * dr + dr * d + self.conv_width * dr + 2 * dr * (dr // 8) + dr
        total_blocks = 0
        counts = self._block_counts()
        for kind, cnt in counts.items():
            if kind == "attn":
                total_blocks += cnt * (per_attn + (per_moe if self.moe else per_mlp) + 2 * d)
            elif kind == "rec":
                total_blocks += cnt * (per_rec + per_mlp + 2 * d)
            elif kind == "rwkv":
                # time-mix (5 proj + decay lora) + channel-mix
                tm = 4 * d * d + d * d + 2 * d * 64
                cm = 2 * d * self.d_ff
                total_blocks += cnt * (tm + cm + 2 * d)
        if self.encoder_layers:
            enc = self.encoder_layers * (per_attn + per_mlp + 2 * d)
            dec_cross = self.n_layers * (per_attn + d)  # cross-attn blocks
            total_blocks += enc + dec_cross
        return emb + head + total_blocks + d  # final norm

    def _block_counts(self) -> dict:
        groups, rem = self.pattern_counts
        counts: dict = {}
        for kind in self.block_pattern:
            counts[kind] = counts.get(kind, 0) + groups
        for kind in self.block_pattern[:rem]:
            counts[kind] = counts.get(kind, 0) + 1
        return counts

    @property
    def supports_long_context(self) -> bool:
        """True iff decode cost is sub-quadratic in context (SSM / hybrid
        with bounded window) — gates the long_500k shape per the brief."""
        kinds = set(self.block_pattern)
        if "rwkv" in kinds and "attn" not in kinds:
            return True
        if "rec" in kinds:
            return self.local_window > 0  # bounded KV per attn layer
        return False


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    pat = len(cfg.block_pattern)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            num_shared=min(cfg.moe.num_shared, 1),
            # no capacity drops at smoke scale, so cached decode is exactly
            # parity with the full forward (drops are batch-dependent)
            capacity_factor=8.0)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(2 * pat, pat),         # at least 2 pattern groups
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab=503,                           # deliberately non-multiple of 256
        moe=moe,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_tokens=8 if cfg.frontend != "embed" else 0,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        rnn_width=64 if cfg.rnn_width else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        scan_layers=cfg.scan_layers,
        vocab_pad_to=64,
    )
