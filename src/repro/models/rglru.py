"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = [linear x-branch + gelu gate-branch] -> causal depthwise conv ->
input/recurrence gates -> RG-LRU diagonal linear recurrence -> gated
output projection.

    r_t = sigmoid(lowrank_a(u_t));  i_t = sigmoid(lowrank_x(u_t))
    log a_t = -c * softplus(Lambda) * r_t           (c = 8)
    h_t = exp(log a_t) * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is a first-order linear scan -> jax.lax.associative_scan
(train/prefill) or a single fused step (decode).  TPU adaptation: the
diagonal recurrence is embarrassingly parallel over channels, so the
channel dim is sharded over 'model' ('rnn' logical axis) and the scan is
over time only.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist import constrain
from .config import ArchConfig
from .spec import ParamSpec

__all__ = ["rec_block_specs", "rec_block_apply", "init_rec_cache",
           "rglru_scan_ref"]

_C = 8.0  # Griffin's gate sharpness constant


def rec_block_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    d, dr, cw = cfg.d_model, cfg.d_rnn, cfg.conv_width
    rank = max(dr // 8, 8)
    L = tuple("layers" for _ in prefix_shape)
    from .blocks import norm_specs, mlp_specs  # avoid cycle at import time
    return {
        "ln1": norm_specs(cfg, prefix_shape),
        "rec": {
            "wx": ParamSpec(prefix_shape + (d, dr), L + (None, "rnn")),
            "wgate": ParamSpec(prefix_shape + (d, dr), L + (None, "rnn")),
            "conv_w": ParamSpec(prefix_shape + (cw, dr), L + ("conv_k", "rnn"),
                                init="uniform_conv"),
            "conv_b": ParamSpec(prefix_shape + (dr,), L + ("rnn",), init="zeros"),
            "lam": ParamSpec(prefix_shape + (dr,), L + ("rnn",), init="ones",
                             scale=0.65),
            "wa_a": ParamSpec(prefix_shape + (dr, rank), L + ("rnn", "lora")),
            "wa_b": ParamSpec(prefix_shape + (rank, dr), L + ("lora", "rnn")),
            "wx_a": ParamSpec(prefix_shape + (dr, rank), L + ("rnn", "lora")),
            "wx_b": ParamSpec(prefix_shape + (rank, dr), L + ("lora", "rnn")),
            "wo": ParamSpec(prefix_shape + (dr, d), L + ("rnn", None)),
        },
        "ln2": norm_specs(cfg, prefix_shape),
        "mlp": mlp_specs(cfg, prefix_shape),
    }


def init_rec_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    dr, cw = cfg.d_rnn, cfg.conv_width
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, dr), dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           prev: Optional[jax.Array] = None) -> jax.Array:
    """x [B,S,dr], w [cw,dr]; left-pad with zeros or the cached tail."""
    cw = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(cw))
    return out + b


def rglru_scan_ref(u: jax.Array, log_a: jax.Array, h0: Optional[jax.Array] = None
                   ) -> jax.Array:
    """Reference linear recurrence h_t = a_t h_{t-1} + b_t via associative
    scan.  u = gated input sqrt(1-a^2)*i*x (fp32), log_a [B,S,dr]."""
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold the carried state into the first step's input
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h


def rec_block_apply(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Pre-norm RG-LRU residual block + MLP.  Returns (y, new_cache)."""
    from .layers import mlp, norm  # local import to avoid cycles

    p = params["rec"]
    B, S, _ = x.shape
    h_in = norm(x, params["ln1"], cfg.norm, io=cfg.norm_io)
    xb = jnp.einsum("bsd,de->bse", h_in, p["wx"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", h_in, p["wgate"]))
    xb = constrain(xb, "batch", None, "act_mlp")

    prev = None if cache is None else cache["conv"]
    u = _causal_depthwise_conv(xb, p["conv_w"], p["conv_b"], prev)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid((uf @ p["wa_a"].astype(jnp.float32))
                       @ p["wa_b"].astype(jnp.float32))
    i = jax.nn.sigmoid((uf @ p["wx_a"].astype(jnp.float32))
                       @ p["wx_b"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)

    use_pallas = cfg.seq_impl in ("pallas", "pallas_interpret")

    def _scan(u_, la_, h0_=None):
        if use_pallas:
            from ..kernels import ops as _kops  # late import: no cycle
            return _kops.rglru_scan(u_, la_, h0_, impl=cfg.seq_impl)
        return rglru_scan_ref(u_, la_, h0_)

    if cache is None:
        h = _scan(gated_in, log_a)
        new_cache = None
    else:
        if S == 1:
            h = jnp.exp(log_a[:, 0]) * cache["h"] + gated_in[:, 0]
            h = h[:, None]
        else:
            h = _scan(gated_in, log_a, cache["h"])
        tail = jnp.concatenate([prev.astype(xb.dtype), xb], axis=1)[:, -(cfg.conv_width - 1):]
        new_cache = {"h": h[:, -1].astype(jnp.float32), "conv": tail}

    out = (gate * h.astype(gate.dtype)) @ p["wo"]
    x = x + out

    h2 = norm(x, params["ln2"], cfg.norm, io=cfg.norm_io)
    return x + mlp(h2, params["mlp"], cfg.act), new_cache
