"""Mixture-of-Experts layer: top-k routing with sort-based capacity
dispatch (gather -> grouped einsum -> scatter-add), plus a dense oracle
used by tests.

Sharding: experts across the 'model' axis when E divides it (dbrx, EP);
otherwise each expert's d_ff is tensor-parallel (granite, E=40).  The
dispatch is written with global gathers so GSPMD inserts the all-to-all.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..dist import constrain
from .config import ArchConfig
from .spec import ParamSpec

__all__ = ["moe_specs", "moe_apply", "moe_apply_dense"]


def _expert_axes(cfg: ArchConfig, prefix_len: int):
    L = tuple("layers" for _ in range(prefix_len))
    if cfg.moe.expert_shard == "ep":
        return (L + ("experts", None, None), L + ("experts", None, None))
    return (L + (None, None, "expert_mlp"), L + (None, "expert_mlp", None))


def moe_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    Ep = m.e_padded  # storage padded for EP divisibility (router stays E)
    up_axes, down_axes = _expert_axes(cfg, len(prefix_shape))
    L = tuple("layers" for _ in prefix_shape)
    gated = cfg.act in ("swiglu", "geglu")
    out = {
        "router": ParamSpec(prefix_shape + (d, E), L + (None, None), scale=0.1),
        "wi": ParamSpec(prefix_shape + (Ep, d, f), up_axes),
        "wo": ParamSpec(prefix_shape + (Ep, f, d), down_axes),
    }
    if gated:
        out["wg"] = ParamSpec(prefix_shape + (Ep, d, f), up_axes)
    if m.num_shared:
        S = m.num_shared
        out["shared_wi"] = ParamSpec(prefix_shape + (S, d, f), up_axes)
        out["shared_wo"] = ParamSpec(prefix_shape + (S, f, d), down_axes)
        if gated:
            out["shared_wg"] = ParamSpec(prefix_shape + (S, d, f), up_axes)
    return out


def _act(g, u, act):
    if act == "swiglu":
        return jax.nn.silu(g) * u
    if act == "geglu":
        return jax.nn.gelu(g) * u
    return jax.nn.gelu(u)


def _expert_ffn(tokens, wi, wg, wo, act):
    """tokens [E, C, d] -> [E, C, d] through per-expert FFNs."""
    u = jnp.einsum("ecd,edf->ecf", tokens, wi)
    if wg is not None:
        g = jnp.einsum("ecd,edf->ecf", tokens, wg)
        h = _act(g, u, act)
    else:
        h = _act(None, u, act)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_apply(params: dict, x: jax.Array, cfg: ArchConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE.  x [B, S, d] -> (y [B, S, d], aux_loss).

    aux_loss is the standard load-balancing loss (Switch): E * sum_e
    f_e * p_e, where f_e = fraction of tokens routed to e, p_e = mean
    router prob.

    dispatch='global'  sorts over all B*S tokens (baseline; replicated
    dispatch buffers under GSPMD).
    dispatch='grouped' vmaps the dispatch over the batch dim, so the
    sort/gather/scatter stay local to each data shard; capacity is per
    sequence (C = cf*S*K/E).  See EXPERIMENTS.md Sec-Perf / granite.
    """
    if cfg.moe.dispatch == "grouped":
        return _moe_apply_grouped(params, x, cfg)
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    flat = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", flat, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, K)             # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing aux loss ----
    f = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (T * K)
    p = probs.mean(axis=0)
    aux = E * jnp.sum(f * p)

    # ---- sort-based capacity dispatch ----
    Ep = m.e_padded          # dummy expert rows stay at the sentinel
    C = max(1, int(m.capacity_factor * T * K / E))
    flat_e = top_ids.reshape(-1)                              # [T*K]
    flat_g = gate_vals.reshape(-1).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), K)                     # token index per slot
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within each expert's group
    group_start = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - group_start[se]
    # dispatch index matrix: token id per (expert, slot); T = sentinel pad.
    # over-capacity slots have pos >= C and are dropped by scatter mode.
    disp = jnp.full((Ep, C), T, jnp.int32)
    disp = disp.at[se, pos].set(st.astype(jnp.int32), mode="drop")
    gmat = jnp.zeros((Ep, C), x.dtype)
    gmat = gmat.at[se, pos].set(sg, mode="drop")

    padded = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], axis=0)
    gathered = padded[disp]                                   # [E, C, d]
    gathered = constrain(gathered, "act_experts", None, None)

    y = _expert_ffn(gathered, params["wi"], params.get("wg"), params["wo"], cfg.act)
    y = y * gmat[..., None]

    out = jnp.zeros((T + 1, d), y.dtype).at[disp.reshape(-1)].add(
        y.reshape(Ep * C, d))[:T]

    # ---- shared experts (always-on) ----
    if m.num_shared:
        sh = _expert_ffn(
            jnp.broadcast_to(flat, (m.num_shared,) + flat.shape),
            params["shared_wi"], params.get("shared_wg"),
            params["shared_wo"], cfg.act).sum(0)
        out = out + sh

    return out.reshape(B, S, d).astype(x.dtype), aux


def _moe_apply_grouped(params: dict, x: jax.Array, cfg: ArchConfig
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-sequence capacity dispatch (vmapped over batch).

    Identical routing to the global path; only the capacity pool is per
    sequence, so the sort/gather/scatter indices never cross the batch
    dim — under GSPMD every dispatch buffer inherits the batch sharding
    and stays on its data shard (no replicated [E, B*S*K/E, d] temps, no
    all-gather of the sort keys).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    Ep = m.e_padded
    C = max(1, int(m.capacity_factor * S * K / E))

    logits = jnp.einsum("bsd,de->bse", x,
                        params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, K)               # [B, S, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)

    f = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) \
        / (B * S * K)
    aux = E * jnp.sum(f * probs.mean(axis=(0, 1)))

    def dispatch_one(xb, ids, gates):
        """xb [S, d]; ids/gates [S, K] -> (y [S, d])."""
        flat_e = ids.reshape(-1)                               # [S*K]
        flat_g = gates.reshape(-1).astype(xb.dtype)
        flat_t = jnp.repeat(jnp.arange(S), K)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        group_start = jnp.searchsorted(se, jnp.arange(E), side="left")
        pos = jnp.arange(S * K) - group_start[se]
        disp = jnp.full((Ep, C), S, jnp.int32)
        disp = disp.at[se, pos].set(st.astype(jnp.int32), mode="drop")
        gmat = jnp.zeros((Ep, C), xb.dtype).at[se, pos].set(sg, mode="drop")
        padded = jnp.concatenate([xb, jnp.zeros((1, d), xb.dtype)], axis=0)
        return padded[disp], gmat, disp                        # [Ep, C, d]

    gathered, gmat, disp = jax.vmap(dispatch_one)(x, top_ids, gate_vals)
    gathered = constrain(gathered, "batch", "act_experts", None, None)

    def ffn_b(g):
        return _expert_ffn(g, params["wi"], params.get("wg"), params["wo"],
                           cfg.act)

    y = jax.vmap(ffn_b)(gathered) * gmat[..., None]            # [B, E, C, d]

    def scatter_one(yb, dispb):
        return jnp.zeros((S + 1, d), yb.dtype).at[dispb.reshape(-1)].add(
            yb.reshape(Ep * C, d))[:S]

    out = jax.vmap(scatter_one)(y, disp)                       # [B, S, d]

    if m.num_shared:
        flat = x.reshape(B * S, d)
        sh = _expert_ffn(
            jnp.broadcast_to(flat, (m.num_shared,) + flat.shape),
            params["shared_wi"], params.get("shared_wg"),
            params["shared_wo"], cfg.act).sum(0)
        out = out + sh.reshape(B, S, d)

    return out.astype(x.dtype), aux


def moe_apply_dense(params: dict, x: jax.Array, cfg: ArchConfig
                    ) -> Tuple[jax.Array, jax.Array]:
    """Oracle: run every expert on every token, weight by (renormalized)
    top-k gates.  O(E) compute — test-only."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    flat = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", flat, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    Ep = m.e_padded
    dense_gates = jnp.zeros((T, Ep), jnp.float32)
    dense_gates = jax.vmap(lambda g, i, row: row.at[i].set(g))(
        gate_vals, top_ids, dense_gates)

    all_y = _expert_ffn(
        jnp.broadcast_to(flat, (Ep,) + flat.shape),
        params["wi"], params.get("wg"), params["wo"], cfg.act)  # [Ep, T, d]
    out = jnp.einsum("te,etd->td", dense_gates.astype(x.dtype), all_y)

    f = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(f * probs.mean(axis=0))

    if m.num_shared:
        sh = _expert_ffn(
            jnp.broadcast_to(flat, (m.num_shared,) + flat.shape),
            params["shared_wi"], params.get("shared_wg"),
            params["shared_wo"], cfg.act).sum(0)
        out = out + sh
    return out.reshape(B, S, d).astype(x.dtype), aux
