"""Encoder-decoder backbone (Whisper-large-v3 style).

The audio frontend is a STUB per the brief: input_specs provide
precomputed frame embeddings [B, T_enc, d_model] (standing in for the
mel + conv1d stem).  Encoder = bidirectional attention blocks; decoder =
causal self-attention + cross-attention + MLP per layer.  Sinusoidal
absolute positions (whisper uses no RoPE).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..dist import constrain
from .config import ArchConfig
from .layers import cross_entropy, norm
from .spec import ParamSpec
from . import blocks as B

__all__ = ["encdec_specs", "encdec_loss", "encdec_prefill",
           "encdec_decode_step", "init_encdec_cache", "encdec_cache_axes"]


def _maybe_scan(body, x, xs, cfg: ArchConfig):
    """lax.scan over stacked layers, or a python unroll when
    cfg.scan_layers is False (the dry-run's reduced-depth roofline
    variants need per-layer-visible HLO: a while body is costed once)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    leaves = jax.tree_util.tree_leaves(xs)
    L = leaves[0].shape[0]
    ys = []
    for i in range(L):
        sl = jax.tree_util.tree_map(lambda t: t[i], xs)
        x, y = body(x, sl)
        ys.append(y)
    if all(y is None for y in ys):
        return x, None
    return x, jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *ys)


def _sinusoid(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def encdec_specs(cfg: ArchConfig) -> dict:
    d, vp = cfg.d_model, cfg.padded_vocab
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    enc_block = B.attn_block_specs(cfg, prefix_shape=(Le,))
    dec_block = {
        "self": B.attn_block_specs(cfg, prefix_shape=(Ld,)),
        "cross": B.cross_block_specs(cfg, prefix_shape=(Ld,)),
    }
    return {
        "embed": ParamSpec((vp, d), ("vocab", None), init="embed", scale=0.02),
        "enc_stack": enc_block,
        "enc_norm": B.norm_specs(cfg),
        "dec_stack": dec_block,
        "final_norm": B.norm_specs(cfg),
        "head": ParamSpec((d, vp), (None, "vocab")),
    }


def _cast(params, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    return jax.tree_util.tree_map(
        lambda p: p.astype(cdt) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)


def _encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames [B, T, d] (stub embeddings) -> encoder output [B, T, d]."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1])

    def body(x, p_slice):
        y, _, _ = B.attn_block_apply(p_slice, x, cfg, positions=positions,
                                     causal=False, window=0, cache=None)
        return y, None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = _maybe_scan(body, x, params["enc_stack"], cfg)
    return norm(x, params["enc_norm"], cfg.norm, io=cfg.norm_io)


def _decode_tokens(params, cfg: ArchConfig, tokens, positions, cross_caches,
                   self_caches=None):
    """Decoder trunk.  cross_caches: stacked [Ld, ...] K/V from the encoder."""
    emb = params["embed"]
    x = emb[tokens]
    x = x + _sinusoid_at(positions, cfg.d_model, x.dtype)[None]
    x = constrain(x, "batch", "seq" if x.shape[1] > 1 else None, "embed")
    decode = self_caches is not None

    if decode:
        def body(x, slices):
            p_slice, cross_c, self_c = slices
            y, new_c, _ = B.attn_block_apply(
                p_slice["self"], x, cfg, positions=positions, causal=True,
                cache=self_c)
            y = B.cross_block_apply(p_slice["cross"], y, cross_c, cfg)
            return y, new_c

        x, new_self = _maybe_scan(
            body, x, (params["dec_stack"], cross_caches, self_caches), cfg)
    else:
        def body(x, slices):
            p_slice, cross_c = slices
            y, _, _ = B.attn_block_apply(
                p_slice["self"], x, cfg, positions=positions, causal=True,
                cache=None)
            y = B.cross_block_apply(p_slice["cross"], y, cross_c, cfg)
            return y, None

        if cfg.remat != "none":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        x, _ = _maybe_scan(body, x, (params["dec_stack"], cross_caches), cfg)
        new_self = None
    x = norm(x, params["final_norm"], cfg.norm, io=cfg.norm_io)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    return constrain(logits, "batch", None, "vocab"), new_self


def _sinusoid_at(positions, d, dtype):
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = positions[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _make_cross_caches(params, cfg, enc_out):
    """Project encoder output into stacked per-layer cross K/V."""
    def per_layer(cross_p):
        return B.make_cross_cache(cross_p, enc_out, cfg)
    return jax.vmap(per_layer, in_axes=0)(params["dec_stack"]["cross"])


def encdec_loss(params, cfg: ArchConfig, batch: dict) -> Tuple[jax.Array, dict]:
    """batch: frames [B,T,d], tokens [B,Sd], labels [B,Sd], loss_weight [B]."""
    params = _cast(params, cfg)
    enc_out = _encode(params, cfg, batch["frames"])
    cross = _make_cross_caches(params, cfg, enc_out)
    positions = jnp.arange(batch["tokens"].shape[1])
    logits, _ = _decode_tokens(params, cfg, batch["tokens"], positions, cross)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ce)
    row = (ce * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
    return wloss, {"loss": wloss, "mean_ce": row.mean(),
                   "aux_loss": jnp.zeros((), jnp.float32)}


def init_encdec_cache(cfg: ArchConfig, batch: int, enc_len: int,
                      self_len: int, dtype=jnp.bfloat16) -> dict:
    Ld = cfg.n_layers
    stack = lambda c: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (Ld,) + x.shape), c)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "cross": stack(B.init_cross_cache(cfg, batch, enc_len, dtype)),
        "self": stack(B.init_attn_cache(cfg, batch, self_len, dtype)),
    }


def encdec_cache_axes(cfg: ArchConfig) -> dict:
    kv = ("layers", "batch", "seq_shard", "act_kv", None)
    return {
        "pos": (),
        "cross": {"k": kv, "v": kv},
        "self": {"k": kv, "v": kv, "kpos": ("layers", None)},
    }


def encdec_prefill(params, cfg: ArchConfig, batch: dict, self_len: int
                   ) -> Tuple[jax.Array, dict]:
    """Encode frames + process the decoder prompt; returns (logits, caches)."""
    params = _cast(params, cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    enc_out = _encode(params, cfg, batch["frames"])
    cross = _make_cross_caches(params, cfg, enc_out)
    Bsz, Sd = batch["tokens"].shape
    positions = jnp.arange(Sd)
    self_caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
        B.init_attn_cache(cfg, Bsz, self_len, cdt))

    def body(x, slices):
        p_slice, cross_c, self_c = slices
        y, new_c, _ = B.attn_block_apply(
            p_slice["self"], x, cfg, positions=positions, causal=True,
            cache=self_c)
        y = B.cross_block_apply(p_slice["cross"], y, cross_c, cfg)
        return y, new_c

    emb = params["embed"]
    x = emb[batch["tokens"]] + _sinusoid(Sd, cfg.d_model, cdt)[None]
    x, new_self = _maybe_scan(body, x,
                              (params["dec_stack"], cross, self_caches), cfg)
    x = norm(x, params["final_norm"], cfg.norm, io=cfg.norm_io)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(x.dtype))
    caches = {"pos": jnp.asarray(Sd, jnp.int32), "cross": cross,
              "self": new_self}
    return logits, caches


def encdec_decode_step(params, cfg: ArchConfig, tokens: jax.Array,
                       caches: dict) -> Tuple[jax.Array, dict]:
    params = _cast(params, cfg)
    pos = caches["pos"]
    positions = pos[None] + jnp.arange(1)
    logits, new_self = _decode_tokens(params, cfg, tokens, positions,
                                      caches["cross"], caches["self"])
    return logits[:, 0], {"pos": pos + 1, "cross": caches["cross"],
                          "self": new_self}
