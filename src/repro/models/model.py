"""Model facade: one object per architecture exposing init / loss /
prefill / decode_step / input_specs, family-dispatched.

This is the single surface the training loop, serving runtime, dry-run
and benchmarks consume.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from . import encdec as ED
from . import lm as LM
from . import spec as SP

__all__ = ["ShapeCell", "SHAPES", "Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    specs: dict

    # ---------------- params ----------------
    def init(self, rng: jax.Array):
        return SP.init_params(self.specs, rng)

    def abstract_params(self):
        return SP.abstract_params(self.specs)

    def param_axes(self):
        return SP.axes_tree(self.specs)

    def param_count(self) -> int:
        return SP.param_count(self.specs)

    # ---------------- training ----------------
    def loss_fn(self, params, batch) -> Tuple[jax.Array, dict]:
        if self.cfg.family == "encdec":
            return ED.encdec_loss(params, self.cfg, batch)
        return LM.lm_loss(params, self.cfg, batch)

    # ---------------- serving ----------------
    @property
    def supports_masked_prefill(self) -> bool:
        """True when ragged LEFT-padded prompts can prefill in one
        batched call via ``batch["length_mask"]`` (attention blocks
        exclude pad keys exactly; recurrent state has no pad-skip, and
        the frame/patch frontends own their prefix semantics)."""
        return (self.cfg.family != "encdec"
                and self.cfg.frontend == "embed"
                and all(k == "attn" for k in self.cfg.block_pattern))

    def prefill(self, params, batch, cache_len: int):
        if self.cfg.family == "encdec":
            return ED.encdec_prefill(params, self.cfg, batch,
                                     self_len=cache_len)
        return LM.lm_prefill(params, self.cfg, batch, cache_len)

    def decode_step(self, params, tokens, caches):
        if self.cfg.family == "encdec":
            return ED.encdec_decode_step(params, self.cfg, tokens, caches)
        return LM.lm_decode_step(params, self.cfg, tokens, caches)

    def init_cache(self, batch: int, length: int, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return ED.init_encdec_cache(self.cfg, batch, enc_len=length,
                                        self_len=max(length // 8, 16),
                                        dtype=dtype)
        return LM.init_lm_cache(self.cfg, batch, length, dtype)

    def cache_axes(self):
        if self.cfg.family == "encdec":
            return ED.encdec_cache_axes(self.cfg)
        return LM.lm_cache_axes(self.cfg)

    def abstract_cache(self, batch: int, length: int, dtype=jnp.bfloat16):
        """ShapeDtypeStruct cache tree — no allocation (dry-run path)."""
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, length, dtype))

    # ---------------- input specs ----------------
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        cdt = jnp.dtype(cfg.compute_dtype)
        i32 = jnp.int32
        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct

        if cell.kind == "train":
            if cfg.family == "encdec":
                Sd = max(S // 8, 16)
                return {
                    "frames": sds((B, S, cfg.d_model), cdt),
                    "tokens": sds((B, Sd), i32),
                    "labels": sds((B, Sd), i32),
                    "loss_weight": sds((B,), f32),
                }
            if cfg.frontend == "patches":
                P = cfg.frontend_tokens or 256
                return {
                    "patches": sds((B, P, cfg.d_model), cdt),
                    "tokens": sds((B, S - P), i32),
                    "labels": sds((B, S - P), i32),
                    "loss_weight": sds((B,), f32),
                }
            return {
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
                "loss_weight": sds((B,), f32),
            }

        if cell.kind == "prefill":
            if cfg.family == "encdec":
                Sd = max(S // 8, 16)
                return {"frames": sds((B, S, cfg.d_model), cdt),
                        "tokens": sds((B, Sd), i32)}
            if cfg.frontend == "patches":
                P = cfg.frontend_tokens or 256
                return {"patches": sds((B, P, cfg.d_model), cdt),
                        "tokens": sds((B, S - P), i32)}
            return {"tokens": sds((B, S), i32)}

        # decode: one new token against a cache of seq_len
        return {
            "tokens": sds((B, 1), i32),
            "caches": self.abstract_cache(B, S, cdt),
        }

    def supports_cell(self, cell: ShapeCell) -> Tuple[bool, str]:
        """Gate per-arch inapplicable cells (documented in DESIGN.md)."""
        if cell.name == "long_500k" and not self.cfg.supports_long_context:
            return False, "full quadratic attention at 512k is infeasible; " \
                          "skipped per brief (sub-quadratic archs only)"
        return True, ""


def _apply_param_dtype(specs, dtype_str: str):
    """Override the storage dtype of matrix-shaped params (norm scales
    and other vectors stay fp32 — their memory is negligible and fp32
    keeps the reductions stable)."""
    dt = jnp.dtype(dtype_str)
    if dt == jnp.float32:
        return specs

    def one(s: SP.ParamSpec):
        if len(s.shape) >= 2:
            return dataclasses.replace(s, dtype=dt)
        return s

    return jax.tree_util.tree_map(
        one, specs, is_leaf=lambda t: isinstance(t, SP.ParamSpec))


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        specs = ED.encdec_specs(cfg)
    else:
        specs = LM.lm_specs(cfg)
    specs = _apply_param_dtype(specs, cfg.param_dtype)
    return Model(cfg=cfg, specs=specs)
