"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay, plus channel-mix.

Time-mix recurrence per head (dh = head size, state S in R^{dh x dh}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (u = per-head bonus)

with w_t = exp(-exp(w0 + lora_w(x_t))) data-dependent decay.  Token shift
mixes x_t with x_{t-1} via learned per-channel lerps (the v6 'ddlerp' is
simplified to static mu per projection — the systems-relevant dataflow,
state shape and decay structure are faithful).

Two sequence impls:
  * 'scan'    : lax.scan over time (reference; O(T) steps)
  * 'chunked' : intra-chunk parallel + inter-chunk state carry (the form
                the Pallas kernel implements; O(T/chunk) steps of dense
                matmuls — MXU-friendly).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .spec import ParamSpec

__all__ = ["rwkv_block_specs", "rwkv_block_apply", "init_rwkv_cache",
           "wkv_scan_ref", "wkv_chunked"]

_LORA = 64


def rwkv_block_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    d = cfg.d_model
    H, dh = cfg.n_heads, cfg.d_head
    assert H * dh == d, "rwkv requires n_heads * d_head == d_model"
    L = tuple("layers" for _ in prefix_shape)
    from .blocks import norm_specs
    mm = lambda: ParamSpec(prefix_shape + (d, d), L + (None, "qkv"))
    mu = lambda: ParamSpec(prefix_shape + (d,), L + (None,), init="zeros")
    return {
        "ln1": norm_specs(cfg, prefix_shape),
        "tm": {
            "mu_r": mu(), "mu_k": mu(), "mu_v": mu(), "mu_g": mu(), "mu_w": mu(),
            "wr": mm(), "wk": mm(), "wv": mm(), "wg": mm(),
            "w0": ParamSpec(prefix_shape + (d,), L + (None,), init="ones",
                            scale=-4.0),
            "w_a": ParamSpec(prefix_shape + (d, _LORA), L + (None, "lora")),
            "w_b": ParamSpec(prefix_shape + (_LORA, d), L + ("lora", None)),
            "u": ParamSpec(prefix_shape + (H, dh), L + ("heads", None),
                           init="zeros"),
            "wo": mm(),
            "ln_x": ParamSpec(prefix_shape + (d,), L + (None,), init="ones"),
        },
        "ln2": norm_specs(cfg, prefix_shape),
        "cm": {
            "mu_k": mu(), "mu_r": mu(),
            "wk": ParamSpec(prefix_shape + (d, cfg.d_ff), L + (None, "mlp")),
            "wv": ParamSpec(prefix_shape + (cfg.d_ff, d), L + ("mlp", None)),
            "wr": mm(),
        },
    }


def init_rwkv_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    H, dh = cfg.n_heads, cfg.d_head
    d = cfg.d_model
    return {
        "state": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dtype),   # last token (time-mix shift)
        "x_cm": jnp.zeros((batch, d), dtype),   # last token (channel-mix shift)
    }


def _token_shift(x: jax.Array, mu: jax.Array, prev: Optional[jax.Array]
                 ) -> jax.Array:
    """lerp(x_t, x_{t-1}, mu) with x_{-1} = prev (or zeros)."""
    if prev is None:
        xprev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        xprev = jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return x + mu * (xprev - x)


def wkv_scan_ref(r, k, v, w, u, s0=None):
    """Reference WKV recurrence.

    r,k,v: [B,T,H,dh]; w: [B,T,H,dh] decay in (0,1); u: [H,dh].
    Returns (o [B,T,H,dh], s_T [B,H,dh,dh]) with

        o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
        S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    B, T, H, dh = r.shape
    s = jnp.zeros((B, H, dh, dh), jnp.float32) if s0 is None else s0

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # each [B,H,dh]
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), s


def wkv_chunked(r, k, v, w, u, s0=None, chunk: int = 16):
    """Chunked-parallel WKV (matches wkv_scan_ref; see tests).

    Within a chunk of length c, with cumulative decays
    W_t = prod_{j<=t} w_j (exclusive of j=t? see below):

      contribution of state entering the chunk:  o_t += r_t (D_t * S_in)
      intra-chunk:  o_t += sum_{j<t} (r_t . D_t/D_j+1 ...) — realized as a
      lower-triangular (c x c) matmul of decay-weighted r, k plus the
      diagonal u-bonus term.

    All heavy ops are dense [c,c] / [c,dh] matmuls — the MXU-friendly form
    the Pallas kernel mirrors.
    """
    B, T, H, dh = r.shape
    assert T % chunk == 0, "pad sequence to a chunk multiple"
    nch = T // chunk
    f32 = jnp.float32

    def to_chunks(t):
        return jnp.moveaxis(t.astype(f32).reshape(B, nch, chunk, H, dh), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))   # [nch, B, c, H, dh]
    logw = jnp.log(jnp.maximum(wc, 1e-12))
    cum = jnp.cumsum(logw, axis=2)                  # inclusive log-decay
    cum_excl = cum - logw                           # exclusive (prod_{j<t})

    s = jnp.zeros((B, H, dh, dh), f32) if s0 is None else s0.astype(f32)
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)

    def chunk_step(s, inp):
        ri, ki, vi, ce, ci = inp
        # state entering chunk, decayed by prod_{j<t} w_j = exp(ce_t)
        r_dec = ri * jnp.exp(ce)
        o_state = jnp.einsum("bthk,bhkv->bthv", r_dec, s)
        # intra-chunk pairs (j < t): coefficient exp(ce_t - ci_j), realized
        # as (r exp(ce)) . (k exp(-ci)); the wlog clamp in the caller bounds
        # the exponent at chunk*5 = 80 < log(f32max)
        k_dec = ki * jnp.exp(-ci)
        scores = jnp.einsum("bthk,bjhk->bhtj", r_dec, k_dec) * tri[None, None]
        o_intra = jnp.einsum("bhtj,bjhv->bthv", scores, vi)
        # diagonal bonus: r_t . (u * k_t) v_t
        bonus = jnp.einsum("bthk,bthk->bth", ri, u[None, None] * ki)
        o = o_state + o_intra + bonus[..., None] * vi
        # carry: S_out = diag(prod w) S_in + sum_j (prod_{l>j} w_l) k_j^T v_j
        total = ci[:, -1]
        k_carry = ki * jnp.exp(total[:, None] - ci)
        s = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bjhk,bjhv->bhkv", k_carry, vi)
        return s, o

    s, o = jax.lax.scan(chunk_step, s, (rc, kc, vc, cum_excl, cum))
    o = jnp.moveaxis(o, 0, 1).reshape(B, T, H, dh)
    return o.astype(r.dtype), s


def rwkv_block_apply(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """RWKV6 residual block.  Returns (y, new_cache)."""
    from .layers import norm

    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    tm, cm = params["tm"], params["cm"]

    # ---------------- time mix ----------------
    h = norm(x, params["ln1"], cfg.norm, io=cfg.norm_io)
    prev_tm = None if cache is None else cache["x_tm"]
    xr = _token_shift(h, tm["mu_r"], prev_tm)
    xk = _token_shift(h, tm["mu_k"], prev_tm)
    xv = _token_shift(h, tm["mu_v"], prev_tm)
    xg = _token_shift(h, tm["mu_g"], prev_tm)
    xw = _token_shift(h, tm["mu_w"], prev_tm)

    r = (xr @ tm["wr"]).reshape(B, S, H, dh)
    k = (xk @ tm["wk"]).reshape(B, S, H, dh)
    v = (xv @ tm["wv"]).reshape(B, S, H, dh)
    g = jax.nn.silu(xg @ tm["wg"])
    # data-dependent decay in (0,1): exp(-exp(.)).  wlog is clamped so the
    # per-step log-decay lies in [-5, -6e-6]; with chunk=16 the chunked
    # factorization's largest exponent is 16*5 = 80 < log(f32 max) ~ 88.7,
    # so BOTH impls see the identical decay and stay exactly equivalent.
    wlog = tm["w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ tm["w_a"].astype(jnp.float32))
        @ tm["w_b"].astype(jnp.float32))
    wlog = jnp.clip(wlog, -12.0, 1.609)
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, S, H, dh)

    s0 = None if cache is None else cache["state"]
    impl = cfg.seq_impl
    if impl == "auto":
        impl = "chunked" if (cache is None and S % 16 == 0 and S >= 64) else "scan"
    if impl in ("pallas", "pallas_interpret") and S % 16 == 0 and S >= 16:
        from ..kernels import ops as _kops  # late import: no cycle
        o, s_out = _kops.rwkv6_wkv(r, k, v, w, tm["u"].astype(jnp.float32),
                                   s0, impl=impl, chunk=16)
    elif impl == "chunked" and S % 16 == 0:
        o, s_out = wkv_chunked(r, k, v, w, tm["u"].astype(jnp.float32), s0)
    else:
        o, s_out = wkv_scan_ref(r, k, v, w, tm["u"].astype(jnp.float32), s0)

    # per-head group norm then gate
    o = o.reshape(B, S, d).astype(jnp.float32)
    oh = o.reshape(B, S, H, dh)
    mu = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    o = ((oh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    o = (o * tm["ln_x"]).astype(x.dtype)
    x = x + (g * o) @ tm["wo"]

    # ---------------- channel mix ----------------
    h2 = norm(x, params["ln2"], cfg.norm, io=cfg.norm_io)
    prev_cm = None if cache is None else cache["x_cm"]
    xk2 = _token_shift(h2, cm["mu_k"], prev_cm)
    xr2 = _token_shift(h2, cm["mu_r"], prev_cm)
    kk = jnp.square(jax.nn.relu(xk2 @ cm["wk"]))
    out = jax.nn.sigmoid(xr2 @ cm["wr"]) * (kk @ cm["wv"])
    y = x + out

    new_cache = None
    if cache is not None:
        new_cache = {"state": s_out, "x_tm": h[:, -1], "x_cm": h2[:, -1]}
    return y, new_cache
