"""Parameter-spec machinery: one source of truth for shapes, logical
sharding axes and initializers.

Models build a nested dict of `ParamSpec`s; from it we derive
  * materialized parameters (init_params),
  * ShapeDtypeStruct pytrees for allocation-free lowering (abstract_params),
  * logical-axis pytrees consumed by repro.dist.sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "abstract_params", "axes_tree",
           "param_count", "param_bytes"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis name per dim (None = replicated)
    init: str = "normal"                  # normal | zeros | ones | embed | uniform_conv
    scale: float = 1.0                    # multiplier on the default fan-in scale
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return shape[0]
    # treat last dim as fan-out, everything else as fan-in
    n = 1
    for d in shape[:-1]:
        n *= d
    return max(n, 1)


def _materialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "normal":
        std = spec.scale / np.sqrt(_fan_in(spec.shape))
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init == "uniform_conv":
        lim = spec.scale / np.sqrt(_fan_in(spec.shape))
        return jax.random.uniform(key, spec.shape, spec.dtype, -lim, lim)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, rng: jax.Array):
    """Materialize a spec tree into parameter arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_materialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct tree — lower/compile without allocating."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec)


def axes_tree(specs):
    """Tree of logical-axes tuples (same structure as params)."""
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def param_bytes(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                   for s in leaves))
