"""Shared neural-net layers (pure JAX, functional).

Everything is expressed as einsums over logically-annotated tensors so
GSPMD can partition them; no framework dependency.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..dist import constrain

__all__ = [
    "rmsnorm", "layernorm", "norm", "rope", "mlp",
    "attention", "chunked_attention", "cross_entropy",
]

_NEG_INF = -1e30


# ----------------------------- norms ---------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            io: str = "f32") -> jax.Array:
    """io='f32': the classic full-fp32 chain.  io='bf16': only the
    variance reduction runs in fp32; the normalize/scale elementwise ops
    stay in the compute dtype — halves the dominant per-layer HBM
    traffic of wide dense models (EXPERIMENTS.md Sec-Perf, command-r)."""
    dt = x.dtype
    if io == "bf16":
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return x * inv * (1.0 + scale.astype(dt))
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5, io: str = "f32") -> jax.Array:
    dt = x.dtype
    if io == "bf16":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return (x - mu.astype(dt)) * inv * scale.astype(dt) + bias.astype(dt)
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm(x: jax.Array, params: dict, kind: str, io: str = "f32") -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], io=io)
    return layernorm(x, params["scale"], params["bias"], io=io)


# ----------------------------- RoPE -----------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: [..., S, H, dh]; positions: [S] or [B, S]."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    # broadcast over heads: [..., S, 1, half]
    sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------- MLP -------------------------------------------

def mlp(x: jax.Array, params: dict, act: str) -> jax.Array:
    """Gated or plain MLP.  Weights: wi [d, F] (+wg for gated), wo [F, d]."""
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, params["wg"])
        u = jnp.einsum("...d,df->...f", x, params["wi"])
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    elif act == "gelu":
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wi"])
                        + params.get("bi", 0.0))
    else:
        raise ValueError(act)
    h = constrain(h, "batch", None, "act_mlp")
    out = jnp.einsum("...f,fd->...d", h, params["wo"])
    if "bo" in params:
        out = out + params["bo"]
    return out


# --------------------------- attention ---------------------------------------

def _mask_bias(qpos: jax.Array, kpos: jax.Array, causal: bool, window: int,
               kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """[..., Sq, Sk] additive mask bias.

    ``qpos`` [..., Sq], ``kpos`` [..., Sk] and ``kv_valid`` [..., Sk] may
    each carry leading batch dims (per-row positions/validity for ragged
    left-padded serving batches); they broadcast together.
    """
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), dtype=bool)
    if causal:
        ok &= q >= k
    if window > 0:
        ok &= q - k < window
    if kv_valid is not None:
        ok = ok & kv_valid[..., None, :]
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """q [B,Sq,Kv,G,dh], k [B,Sk,Kv,dh] -> [B,Kv,G,Sq,Sk] (fp32)."""
    return jnp.einsum("bqngd,bknd->bngqk", q, k,
                      preferred_element_type=jnp.float32) * scale


def attention(
    q: jax.Array,                 # [B, Sq, H, dh]
    k: jax.Array,                 # [B, Sk, Kv, dh]
    v: jax.Array,                 # [B, Sk, Kv, dh]
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset=0,                   # int or scalar array: absolute pos of q[0]
    qpos: Optional[jax.Array] = None,   # [Sq] or [B, Sq] absolute q positions
                                        # (overrides q_offset; per-row for
                                        # ragged left-padded batches)
    kpos: Optional[jax.Array] = None,   # [Sk] or [B, Sk] absolute key
                                        # positions (ring caches)
    kv_valid: Optional[jax.Array] = None,  # [Sk] or [B, Sk] bool validity
    impl: str = "xla_naive",
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Grouped-query attention; returns [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    if impl in ("pallas", "pallas_interpret") and kpos is None \
            and kv_valid is None and qpos is None:
        from ..kernels import ops as _kops  # late import: no cycle
        return _kops.attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset, impl=impl)
    qg = q.reshape(B, Sq, Kv, G, dh)
    if impl == "xla_chunked" and Sq > q_block and qpos is None \
            and kv_valid is None:
        out = chunked_attention(qg, k, v, causal=causal, window=window,
                                softcap=softcap, q_offset=q_offset,
                                q_block=q_block, kv_block=kv_block)
        return out.reshape(B, Sq, H, dh)

    scale = dh ** -0.5
    scores = _gqa_scores(qg, k, scale)  # [B,Kv,G,Sq,Sk]
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    if qpos is None:
        qpos = q_offset + jnp.arange(Sq)
    if kpos is None:
        kpos = jnp.arange(k.shape[1])
    bias = _mask_bias(qpos, kpos, causal, window, kv_valid)
    if bias.ndim == 3:  # [B, Sq, Sk] per-row bias -> [B, 1, 1, Sq, Sk]
        bias = bias[:, None, None]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngqk,bknd->bqngd", probs, v)
    return out.reshape(B, Sq, H, dh)


def chunked_attention(
    qg: jax.Array,                # [B, Sq, Kv, G, dh]
    k: jax.Array,                 # [B, Sk, Kv, dh]
    v: jax.Array,
    *,
    causal: bool,
    window: int,
    softcap: float,
    q_offset,
    q_block: int,
    kv_block: int,
) -> jax.Array:
    """Online-softmax blocked attention (flash-style, XLA-level).

    Memory is O(q_block * kv_block) per step instead of O(Sq * Sk); this
    is the default train/prefill path for 4k-32k sequences and the
    reference the Pallas kernel is checked against.
    """
    B, Sq, Kv, G, dh = qg.shape
    Sk = k.shape[1]
    scale = dh ** -0.5
    nq = -(-Sq // q_block)
    pad_q = nq * q_block - Sq
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    nk = -(-Sk // kv_block)
    pad_k = nk * kv_block - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qs = jnp.moveaxis(qg.reshape(B, nq, q_block, Kv, G, dh), 1, 0)

    def q_step(q_i, qblk):  # qblk: [B, q_block, Kv, G, dh]
        qpos = q_offset + q_i * q_block + jnp.arange(q_block)

        def kv_step(carry, kv_i):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, kv_i * kv_block, kv_block, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, kv_i * kv_block, kv_block, 1)
            s = jnp.einsum("bqngd,bknd->bngqk", qblk, ks,
                           preferred_element_type=jnp.float32) * scale
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            kpos = kv_i * kv_block + jnp.arange(kv_block)
            kvalid = kpos < Sk
            s = s + _mask_bias(qpos, kpos, causal, window, kvalid)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bngqk,bknd->bngqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, G, q_block), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # [B, q_block, Kv, G, dh]

    outs = jax.lax.map(lambda args: q_step(*args),
                       (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_block, Kv, G, dh)
    if pad_q:
        out = out[:, :Sq]
    return out.astype(v.dtype)


# ----------------------------- loss ------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int) -> jax.Array:
    """Per-token CE over a (padded) vocab.  logits [..., Vp]; labels [...]."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab:
        pad_bias = jnp.where(jnp.arange(vp) < vocab, 0.0, _NEG_INF)
        logits = logits + pad_bias
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - lab
