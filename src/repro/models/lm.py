"""Decoder-only LM assembly: dense / MoE / hybrid (RG-LRU) / RWKV6 / VLM.

Layers are organized as repeating *pattern groups* (e.g. recurrentgemma's
("rec","rec","attn")); full groups are scanned with stacked parameters
(compile-time O(1) in depth), remainder layers are unrolled.  One code
path serves training, prefill and cached decode.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..dist import constrain
from .config import ArchConfig
from .layers import cross_entropy, norm
from .spec import ParamSpec
from . import blocks as B
from . import rglru as R
from . import rwkv6 as W

__all__ = ["lm_specs", "lm_forward", "lm_loss", "lm_prefill",
           "lm_decode_step", "init_lm_cache", "lm_cache_axes"]


# ------------------------- specs ---------------------------------------------

def _block_specs(kind: str, cfg: ArchConfig, prefix_shape=()) -> dict:
    if kind == "attn":
        return B.attn_block_specs(cfg, prefix_shape, with_moe=cfg.moe is not None)
    if kind == "rec":
        return R.rec_block_specs(cfg, prefix_shape)
    if kind == "rwkv":
        return W.rwkv_block_specs(cfg, prefix_shape)
    raise ValueError(kind)


def lm_specs(cfg: ArchConfig) -> dict:
    d, vp = cfg.d_model, cfg.padded_vocab
    specs: Dict[str, Any] = {
        "embed": ParamSpec((vp, d), ("vocab", None), init="embed", scale=0.02),
        "final_norm": B.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, vp), (None, "vocab"))
    groups, rem = cfg.pattern_counts
    if cfg.scan_layers and groups > 0:
        specs["stack"] = {
            f"p{i}": _block_specs(kind, cfg, prefix_shape=(groups,))
            for i, kind in enumerate(cfg.block_pattern)
        }
    elif groups > 0:  # unrolled
        specs["unrolled"] = {
            f"l{g}_{i}": _block_specs(kind, cfg)
            for g in range(groups)
            for i, kind in enumerate(cfg.block_pattern)
        }
    specs["rem"] = {
        f"r{i}": _block_specs(kind, cfg)
        for i, kind in enumerate(cfg.block_pattern[:rem])
    }
    return specs


# ------------------------- caches --------------------------------------------

def _block_cache(kind: str, cfg: ArchConfig, batch: int, length: int, dtype):
    if kind == "attn":
        return B.init_attn_cache(cfg, batch, length, dtype)
    if kind == "rec":
        return R.init_rec_cache(cfg, batch, dtype)
    if kind == "rwkv":
        return W.init_rwkv_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_lm_cache(cfg: ArchConfig, batch: int, length: int,
                  dtype=jnp.bfloat16) -> dict:
    groups, rem = cfg.pattern_counts
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.scan_layers and groups > 0:
        cache["stack"] = {
            f"p{i}": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (groups,) + x.shape),
                _block_cache(kind, cfg, batch, length, dtype))
            for i, kind in enumerate(cfg.block_pattern)
        }
    elif groups > 0:
        cache["unrolled"] = {
            f"l{g}_{i}": _block_cache(kind, cfg, batch, length, dtype)
            for g in range(groups)
            for i, kind in enumerate(cfg.block_pattern)
        }
    cache["rem"] = {
        f"r{i}": _block_cache(kind, cfg, batch, length, dtype)
        for i, kind in enumerate(cfg.block_pattern[:rem])
    }
    return cache


def _attn_cache_axes(cfg: ArchConfig, stacked: bool):
    L = ("layers",) if stacked else ()
    kv_mode = ("batch", "seq_shard", "act_kv", None)
    return {"k": L + kv_mode, "v": L + kv_mode, "kpos": L + (None,)}


def _rec_cache_axes(stacked: bool):
    L = ("layers",) if stacked else ()
    return {"h": L + ("batch", "rnn"), "conv": L + ("batch", None, "rnn")}


def _rwkv_cache_axes(stacked: bool):
    L = ("layers",) if stacked else ()
    return {"state": L + ("batch", "act_heads", None, None),
            "x_tm": L + ("batch", None), "x_cm": L + ("batch", None)}


def lm_cache_axes(cfg: ArchConfig) -> dict:
    """Logical-axes tree matching init_lm_cache's structure."""
    def kind_axes(kind, stacked):
        if kind == "attn":
            return _attn_cache_axes(cfg, stacked)
        if kind == "rec":
            return _rec_cache_axes(stacked)
        return _rwkv_cache_axes(stacked)

    groups, rem = cfg.pattern_counts
    axes: Dict[str, Any] = {"pos": ()}
    if cfg.scan_layers and groups > 0:
        axes["stack"] = {f"p{i}": kind_axes(kind, True)
                         for i, kind in enumerate(cfg.block_pattern)}
    elif groups > 0:
        axes["unrolled"] = {f"l{g}_{i}": kind_axes(kind, False)
                            for g in range(groups)
                            for i, kind in enumerate(cfg.block_pattern)}
    axes["rem"] = {f"r{i}": kind_axes(kind, False)
                   for i, kind in enumerate(cfg.block_pattern[:rem])}
    return axes


# ------------------------- block dispatch -----------------------------------

def _apply_block(kind: str, params, x, cfg: ArchConfig, positions, cache,
                 seq_mask=None):
    if kind == "attn":
        window = cfg.local_window
        y, c, aux = B.attn_block_apply(
            params, x, cfg, positions=positions, causal=True, window=window,
            cache=cache, use_moe=cfg.moe is not None, seq_mask=seq_mask)
        return y, c, aux
    if seq_mask is not None or positions.ndim == 2:
        # recurrent state would absorb pad tokens; the serving engine
        # routes such archs through exact-length per-request prefill
        raise NotImplementedError(
            f"masked ragged prefill/decode supports attention blocks "
            f"only; got a {kind!r} block (see Model.supports_masked_prefill)")
    if kind == "rec":
        y, c = R.rec_block_apply(params, x, cfg, cache=cache)
        return y, c, jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        y, c = W.rwkv_block_apply(params, x, cfg, cache=cache)
        return y, c, jnp.zeros((), jnp.float32)
    raise ValueError(kind)


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


# ------------------------- forward -------------------------------------------

def _embed_inputs(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    emb = params["embed"].astype(cdt)
    if cfg.frontend == "patches":
        tok = emb[batch["tokens"]]
        x = jnp.concatenate([batch["patches"].astype(cdt), tok], axis=1)
    else:
        x = emb[batch["tokens"]]
    return constrain(x, "batch", "seq", "embed")


def _run_blocks(params, cfg: ArchConfig, x, positions, caches=None,
                seq_mask=None):
    """Shared trunk: scan pattern groups + unrolled remainder.

    Returns (x, aux_sum, new_caches or None)."""
    groups, rem = cfg.pattern_counts
    pat = cfg.block_pattern
    decode = caches is not None
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {} if decode else None

    if groups > 0 and cfg.scan_layers:
        stack_params = params["stack"]

        if decode:
            def group_body_dec(x, slices):
                p_slice, c_slice = slices
                aux_g = jnp.zeros((), jnp.float32)
                new_c = {}
                for i, kind in enumerate(pat):
                    x, c_out, aux = _apply_block(
                        kind, p_slice[f"p{i}"], x, cfg, positions,
                        c_slice[f"p{i}"], seq_mask)
                    new_c[f"p{i}"] = c_out
                    aux_g = aux_g + aux
                return x, (aux_g, new_c)

            x, (auxs, ncs) = jax.lax.scan(group_body_dec, x,
                                          (stack_params, caches["stack"]))
            new_caches["stack"] = ncs
        else:
            def group_body(x, p_slice):
                aux_g = jnp.zeros((), jnp.float32)
                for i, kind in enumerate(pat):
                    x, _, aux = _apply_block(kind, p_slice[f"p{i}"], x, cfg,
                                             positions, None, seq_mask)
                    aux_g = aux_g + aux
                return x, aux_g

            x, auxs = jax.lax.scan(_remat(group_body, cfg), x, stack_params)
        aux_total = aux_total + auxs.sum()
    elif groups > 0:
        for g in range(groups):
            for i, kind in enumerate(pat):
                key = f"l{g}_{i}"
                p_blk = params["unrolled"][key]
                if decode:
                    x, c_out, aux = _apply_block(kind, p_blk, x, cfg,
                                                 positions,
                                                 caches["unrolled"][key],
                                                 seq_mask)
                    new_caches.setdefault("unrolled", {})[key] = c_out
                else:
                    def blk_fn(p, x, kind=kind):
                        y, _, aux = _apply_block(kind, p, x, cfg, positions,
                                                 None, seq_mask)
                        return y, aux
                    fn = _remat(blk_fn, cfg) if cfg.remat != "none" else blk_fn
                    x, aux = fn(p_blk, x)
                    c_out = None
                aux_total = aux_total + aux

    for i, kind in enumerate(pat[:rem]):
        key = f"r{i}"
        c_in = caches["rem"][key] if decode else None
        x, c_out, aux = _apply_block(kind, params["rem"][key], x, cfg,
                                     positions, c_in, seq_mask)
        if decode:
            new_caches.setdefault("rem", {})[key] = c_out
        aux_total = aux_total + aux
    if decode and "rem" not in new_caches:
        new_caches["rem"] = {}
    return x, aux_total, new_caches


def _logits(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = norm(x, params["final_norm"], cfg.norm, io=cfg.norm_io)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return constrain(logits, "batch", "seq", "vocab")


def lm_forward(params, cfg: ArchConfig, batch: dict) -> Tuple[jax.Array, jax.Array]:
    """Training/eval forward.  Returns (logits [B,S,Vp], aux_loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    params = jax.tree_util.tree_map(
        lambda p: p.astype(cdt) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, aux, _ = _run_blocks(params, cfg, x, positions, caches=None)
    return _logits(params, cfg, x), aux


def _chunked_ce(params, cfg: ArchConfig, x: jax.Array, labels: jax.Array
                ) -> jax.Array:
    """Head + CE in sequence chunks of cfg.loss_chunk: the full
    [B, S, V] fp32 logits tensor is never materialized (each chunk's
    logits are checkpointed, recomputed in the backward pass).  Python
    loop, not lax.map — the dry-run's cost accounting must see every
    chunk (a while body is costed once).  Sec-Perf, command-r."""
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(x.dtype)

    def chunk_ce(xc, lc):
        xc = norm(xc, params["final_norm"], cfg.norm, io=cfg.norm_io)
        logits = jnp.einsum("bsd,dv->bsv", xc, head)
        return cross_entropy(logits, lc, cfg.vocab)

    chunk_ce = jax.checkpoint(chunk_ce)
    c = cfg.loss_chunk
    Sl = labels.shape[1]
    outs = [chunk_ce(x[:, i: i + c], labels[:, i: i + c])
            for i in range(0, Sl, c)]
    return jnp.concatenate(outs, axis=1)                     # [B, Sl] fp32


def lm_loss(params, cfg: ArchConfig, batch: dict) -> Tuple[jax.Array, dict]:
    """Coded-weighted loss.

    batch: tokens [B,St] (+patches for vlm), labels [B,Sl],
           loss_weight [B] (the gradient-coding decode weights folded per
           row; uniform 1/B when uncoded), loss_mask [B,Sl] optional.
    """
    labels = batch["labels"]
    Sl = labels.shape[1]
    if cfg.loss_chunk > 0:
        cdt = jnp.dtype(cfg.compute_dtype)
        cparams = jax.tree_util.tree_map(
            lambda p: p.astype(cdt)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        x = _embed_inputs(cparams, cfg, batch)
        positions = jnp.arange(x.shape[1])
        x, aux, _ = _run_blocks(cparams, cfg, x, positions, caches=None)
        ce = _chunked_ce(cparams, cfg, x[:, -Sl:], labels)
    else:
        logits, aux = lm_forward(params, cfg, batch)
        logits = logits[:, -Sl:]  # vlm: loss only on the text suffix
        ce = cross_entropy(logits, labels, cfg.vocab)  # [B, Sl] fp32
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ce)
    row = (ce * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
    loss = wloss + 0.01 * aux
    metrics = {
        "loss": wloss,
        "aux_loss": aux,
        "mean_ce": row.mean(),
    }
    return loss, metrics


# ------------------------- serving -------------------------------------------

def lm_prefill(params, cfg: ArchConfig, batch: dict, cache_len: int
               ) -> Tuple[jax.Array, dict]:
    """Process a prompt, returning (last-token logits, filled caches).

    ``batch["length_mask"]`` ([B, S] bool, True = real token) enables
    ragged LEFT-padded prompts: row i's real tokens sit right-aligned at
    ``tokens[i, S-len_i:]``.  Real tokens get per-row positions
    ``0..len_i-1`` (so RoPE and causal masking match an unpadded
    per-request prefill exactly), pads get distinct negative positions
    and are excluded from attention; the filled cache carries per-row
    ``pos`` [B] and per-row key validity, so subsequent decode steps are
    also per-row.  Attention-block archs only (recurrent state has no
    pad-skip; see Model.supports_masked_prefill).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    params = jax.tree_util.tree_map(
        lambda p: p.astype(cdt) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)
    x = _embed_inputs(params, cfg, batch)
    Bsz, S = x.shape[0], x.shape[1]
    mask = batch.get("length_mask")
    caches = init_lm_cache(cfg, Bsz, cache_len, cdt)
    if mask is None:
        positions = jnp.arange(S)
        x, _, new_caches = _run_blocks(params, cfg, x, positions,
                                       caches=caches)
        new_caches["pos"] = jnp.asarray(S, jnp.int32)
    else:
        mask = mask.astype(bool)
        lens = mask.sum(-1).astype(jnp.int32)                      # [B]
        positions = jnp.arange(S)[None, :] - (S - lens[:, None])   # [B, S]
        x, _, new_caches = _run_blocks(params, cfg, x, positions,
                                       caches=caches, seq_mask=mask)
        new_caches["pos"] = lens
    # left padding means the last real token is at index S-1 in every row
    logits = _logits(params, cfg, x[:, -1:])
    return logits[:, 0], new_caches


def lm_decode_step(params, cfg: ArchConfig, tokens: jax.Array, caches: dict
                   ) -> Tuple[jax.Array, dict]:
    """One decode step.  tokens [B, 1]; caches from prefill/init.

    ``caches["pos"]`` is a scalar (uniform batch) or [B] (per-row, after
    a masked ragged prefill); per-row positions route the attention
    blocks through the per-row ring-cache path.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    params = jax.tree_util.tree_map(
        lambda p: p.astype(cdt) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)
    emb = params["embed"].astype(cdt)
    x = emb[tokens]
    x = constrain(x, "batch", None, "embed")
    pos = caches["pos"]
    if pos.ndim == 0:
        positions = pos[None] + jnp.arange(1)          # [1], shared
    else:
        positions = pos[:, None] + jnp.arange(1)       # [B, 1], per-row
    x, _, new_caches = _run_blocks(params, cfg, x, positions, caches=caches)
    new_caches["pos"] = pos + 1
    logits = _logits(params, cfg, x)
    return logits[:, 0], new_caches
