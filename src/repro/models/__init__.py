"""Pure-JAX model stack for the assigned architecture pool."""

from .config import ArchConfig, MoEConfig, reduce_for_smoke  # noqa: F401
from .model import SHAPES, Model, ShapeCell, build_model  # noqa: F401
