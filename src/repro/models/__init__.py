"""Pure-JAX model stack for the assigned architecture pool.

Public surface: ``build_model(cfg) -> Model`` (init / loss_fn /
param_count over the arch pool: dense + MoE transformers, rglru /
rwkv6 recurrent blocks, enc-dec), the ``ArchConfig`` / ``MoEConfig``
config records with ``reduce_for_smoke``, and the ``SHAPES`` table
(``ShapeCell``) the dry-run harness sweeps.  Models name logical axes
so ``repro.dist`` can shard them on any mesh, cast inputs at the
device boundary (fp64-clean for the differential suites), and carry
``loss_weight`` per row — the hook the coded pipeline stamps.
"""

from .config import ArchConfig, MoEConfig, reduce_for_smoke  # noqa: F401
from .model import SHAPES, Model, ShapeCell, build_model  # noqa: F401
