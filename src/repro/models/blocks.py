"""Residual blocks: attention (+MLP/MoE), cross-attention; param specs and
apply functions with a uniform (params, x, cache) -> (y, cache) interface.

All blocks are cache-polymorphic: cache=None means full-sequence training
/ prefill-without-cache; a cache dict means single-or-multi-token decode
with static shapes (ring buffers for windowed attention).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist import constrain
from .config import ArchConfig
from .layers import attention, mlp, norm, rope
from .spec import ParamSpec
from . import moe as moe_lib

__all__ = [
    "norm_specs", "attn_block_specs", "cross_block_specs",
    "attn_block_apply", "cross_block_apply",
    "init_attn_cache", "init_cross_cache",
]


def _p(prefix_shape):
    """Leading logical axes for an optional stacked-layer prefix."""
    return tuple("layers" for _ in prefix_shape)


def norm_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    d = cfg.d_model
    axes = _p(prefix_shape) + (None,)
    out = {"scale": ParamSpec(prefix_shape + (d,), axes,
                              init="zeros" if cfg.norm == "rmsnorm" else "ones")}
    if cfg.norm == "layernorm":
        out["bias"] = ParamSpec(prefix_shape + (d,), axes, init="zeros")
    return out


def mlp_specs(cfg: ArchConfig, prefix_shape=(), d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = _p(prefix_shape)
    out = {}
    if cfg.act in ("swiglu", "geglu"):
        out["wg"] = ParamSpec(prefix_shape + (d, f), L + (None, "mlp"))
        out["wi"] = ParamSpec(prefix_shape + (d, f), L + (None, "mlp"))
    else:
        out["wi"] = ParamSpec(prefix_shape + (d, f), L + (None, "mlp"))
        out["bi"] = ParamSpec(prefix_shape + (f,), L + ("mlp",), init="zeros")
    out["wo"] = ParamSpec(prefix_shape + (f, d), L + ("mlp", None))
    if cfg.act == "gelu":
        out["bo"] = ParamSpec(prefix_shape + (d,), L + (None,), init="zeros")
    return out


def attn_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    L = _p(prefix_shape)
    out = {
        "wq": ParamSpec(prefix_shape + (d, qd), L + (None, "qkv")),
        "wk": ParamSpec(prefix_shape + (d, kvd), L + (None, "kv")),
        "wv": ParamSpec(prefix_shape + (d, kvd), L + (None, "kv")),
        "wo": ParamSpec(prefix_shape + (qd, d), L + ("qkv", None)),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec(prefix_shape + (qd,), L + ("qkv",), init="zeros")
        out["bk"] = ParamSpec(prefix_shape + (kvd,), L + ("kv",), init="zeros")
        out["bv"] = ParamSpec(prefix_shape + (kvd,), L + ("kv",), init="zeros")
    return out


def attn_block_specs(cfg: ArchConfig, prefix_shape=(), with_moe: bool = False) -> dict:
    specs = {
        "ln1": norm_specs(cfg, prefix_shape),
        "attn": attn_specs(cfg, prefix_shape),
        "ln2": norm_specs(cfg, prefix_shape),
    }
    if with_moe and cfg.moe is not None:
        specs["moe"] = moe_lib.moe_specs(cfg, prefix_shape)
    else:
        specs["mlp"] = mlp_specs(cfg, prefix_shape)
    return specs


def cross_block_specs(cfg: ArchConfig, prefix_shape=()) -> dict:
    return {"ln": norm_specs(cfg, prefix_shape), "attn": attn_specs(cfg, prefix_shape)}


# ----------------------------- caches ----------------------------------------

def init_attn_cache(cfg: ArchConfig, batch: int, length: int, dtype) -> dict:
    """Static-shape KV cache; windowed layers use a ring buffer of size
    min(window, length)."""
    W = min(cfg.local_window, length) if cfg.local_window else length
    kv = cfg.n_kv
    return {
        "k": jnp.zeros((batch, W, kv, cfg.d_head), dtype),
        "v": jnp.zeros((batch, W, kv, cfg.d_head), dtype),
        "kpos": jnp.full((W,), -1, jnp.int32),  # absolute positions (-1 empty)
    }


def init_cross_cache(cfg: ArchConfig, batch: int, enc_len: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), dtype),
    }


# ----------------------------- apply -----------------------------------------

def _project_qkv(p: dict, x: jax.Array, cfg: ArchConfig):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv, cfg.d_head)
    return q, k, v


def attn_block_apply(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,            # [S] absolute positions of x, or
                                     # [B, S] per-row (ragged serving)
    causal: bool = True,
    window: int = 0,
    cache: Optional[dict] = None,
    use_moe: bool = False,
    seq_mask: Optional[jax.Array] = None,  # [B, S] bool: True = real token
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Pre-norm residual block. Returns (y, new_cache, aux_loss).

    With ``seq_mask`` (masked ragged prefill) or 2-D ``positions``
    (decode after one), pad/invalid keys carry negative positions: they
    are excluded from attention and land in unused ring slots whose
    ``kpos`` stays < 0 — the same "empty" convention the ring cache
    already uses — so later decode steps never attend to them.
    """
    B, S, _ = x.shape
    ragged = positions.ndim == 2
    h = norm(x, params["ln1"], cfg.norm, io=cfg.norm_io)
    q, k, v = _project_qkv(params["attn"], h, cfg)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "act_heads", None)
    k = constrain(k, "batch", None, "act_kv", None)

    if cache is None:
        if ragged or seq_mask is not None:
            out = attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.logit_softcap, qpos=positions,
                            kpos=positions, kv_valid=seq_mask,
                            impl=cfg.attn_impl)
        else:
            out = attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.logit_softcap, q_offset=positions[0],
                            impl=cfg.attn_impl)
        new_cache = None
    elif S == 1 and ragged:
        # per-row cached decode (after a masked ragged prefill): each row
        # inserts at its own position; kpos is per-row [B, W]
        W = cache["k"].shape[1]
        bidx = jnp.arange(B)
        slot = (positions[:, 0] % W).astype(jnp.int32)
        ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        kpos = jnp.broadcast_to(cache["kpos"], (B, W))
        kpos = kpos.at[bidx, slot].set(positions[:, 0].astype(jnp.int32))
        out = attention(q, ck, cv, causal=causal, window=window,
                        softcap=cfg.logit_softcap, qpos=positions,
                        kpos=kpos, kv_valid=kpos >= 0, impl="xla_naive")
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
    elif S == 1:  # cached decode: ring-buffer insert + attend over buffer
        W = cache["k"].shape[1]
        slot = positions % W
        ck = cache["k"].at[:, slot].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slot].set(v.astype(cache["v"].dtype))
        kpos = cache["kpos"].at[slot].set(positions.astype(jnp.int32))
        out = attention(q, ck, cv, causal=causal, window=window,
                        softcap=cfg.logit_softcap, q_offset=positions[0],
                        kpos=kpos, kv_valid=kpos >= 0, impl="xla_naive")
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
    elif ragged or seq_mask is not None:
        # masked ragged prefill: full attention over valid keys only,
        # then per-row tail write (pads keep kpos < 0 = invalid slots)
        out = attention(q, k, v, causal=causal, window=window,
                        softcap=cfg.logit_softcap, qpos=positions,
                        kpos=positions, kv_valid=seq_mask,
                        impl=cfg.attn_impl)
        W = cache["k"].shape[1]
        take = min(W, S)
        pos_tail = positions[:, -take:].astype(jnp.int32)   # [B, take]
        slot = pos_tail % W
        bidx = jnp.arange(B)[:, None]
        ck = cache["k"].at[bidx, slot].set(
            k[:, -take:].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(
            v[:, -take:].astype(cache["v"].dtype))
        kpos = jnp.broadcast_to(cache["kpos"], (B, W))
        kpos = kpos.at[bidx, slot].set(pos_tail)
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
    else:  # prefill: full attention, then write the tail into the cache
        out = attention(q, k, v, causal=causal, window=window,
                        softcap=cfg.logit_softcap, q_offset=positions[0],
                        impl=cfg.attn_impl)
        W = cache["k"].shape[1]
        take = min(W, S)
        pos_tail = positions[-take:]
        slot = pos_tail % W
        ck = cache["k"].at[:, slot].set(k[:, -take:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, slot].set(v[:, -take:].astype(cache["v"].dtype))
        kpos = cache["kpos"].at[slot].set(pos_tail.astype(jnp.int32))
        new_cache = {"k": ck, "v": cv, "kpos": kpos}

    out = out.reshape(B, S, cfg.q_dim)
    x = x + jnp.einsum("bse,ed->bsd", out, params["attn"]["wo"])

    h2 = norm(x, params["ln2"], cfg.norm, io=cfg.norm_io)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        ff, aux = moe_lib.moe_apply(params["moe"], h2, cfg)
    else:
        ff = mlp(h2, params["mlp"], cfg.act)
    return x + ff, new_cache, aux


def cross_block_apply(
    params: dict,
    x: jax.Array,
    cross_cache: dict,               # precomputed encoder K/V
    cfg: ArchConfig,
) -> jax.Array:
    """Cross-attention residual block (encoder-decoder)."""
    B, S, _ = x.shape
    h = norm(x, params["ln"], cfg.norm, io=cfg.norm_io)
    q = jnp.einsum("bsd,de->bse", h, params["attn"]["wq"])
    if cfg.qkv_bias:
        q = q + params["attn"]["bq"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    out = attention(q, cross_cache["k"], cross_cache["v"], causal=False,
                    impl="xla_naive")
    out = out.reshape(B, S, cfg.q_dim)
    return x + jnp.einsum("bse,ed->bsd", out, params["attn"]["wo"])


def make_cross_cache(params: dict, enc_out: jax.Array, cfg: ArchConfig) -> dict:
    """Project encoder output once into cross K/V (whisper-style)."""
    B, T, _ = enc_out.shape
    k = jnp.einsum("btd,de->bte", enc_out, params["attn"]["wk"])
    v = jnp.einsum("btd,de->bte", enc_out, params["attn"]["wv"])
    if cfg.qkv_bias:
        k, v = k + params["attn"]["bk"], v + params["attn"]["bv"]
    return {"k": k.reshape(B, T, cfg.n_kv, cfg.d_head),
            "v": v.reshape(B, T, cfg.n_kv, cfg.d_head)}
