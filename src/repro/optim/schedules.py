"""Learning-rate schedules: cosine, constant, and WSD (Warmup-Stable-
Decay, MiniCPM arXiv:2404.06395) — all pure functions of the step."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["make_schedule"]


def _warmup(step, warmup_steps):
    return jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))


def make_schedule(name: str, base_lr: float, total_steps: int,
                  warmup_steps: int = 100, min_ratio: float = 0.1,
                  decay_frac: float = 0.1):
    """Returns f(step) -> lr.

    wsd: warmup -> flat at base_lr -> decay over the last decay_frac of
    training (1 - sqrt progress, per MiniCPM), floored at min_ratio.
    """
    total = max(total_steps, 1)

    def cosine(step):
        w = _warmup(step, warmup_steps)
        t = jnp.clip((step - warmup_steps) / max(total - warmup_steps, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * w * cos

    def const(step):
        return base_lr * _warmup(step, warmup_steps)

    def wsd(step):
        w = _warmup(step, warmup_steps)
        decay_start = total * (1 - decay_frac)
        t = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1),
                     0, 1)
        decay = 1 - (1 - min_ratio) * jnp.sqrt(t)
        return base_lr * w * decay

    fns = {"cosine": cosine, "const": const, "wsd": wsd}
    if name not in fns:
        raise ValueError(f"unknown schedule {name!r}")
    return fns[name]
