"""AdamW with global-norm clipping, fully hand-rolled (no optax), plus
ZeRO-1 sharding rules for the optimizer state.

The optimizer state is a pytree {mu, nu} mirroring params; under a mesh,
`opt_state_shardings` shards each moment like its parameter *plus* the
first replicated dimension over 'data' (ZeRO-1) when divisible.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.sharding import logical_to_pspec

__all__ = ["OptConfig", "init_opt_state", "adamw_update",
           "opt_state_shardings", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_ratio: float = 0.1
    decay_frac: float = 0.1
    # gradient compression (beyond-paper; composes with coding since the
    # decode is linear): 'none' | 'int8'
    compress: str = "none"


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig, lr: jax.Array
                 ) -> Tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0

    if cfg.compress == "int8":
        from .compress import fake_quantize_int8
        grads = jax.tree_util.tree_map(fake_quantize_int8, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * (g * g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


def opt_state_shardings(param_axes, abstract_params, mesh: Mesh,
                        zero1: bool = True):
    """NamedShardings for {mu, nu, step}.

    ZeRO-1: each moment inherits its parameter's PartitionSpec and, if a
    dimension is still replicated and divisible by the 'data' axis, that
    dimension is sharded over 'data' — optimizer memory scales down with
    the DP degree while params/grads stay DP-replicated.
    """
    data_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)

    def one(axes, aval):
        spec = list(logical_to_pspec(axes, aval.shape, mesh))
        spec += [None] * (len(aval.shape) - len(spec))
        if zero1 and "data" in mesh.axis_names:
            for i, (sp, dim) in enumerate(zip(spec, aval.shape)):
                if sp is None and dim % data_size == 0 and data_size > 1:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    moment = jax.tree_util.tree_map(one, param_axes, abstract_params,
                                    is_leaf=is_axes)
    return {
        "mu": moment,
        "nu": moment,
        "step": NamedSharding(mesh, P()),
    }
