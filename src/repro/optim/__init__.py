"""Hand-rolled optimizer substrate.

Public surface: ``OptConfig`` / ``adamw_update`` / ``init_opt_state`` /
``global_norm`` (AdamW with decoupled weight decay and global-norm
clipping), ``opt_state_shardings`` (ZeRO-1: optimizer moments sharded
over 'data'), ``make_schedule`` (cosine / linear / constant with
warmup), and the ``compress`` module (gradient compression hooks).
"""

from .adamw import (  # noqa: F401
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    opt_state_shardings,
)
from .schedules import make_schedule  # noqa: F401
from . import compress  # noqa: F401
