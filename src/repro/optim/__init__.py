"""Hand-rolled optimizer substrate: AdamW, schedules, ZeRO-1 sharding,
gradient compression."""

from .adamw import (  # noqa: F401
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    opt_state_shardings,
)
from .schedules import make_schedule  # noqa: F401
from . import compress  # noqa: F401
