"""Gradient compression (beyond-paper distributed-optimization trick).

int8 block-quantization with stochastic rounding.  On TPU hardware this
pairs with a shard_map ring all-reduce exchanging int8 payloads (8x ICI
byte reduction — see EXPERIMENTS.md roofline notes); on the CPU container
we exercise the *numerics* end-to-end via fake-quantize (quantize ->
dequantize) inside the optimizer, which is exactly the error the real
system would see after decode.

Composes with gradient coding because the decode is linear: quantizing
coded partials before the weighted sum commutes with the one-step decode
up to the quantization noise analyzed here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "fake_quantize_int8"]

_BLOCK = 256


def _pad_to_block(x: jax.Array):
    n = x.size
    nb = -(-n // _BLOCK)
    pad = nb * _BLOCK - n
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(nb, _BLOCK), pad


def quantize_int8(x: jax.Array, key: jax.Array | None = None):
    """Per-256-block absmax int8 quantization (optionally stochastic)."""
    orig_shape, orig_dtype = x.shape, x.dtype
    blocks, pad = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = blocks / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale, (orig_shape, orig_dtype, pad)


def dequantize_int8(q, scale, meta):
    orig_shape, orig_dtype, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(orig_shape).astype(orig_dtype)


def fake_quantize_int8(x: jax.Array) -> jax.Array:
    """quantize -> dequantize round trip (deterministic rounding)."""
    if x.size == 0 or x.ndim == 0:
        return x
    q, scale, meta = quantize_int8(x)
    return dequantize_int8(q, scale, meta)
