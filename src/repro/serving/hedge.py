"""Request hedging: replication + deadline cancellation for serving.

The serving-side analogue of the paper's gradient coding: where coded
training pays a compute-overhead factor to make the gradient *sum*
robust to the slowest workers, hedged serving pays a (much smaller)
duplicate-request overhead to make each *request* robust to a slow
replica.  Both trade bounded extra compute for a collapsed tail.

Mechanics (Dean & Barroso, "The Tail at Scale"): a request goes to its
primary replica; if no response arrives within a deadline set at an
online tail quantile of recent primary latencies, a backup copy is
issued to a second replica.  The first finisher wins and the loser is
cancelled, so the backup only costs compute *after* the deadline:

    fired    = T_primary > threshold
    latency  = T_primary                       if not fired
               min(T_primary, threshold + T_backup)  otherwise
    compute  = latency + fired * (latency - threshold)

(The winner runs for ``latency``; a fired loser is cancelled at the
winner's finish, having burned ``latency - threshold``.)

:class:`HedgeController` owns the online threshold: a sliding-window
quantile of observed primary latencies (same window-quantile idiom as
``control.estimator``), inactive (+inf threshold — never fires) until
``warmup`` observations have arrived.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["HedgePolicy", "HedgeController", "hedge_outcomes"]


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Hedging knobs.

    ``quantile``: primary-latency quantile at which the backup fires.
    Must undercut the fast-mode mass to help — e.g. with 1 of 8 replicas
    slow, P(fast primary) = 0.875, so q = 0.95 lands *inside* the slow
    mode and never fires on it; q = 0.85 is the useful regime.
    ``warmup``: observations before hedging activates (cold threshold
    is +inf).  ``window``: sliding window size for the online quantile.
    """

    quantile: float = 0.85
    warmup: int = 256
    window: int = 4096

    def __post_init__(self):
        if not (0.0 < self.quantile < 1.0):
            raise ValueError(f"quantile={self.quantile} must be in (0, 1)")
        if self.warmup < 1 or self.window < 1:
            raise ValueError("warmup and window must be >= 1")


class HedgeController:
    """Online hedge-deadline controller (sliding-window tail quantile)."""

    def __init__(self, policy: HedgePolicy):
        self.policy = policy
        self._window = np.empty(policy.window)
        self._count = 0          # total observations ingested
        self._head = 0           # ring-buffer write position

    def threshold(self) -> float:
        """Current hedge deadline; +inf while warming up."""
        if self._count < self.policy.warmup:
            return float("inf")
        valid = self._window[: min(self._count, self.policy.window)]
        return float(np.quantile(valid, self.policy.quantile))

    def observe(self, latencies: np.ndarray) -> None:
        """Fold a chunk of primary latencies into the sliding window."""
        lat = np.asarray(latencies, dtype=np.float64).ravel()
        if lat.size >= self.policy.window:
            self._window[:] = lat[-self.policy.window:]
            self._head = 0
        else:
            idx = (self._head + np.arange(lat.size)) % self.policy.window
            self._window[idx] = lat
            self._head = int((self._head + lat.size) % self.policy.window)
        self._count += int(lat.size)


def hedge_outcomes(primary: np.ndarray, backup: np.ndarray,
                   threshold: float
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized hedge outcomes for one chunk of requests.

    Returns ``(latency, compute, fired)`` with the first-finisher-wins /
    cancel-the-loser semantics from the module docstring.  An infinite
    ``threshold`` (warmup) degenerates to unhedged serving exactly.
    """
    p = np.asarray(primary, dtype=np.float64)
    b = np.asarray(backup, dtype=np.float64)
    fired = p > threshold
    latency = np.where(fired, np.minimum(p, threshold + b), p)
    compute = latency + np.where(fired, latency - threshold, 0.0)
    return latency, compute, fired
