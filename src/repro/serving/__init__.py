"""Serving runtime.

Public surface: ``Request`` and ``ServingEngine`` — continuous-batching
inference with per-slot deadlines and request hedging (a slot that
misses its deadline re-issues to another replica, first answer wins):
the inference-side analogue of the training deadline/error trade
(docs/architecture.md 3).
"""

from .engine import Request, ServingEngine  # noqa: F401
