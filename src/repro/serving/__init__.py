"""Serving runtime: coded-hedged multi-replica serving.

Public surface (docs/architecture.md §3):

  * ``ServingEngine`` / ``Request`` — single-replica continuous
    batching: per-slot admission and retirement over a vmapped decode
    pool, with length-masked ragged prefill;
  * ``HedgePolicy`` / ``HedgeController`` / ``hedge_outcomes`` —
    request replication with deadline cancellation (fires at an online
    tail quantile, first finisher wins, loser cancelled);
  * ``Router`` / ``ReplicaTailEstimator`` — uniform and
    power-of-two-choices replica selection from sliding tail estimates;
  * ``simulate_serving`` / ``pareto_front`` — vectorized multi-replica
    trace replay for million-request tail/overhead Pareto fronts (E12).
"""

from .engine import Request, ServingEngine, SlotEvent  # noqa: F401
from .hedge import HedgeController, HedgePolicy, hedge_outcomes  # noqa: F401
from .router import ReplicaTailEstimator, Router  # noqa: F401
from .simulator import SimResult, pareto_front, simulate_serving  # noqa: F401
