"""Single-replica serving engine: masked ragged prefill + slot-level
continuous batching.

``generate_batch`` is the synchronous API: ragged prompts prefill in ONE
batched call via the length-masked prefill path (``Model.prefill`` with
``batch["length_mask"]``), so mixed-length batches produce exactly the
tokens per-request generation would (pad keys are excluded from
attention and real tokens keep their unpadded positions).

``serve_queue`` is REAL continuous batching: a fixed pool of ``B`` decode
slots, each slot admitted/retired independently.  A request prefills at
admission (exact length, batch 1 — correct for every model family
including recurrent state), its cache is inserted into the slot pool,
and every decode step advances all occupied slots in one vmapped
``decode_step``.  A slot retires the moment its request reaches its own
``max_new_tokens`` (``Request.done`` is set) and is immediately re-used
by the next pending request while the other slots keep decoding — there
are no synchronous waves and no over-decoding past a request's budget.

Straggler note: gradient coding is a *training* technique (there is no
gradient sum to code at inference); the serving-side mitigation at scale
is request replication / deadline hedging — implemented by
``serving.hedge`` + ``serving.router`` over the multi-replica simulator
in ``serving.simulator``.  See docs/architecture.md §3.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model

__all__ = ["Request", "SlotEvent", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class SlotEvent:
    """One scheduler transition (the occupancy-invariant test hook)."""
    kind: str                    # "admit" | "retire"
    rid: int
    slot: int
    tick: int                    # decode steps executed so far


class ServingEngine:
    """Continuous batching over a fixed pool of decode slots.

    ``greedy=True`` decodes by argmax; ``greedy=False`` samples with
    ``temperature`` from a PRNG keyed on ``(seed, rid, token_index)`` —
    independent of batch composition, so a request samples the same
    continuation whether it is served alone or packed with others.
    """

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_len: int, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0):
        if batch_slots <= 0:
            raise ValueError(f"batch_slots must be > 0, got {batch_slots}")
        self.model = model
        self.params = params
        self.B = batch_slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.temperature = float(temperature)
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))
        # slot pool decode: vmap over a leading slot axis of stacked
        # per-slot (batch-1) caches — per-leaf batch-axis positions never
        # matter because the slot axis is always axis 0
        self._slot_decode = jax.jit(
            jax.vmap(model.decode_step, in_axes=(None, 0, 0)))
        self.events: List[SlotEvent] = []   # admission/retirement log
        self._tick = 0

    # ------------------------------------------------------------------
    # token selection
    # ------------------------------------------------------------------

    def _select(self, logits: jax.Array, rid: int, t_index: int) -> int:
        """Next token for one row of logits [Vp]."""
        if self.greedy:
            return int(jnp.argmax(logits))
        key = jax.random.fold_in(jax.random.fold_in(self._key, rid), t_index)
        return int(jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature))

    # ------------------------------------------------------------------
    # synchronous batched API
    # ------------------------------------------------------------------

    def generate_batch(self, prompts: List[np.ndarray], max_new: int,
                       rids: Optional[Sequence[int]] = None
                       ) -> List[List[int]]:
        """Batched generation for (possibly ragged) prompts.

        Same-length prompts prefill unmasked; mixed lengths left-pad and
        prefill through the length-masked path, which matches
        per-request outputs exactly.  Models without masked-prefill
        support (recurrent blocks, frame/patch frontends) fall back to
        per-request generation for ragged inputs.  ``rids`` seed the
        sampling PRNG per row (defaults to the row index).
        """
        B = len(prompts)
        if B == 0:
            return []
        if rids is None:
            rids = list(range(B))
        lens = [len(p) for p in prompts]
        ragged = len(set(lens)) > 1
        if ragged and not self.model.supports_masked_prefill:
            return [self.generate_batch([p], max_new, rids=[rid])[0]
                    for p, rid in zip(prompts, rids)]
        L = max(lens)
        toks = np.zeros((B, L), np.int32)
        mask = np.zeros((B, L), bool)
        for i, p in enumerate(prompts):
            toks[i, L - len(p):] = p          # left-pad
            mask[i, L - len(p):] = True
        batch = {"tokens": jnp.asarray(toks)}
        if ragged:
            batch["length_mask"] = jnp.asarray(mask)
        logits, caches = self._prefill(self.params, batch)
        outs: List[List[int]] = [[] for _ in range(B)]
        cur = np.empty((B, 1), np.int32)
        for b in range(B):
            cur[b, 0] = self._select(logits[b], rids[b], 0)
            outs[b].append(int(cur[b, 0]))
        for t in range(1, max_new):
            logits, caches = self._decode(self.params, jnp.asarray(cur),
                                          caches)
            for b in range(B):
                cur[b, 0] = self._select(logits[b], rids[b], t)
                outs[b].append(int(cur[b, 0]))
        return outs

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------

    def _admit(self, r: Request, slot: int, pool, cur: np.ndarray):
        """Prefill one request (exact length, batch 1) into a slot."""
        if len(r.prompt) + r.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {r.rid}: prompt {len(r.prompt)} + max_new "
                f"{r.max_new_tokens} exceeds cache_len {self.cache_len}")
        prompt = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
        logits, cache = self._prefill(self.params, {"tokens": prompt})
        tok = self._select(logits[0], r.rid, 0)
        r.generated.append(tok)
        cur[slot] = tok
        self.events.append(SlotEvent("admit", r.rid, slot, self._tick))
        if pool is None:
            # first admission defines the stacked pool template
            pool = jax.tree_util.tree_map(
                lambda x: jnp.zeros((self.B,) + x.shape, x.dtype), cache)
        pool = jax.tree_util.tree_map(
            lambda full, one: full.at[slot].set(one), pool, cache)
        return pool

    def _retire(self, r: Request, slot: int,
                results: Dict[int, List[int]]) -> None:
        r.done = True
        results[r.rid] = r.generated
        self.events.append(SlotEvent("retire", r.rid, slot, self._tick))

    def serve_queue(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve a queue with per-slot admission and retirement.

        Each occupied slot decodes exactly its request's
        ``max_new_tokens`` tokens; freed slots admit the next pending
        request immediately, while the remaining slots keep decoding.
        """
        pending = list(requests)[::-1]          # pop() admits FIFO
        results: Dict[int, List[int]] = {}
        slots: List[Optional[Request]] = [None] * self.B
        remaining = [0] * self.B
        emitted = [0] * self.B                  # tokens emitted per slot
        cur = np.zeros((self.B, 1), np.int32)
        pool = None

        while pending or any(s is not None for s in slots):
            # admission: fill every free slot from the queue
            for b in range(self.B):
                while slots[b] is None and pending:
                    r = pending.pop()
                    pool = self._admit(r, b, pool, cur)
                    if r.max_new_tokens <= 1:
                        self._retire(r, b, results)
                        continue            # slot still free: admit again
                    slots[b] = r
                    remaining[b] = r.max_new_tokens - 1
                    emitted[b] = 1
            if not any(s is not None for s in slots):
                continue                    # queue drained by 1-token reqs
            # one decode step over the whole pool (idle slots decode
            # garbage that is never read — the price of a fixed shape)
            logits, pool = self._slot_decode(self.params, jnp.asarray(
                cur[:, :, None]), pool)
            self._tick += 1
            for b, r in enumerate(slots):
                if r is None:
                    continue
                tok = self._select(logits[b, 0], r.rid, emitted[b])
                r.generated.append(tok)
                cur[b] = tok
                emitted[b] += 1
                remaining[b] -= 1
                if remaining[b] == 0:
                    self._retire(r, b, results)
                    slots[b] = None
        return results
