"""Batched serving engine: prefill + step-decode with a continuous-
batching slot scheduler.

Straggler note: gradient coding is a *training* technique (there is no
gradient sum to code at inference); the serving-side mitigation at scale
is request replication / deadline hedging, which the scheduler models via
per-slot deadlines.  See docs/architecture.md §3.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model

__all__ = ["Request", "ServingEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Fixed-slot continuous batching over a single shared KV cache."""

    def __init__(self, model: Model, params, batch_slots: int,
                 cache_len: int, greedy: bool = True):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.cache_len = cache_len
        self.greedy = greedy
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=cache_len))

    def generate_batch(self, prompts: List[np.ndarray], max_new: int
                       ) -> List[List[int]]:
        """Simple synchronous API: same-length prompts, batched decode."""
        B = len(prompts)
        toks = jnp.asarray(np.stack(prompts), jnp.int32)
        logits, caches = self._prefill(self.params, {"tokens": toks})
        outs: List[List[int]] = [[] for _ in range(B)]
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for b in range(B):
            outs[b].append(int(cur[b, 0]))
        for _ in range(max_new - 1):
            logits, caches = self._decode(self.params, cur, caches)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            for b in range(B):
                outs[b].append(int(cur[b, 0]))
        return outs

    def serve_queue(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Continuous batching: keep `B` slots busy, admit new requests as
        slots free up.  Prompts are right-aligned into a shared step loop
        (one prefill per admission, shared decode steps)."""
        pending = list(requests)
        results: Dict[int, List[int]] = {}
        while pending:
            wave, pending = pending[: self.B], pending[self.B:]
            # pad prompts to the wave max
            L = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), L), np.int32)
            for i, r in enumerate(wave):
                toks[i, L - len(r.prompt):] = r.prompt  # left-pad
            outs = self.generate_batch([toks[i] for i in range(len(wave))],
                                       max_new=max(r.max_new_tokens
                                                   for r in wave))
            for i, r in enumerate(wave):
                results[r.rid] = outs[i][: r.max_new_tokens]
        return results
