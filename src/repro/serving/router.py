"""Replica routing for multi-replica serving.

:class:`ReplicaTailEstimator` keeps a per-replica sliding window of
observed request latencies (the per-worker analogue of
``control.estimator.StragglerEstimator``'s fleet-wide window) and
exposes interpolated tail quantiles per replica.

:class:`Router` assigns each request a (primary, backup) replica pair:

  * ``uniform`` — primary uniform over replicas, backup uniform over
    the *other* replicas;
  * ``p2c`` — power of two choices: sample two distinct candidates,
    route to the one with the lower estimated tail quantile; the loser
    is the natural backup (already distinct, and second-best by the
    estimate).

All draws are vectorized per chunk and seeded, so a (seed, trace) pair
fully determines every routing decision.
"""

from __future__ import annotations

import numpy as np

__all__ = ["ReplicaTailEstimator", "Router", "ROUTER_POLICIES"]

ROUTER_POLICIES = ("uniform", "p2c")


class ReplicaTailEstimator:
    """Sliding-window per-replica latency quantiles.

    ``update`` ingests (replica id, latency) pairs chunk-at-a-time;
    each replica keeps its own ring of the last ``window`` latencies.
    ``quantile(q)`` returns the per-replica estimate [n], falling back
    to ``default`` for replicas with no observations yet.
    """

    def __init__(self, n: int, *, window: int = 512, default: float = 1.0):
        if n <= 0:
            raise ValueError(f"need n > 0, got {n}")
        self.n = n
        self.window = max(1, int(window))
        self.default = float(default)
        self._rows = np.empty((n, self.window))
        self._count = np.zeros(n, dtype=np.int64)

    def update(self, replicas: np.ndarray, latencies: np.ndarray) -> None:
        r = np.asarray(replicas, dtype=np.int64)
        lat = np.asarray(latencies, dtype=np.float64)
        if r.shape != lat.shape:
            raise ValueError(f"shape mismatch {r.shape} vs {lat.shape}")
        if r.size == 0:
            return
        # group by replica (stable, so each replica sees its latencies
        # in request order), then ring-write each group's chunk
        order = np.argsort(r, kind="stable")
        sr, sl = r[order], lat[order]
        starts = np.flatnonzero(np.r_[True, sr[1:] != sr[:-1]])
        sizes = np.diff(np.r_[starts, sr.size])
        cum = np.arange(sr.size) - np.repeat(starts, sizes)
        slots = (self._count[sr] + cum) % self.window
        self._rows[sr, slots] = sl
        uniq = sr[starts]
        self._count[uniq] += sizes

    def quantile(self, q: float) -> np.ndarray:
        """Per-replica latency quantile [n] (``default`` when unseen)."""
        out = np.full(self.n, self.default)
        for j in np.flatnonzero(self._count):
            m = min(int(self._count[j]), self.window)
            out[j] = np.quantile(self._rows[j, :m], q)
        return out


class Router:
    """Seeded (primary, backup) replica assignment per request chunk."""

    def __init__(self, n: int, policy: str = "uniform", *, seed: int = 0,
                 tail_q: float = 0.9, window: int = 512):
        if policy not in ROUTER_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from {ROUTER_POLICIES}")
        if n < 2:
            raise ValueError(f"routing needs >= 2 replicas, got {n}")
        self.n = n
        self.policy = policy
        self.tail_q = float(tail_q)
        self.estimator = ReplicaTailEstimator(n, window=window)
        self._rng = np.random.default_rng((seed, 0x52))

    def assign(self, size: int):
        """(primary, backup) replica ids for ``size`` requests."""
        a = self._rng.integers(0, self.n, size)
        # b distinct from a by construction
        b = (a + 1 + self._rng.integers(0, self.n - 1, size)) % self.n
        if self.policy == "uniform":
            return a, b
        est = self.estimator.quantile(self.tail_q)
        better = est[a] <= est[b]
        primary = np.where(better, a, b)
        backup = np.where(better, b, a)
        return primary, backup

    def observe(self, replicas: np.ndarray, latencies: np.ndarray) -> None:
        """Feed completed-request latencies back into the estimator."""
        self.estimator.update(replicas, latencies)
