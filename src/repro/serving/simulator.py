"""Vectorized multi-replica serving simulator.

Replays a :class:`~repro.sim.traces.LatencyTrace` as per-replica latency
streams (column j = replica j, via :class:`~repro.sim.traces.TraceCursor`)
and pushes requests through routing + hedging *without any device
execution*: requests are processed in numpy chunks (default 8192), so
p99/p999-vs-compute-overhead Pareto fronts over >= 1M requests take
seconds on a laptop.

Per chunk:

  1. the :class:`~repro.serving.router.Router` assigns (primary, backup)
     replica pairs;
  2. each replica's cursor yields the latencies those requests would
     observe (the trace is a latency *stream* per replica — backup draws
     consume the backup replica's stream whether or not the hedge fires,
     which keeps the replay deterministic in (seed, trace));
  3. :func:`~repro.serving.hedge.hedge_outcomes` converts
     (primary, backup, threshold) into per-request latency / compute /
     fired under first-finisher-wins cancellation;
  4. observed primary latencies feed back into the hedge controller's
     online quantile and the router's per-replica tail estimator.

Everything downstream of the trace is a pure function of
``(trace, policy, router policy, seed)`` — two runs with the same
arguments produce bitwise-identical result arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..sim.traces import LatencyTrace, TraceCursor
from .hedge import HedgeController, HedgePolicy, hedge_outcomes
from .router import Router

__all__ = ["SimResult", "simulate_serving", "pareto_front"]

_QUANTS = (0.5, 0.9, 0.99, 0.999)


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-request outcome arrays plus scalar summary."""

    latency: np.ndarray          # [R] client-observed latency
    compute: np.ndarray          # [R] replica-seconds burned
    fired: np.ndarray            # [R] bool, hedge fired
    primary: np.ndarray          # [R] primary replica id
    quantiles: Dict[float, float]
    mean_compute: float
    hedge_rate: float

    @property
    def p99(self) -> float:
        return self.quantiles[0.99]

    @property
    def p999(self) -> float:
        return self.quantiles[0.999]

    def overhead_vs(self, other: "SimResult") -> float:
        """Compute overhead of this run relative to ``other`` (the
        paper's compute-overhead axis, serving edition)."""
        return self.mean_compute / other.mean_compute

    def summary(self) -> Dict[str, float]:
        out = {f"p{100 * q:g}": v for q, v in self.quantiles.items()}
        out["mean_compute"] = self.mean_compute
        out["hedge_rate"] = self.hedge_rate
        return out


def simulate_serving(trace: LatencyTrace, num_requests: int, *,
                     policy: Optional[HedgePolicy] = None,
                     router_policy: str = "uniform",
                     seed: int = 0, chunk: int = 8192) -> SimResult:
    """Run ``num_requests`` through the replica pool of ``trace``.

    ``policy=None`` serves unhedged (backup streams are still consumed,
    so hedged and unhedged runs of the same (seed, trace) see identical
    primary latencies and differ only in hedging).
    """
    if num_requests <= 0:
        raise ValueError(f"num_requests must be > 0, got {num_requests}")
    if chunk <= 0:
        raise ValueError(f"chunk must be > 0, got {chunk}")
    router = Router(trace.n, router_policy, seed=seed)
    controller = HedgeController(policy) if policy is not None else None
    cursor = TraceCursor(trace)

    latency = np.empty(num_requests)
    compute = np.empty(num_requests)
    fired = np.zeros(num_requests, dtype=bool)
    primary_ids = np.empty(num_requests, dtype=np.int64)

    done = 0
    while done < num_requests:
        size = min(chunk, num_requests - done)
        pr, br = router.assign(size)
        # one interleaved draw so each replica's stream is consumed in
        # request order regardless of primary/backup role
        both = cursor.take(np.concatenate([pr, br]))
        t_p, t_b = both[:size], both[size:]
        if controller is not None:
            thr = controller.threshold()
            lat, comp, f = hedge_outcomes(t_p, t_b, thr)
            controller.observe(t_p)
        else:
            lat, comp, f = t_p, t_p.copy(), np.zeros(size, dtype=bool)
        router.observe(pr, t_p)
        sl = slice(done, done + size)
        latency[sl], compute[sl], fired[sl] = lat, comp, f
        primary_ids[sl] = pr
        done += size

    quants = {q: float(np.quantile(latency, q)) for q in _QUANTS}
    return SimResult(
        latency=latency, compute=compute, fired=fired, primary=primary_ids,
        quantiles=quants, mean_compute=float(compute.mean()),
        hedge_rate=float(fired.mean()))


def pareto_front(trace: LatencyTrace, num_requests: int, *,
                 quantiles=(0.5, 0.75, 0.85, 0.95, 0.99),
                 router_policy: str = "uniform", seed: int = 0,
                 chunk: int = 8192) -> Dict:
    """Sweep hedge quantiles; return the tail-vs-overhead frontier.

    Result rows share one unhedged baseline run (same seed/trace), so
    ``overhead`` is directly the extra replica-seconds per request the
    hedge quantile buys its tail reduction with.
    """
    base = simulate_serving(trace, num_requests, policy=None,
                            router_policy=router_policy, seed=seed,
                            chunk=chunk)
    rows = []
    for q in quantiles:
        res = simulate_serving(trace, num_requests,
                               policy=HedgePolicy(quantile=q),
                               router_policy=router_policy, seed=seed,
                               chunk=chunk)
        rows.append({"quantile": q, "p50": res.quantiles[0.5],
                     "p99": res.p99, "p999": res.p999,
                     "hedge_rate": res.hedge_rate,
                     "overhead": res.overhead_vs(base)})
    return {"unhedged": {"p50": base.quantiles[0.5], "p99": base.p99,
                         "p999": base.p999,
                         "mean_compute": base.mean_compute},
            "rows": rows}
