"""granite-moe-3b-a800m [moe] — 40 experts top-8, fine-grained
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40e
top-8.  40 experts do not divide the 16-way model axis, so experts are
sharded *internally* (d_ff tensor-parallel) — see docs/architecture.md §2.4.
"""

from repro.models import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv=8,
        d_head=64,
        d_ff=512,
        vocab=49155,
        moe=MoEConfig(
            num_experts=40,
            top_k=8,
            d_ff_expert=512,
            expert_shard="tp",
        ),
        act="swiglu",
        norm="rmsnorm",
    )
