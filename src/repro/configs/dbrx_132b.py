"""dbrx-132b [moe] — 16 experts top-4, fine-grained [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert vocab=100352, MoE 16e
top-4.  16 experts == 16-way model axis -> clean expert parallelism.
"""

from repro.models import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        d_head=128,
        d_ff=10752,
        vocab=100352,
        moe=MoEConfig(
            num_experts=16,
            top_k=4,
            d_ff_expert=10752,
            expert_shard="ep",
        ),
        act="swiglu",
        norm="rmsnorm",
        rope_theta=5e5,
    )
