"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.  Head size 64
(-> 40 wkv heads).  Decode is O(1) state update, so long_500k runs.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv=40,
        d_head=64,
        d_ff=8960,
        vocab=65536,
        block_pattern=("rwkv",),
        rope_theta=0.0,
        act="swiglu",          # used by channel-mix ffn sizing only
        norm="layernorm",
    )
