"""internvl2-76b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The InternViT
frontend is a stub: input_specs provide precomputed patch embeddings for a
256-token visual prefix; the LM backbone (which dominates compute) is
exact.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv=8,
        d_head=128,
        d_ff=28672,
        vocab=128256,
        frontend="patches",
        frontend_tokens=256,
        act="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
    )
