"""command-r-plus-104b [dense] — GQA, no-bias [hf:CohereForAI family].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv=8,
        d_head=128,
        d_ff=33792,
        vocab=256000,
        qkv_bias=False,
        act="swiglu",
        norm="layernorm",
    )
