"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, Griffin pattern
[arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; repeating block
pattern (rec, rec, attn) with a 2048-token sliding window on attention
layers — decode state is O(1)+O(window), so the long_500k cell runs.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv=1,
        d_head=256,
        d_ff=12288,
        vocab=256000,
        block_pattern=("rec", "rec", "attn"),
        local_window=2048,
        rnn_width=4096,
        conv_width=4,
        act="geglu",
        norm="rmsnorm",
        logit_softcap=30.0,
    )
