"""Architecture registry: one module per assigned architecture.

Usage:  cfg = repro.configs.get_config("dbrx-132b")
        ids = repro.configs.list_archs()
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ArchConfig, reduce_for_smoke

_MODULES: Dict[str, str] = {
    "internvl2-76b": "internvl2_76b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "dbrx-132b": "dbrx_132b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen1.5-32b": "qwen15_32b",
    "starcoder2-7b": "starcoder2_7b",
    "command-r-plus-104b": "command_r_plus_104b",
    "minicpm-2b": "minicpm_2b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-large-v3": "whisper_large_v3",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.config()
    return reduce_for_smoke(cfg) if smoke else cfg
