"""minicpm-2b [dense] — WSD schedule, llama-like arch [arXiv:2404.06395].

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.  Tied
embeddings; the Warmup-Stable-Decay schedule is wired into the optimizer
(repro.optim.schedules) via schedule="wsd".
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv=36,
        d_head=64,
        d_ff=5760,
        vocab=122753,
        tie_embeddings=True,
        act="swiglu",
        norm="rmsnorm",
        schedule="wsd",
    )
