"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

32L(enc)+32L(dec) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
The mel+conv1d stem is a stub: input_specs provide precomputed frame
embeddings [B, T, d_model].  Sinusoidal positions (no RoPE), LayerNorm,
GeLU, biases.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,
        encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv=20,
        d_head=64,
        d_ff=5120,
        vocab=51866,
        qkv_bias=True,
        rope_theta=0.0,
        frontend="frames",
        act="gelu",
        norm="layernorm",
    )
