"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.  Plain GeLU MLP +
LayerNorm + biases, per the paper.
"""

from repro.models import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv=4,
        d_head=128,
        d_ff=18432,
        vocab=49152,
        qkv_bias=True,
        act="gelu",
        norm="layernorm",
    )
