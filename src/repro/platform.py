"""repro.platform — the one import-time-safe backend configuration API.

Every knob that must be decided *before* jax initializes its backends
(platform selection, host CPU device-count worlds, x64, XLA flag
presets) lives here, with one documented precedence rule instead of the
ad-hoc ``XLA_FLAGS=`` strings the tests/CI used to carry:

    1. A PRE-SET environment variable wins VERBATIM.  ``configure()``
       never overwrites ``XLA_FLAGS`` / ``JAX_PLATFORMS`` /
       ``JAX_ENABLE_X64`` that the caller (or CI lane) already exported
       — so an outer world always beats an inner default, exactly the
       setdefault contract launch/dryrun.py pioneered.
    2. ``configure()`` must run before jax initializes its backends.
       If it still has assignments to make after the env was consulted
       and jax is already initialized, it raises RuntimeError loudly
       (the old setdefault was silently ineffective in that case).
    3. x64 is the one exception: jax supports toggling it at runtime,
       so a late ``x64=`` goes through ``jax.config.update`` instead of
       raising (a pre-set ``JAX_ENABLE_X64`` still wins).

Entry points:

    configure(platform=, host_devices=, x64=, preset=)  the full API
    host_devices(n)              CPU host-device world (tests, dryrun)
    configure_from_env()         REPRO_PLATFORM / REPRO_HOST_DEVICES /
                                 REPRO_X64 env — how CI lanes export
                                 their world through this module
    subprocess_env(...)          same decisions rendered into an env
                                 dict for a child process (the
                                 differential-test subprocess helper)
    backend_info()               live (platform, devices, hardware
                                 spec); backend_key() is the stable
                                 string the bench baselines key on
    HARDWARE / resolve_hardware  per-backend peak FLOPs / HBM / link
                                 bandwidth presets (launch/roofline.py
                                 reads these instead of hardcoding
                                 TPU-v5e constants)

This module imports no jax at module scope, so it is safe to import
first in any process.
"""

from __future__ import annotations

import dataclasses
import os
import sys
import warnings
from typing import Dict, Optional, Union

__all__ = [
    "HardwareSpec", "HARDWARE", "PRESETS", "BackendInfo",
    "configure", "host_devices", "configure_from_env", "subprocess_env",
    "backend_info", "backend_key", "runtime_platform", "resolve_hardware",
    "jax_is_initialized",
]


# --------------------------------------------------------------------------
# hardware presets (feed launch/roofline.py and launch/autotune.py)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-device roofline constants for one backend.

    Peak numbers are the marketing matmul peaks (bf16 where the backend
    has one); the CPU entry is an order-of-magnitude estimate for a
    modern multicore host (AVX fp32 + dual-channel DDR) — good enough
    to rank tile candidates and to label CPU bench baselines, not a
    calibrated model.
    """

    name: str            # stable key ("tpu-v5e", "gpu-a100", "cpu")
    platform: str        # jax backend name: "tpu" | "gpu" | "cpu"
    peak_flops: float    # FLOP/s per device
    hbm_bw: float        # main-memory bandwidth, B/s per device
    link_bw: float       # interconnect bandwidth, B/s per link
    vmem_bytes: int      # fast scratch budget per core (tile feasibility)


HARDWARE: Dict[str, HardwareSpec] = {
    # TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s per ICI link,
    # ~16 MB VMEM/core (the constants launch/roofline.py used to inline)
    "tpu-v5e": HardwareSpec("tpu-v5e", "tpu", 197e12, 819e9, 50e9,
                            16 * 2**20),
    "tpu-v4": HardwareSpec("tpu-v4", "tpu", 275e12, 1228e9, 50e9,
                           16 * 2**20),
    "gpu-a100": HardwareSpec("gpu-a100", "gpu", 312e12, 2039e9, 600e9,
                             40 * 2**20),   # SMEM+L2 working-set budget
    "gpu-h100": HardwareSpec("gpu-h100", "gpu", 989e12, 3350e9, 900e9,
                             50 * 2**20),
    # host CPU estimate: ~0.5 TFLOP/s fp32 across cores, ~50 GB/s DDR,
    # "link" = memory bus shared between host devices, LLC as scratch
    "cpu": HardwareSpec("cpu", "cpu", 5e11, 5e10, 5e10, 32 * 2**20),
}

# the spec assumed when only the platform is known
_PLATFORM_DEFAULT_HW = {"tpu": "tpu-v5e", "gpu": "gpu-a100", "cpu": "cpu"}

# device_kind substrings -> HARDWARE keys (first match wins)
_DEVICE_KIND_MAP = (
    ("v5 lite", "tpu-v5e"), ("v5e", "tpu-v5e"), ("v4", "tpu-v4"),
    ("h100", "gpu-h100"), ("a100", "gpu-a100"),
)


def resolve_hardware(hw: Union[None, str, HardwareSpec]) -> HardwareSpec:
    """HardwareSpec from a spec, a HARDWARE key, or a platform name."""
    if isinstance(hw, HardwareSpec):
        return hw
    if hw is None:
        return HARDWARE[_PLATFORM_DEFAULT_HW.get(
            runtime_platform() or "cpu", "cpu")]
    if hw in HARDWARE:
        return HARDWARE[hw]
    if hw in _PLATFORM_DEFAULT_HW:
        return HARDWARE[_PLATFORM_DEFAULT_HW[hw]]
    raise KeyError(f"unknown hardware {hw!r}; have {sorted(HARDWARE)} "
                   f"or a platform in {sorted(_PLATFORM_DEFAULT_HW)}")


# --------------------------------------------------------------------------
# XLA flag presets per backend
# --------------------------------------------------------------------------

# Documented env presets.  Each maps env var -> value; applied with the
# pre-set-env-wins rule.  The "cpu" preset is empty on purpose — CPU
# worlds are defined by host_devices(n), which composes the
# --xla_force_host_platform_device_count flag itself.
PRESETS: Dict[str, Dict[str, str]] = {
    "cpu": {},
    # the gpu autotune / latency-hiding flag set (bayespec's
    # set_platform gpu branch, minus the long-removed flags)
    "gpu": {
        "XLA_FLAGS": ("--xla_gpu_triton_gemm_any=True "
                      "--xla_gpu_enable_latency_hiding_scheduler=true"),
    },
    # the TPU process env distilled from olmax's run.sh: one host
    # device (TPU-CPU is not used for ML), step markers at the outer
    # while loop for profiling, quiet TF logging
    "tpu": {
        "XLA_FLAGS": ("--xla_force_host_platform_device_count=1 "
                      "--xla_step_marker_location=1"),
        "TF_CPP_MIN_LOG_LEVEL": "4",
    },
}

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


# --------------------------------------------------------------------------
# jax state probes (no jax import unless already present)
# --------------------------------------------------------------------------


def jax_is_initialized() -> bool:
    """True once jax has created a backend (device count is locked)."""
    if "jax" not in sys.modules:
        return False
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is None:
        return False
    return bool(getattr(xb, "_backends", None))


def runtime_platform() -> Optional[str]:
    """The live jax backend name, or None when jax is uninitialized.

    Never initializes jax itself — callers that only want to *label*
    (roofline warnings, bench baselines) must not pay backend startup.
    """
    if not jax_is_initialized():
        return None
    import jax

    return jax.default_backend()


# --------------------------------------------------------------------------
# configure
# --------------------------------------------------------------------------


def _desired_env(platform: Optional[str], host_devices: Optional[int],
                 x64: Optional[bool], preset: Optional[str]) -> Dict[str, str]:
    """The env assignments configure()/subprocess_env() agree on."""
    if platform is not None and platform not in PRESETS:
        raise ValueError(f"platform {platform!r} not in {sorted(PRESETS)}")
    if preset is None:
        preset = platform
    if preset is not None and preset not in PRESETS:
        raise ValueError(f"preset {preset!r} not in {sorted(PRESETS)}")

    want: Dict[str, str] = dict(PRESETS[preset]) if preset else {}
    if platform is not None:
        want["JAX_PLATFORMS"] = platform
    if host_devices is not None:
        n = int(host_devices)
        if n <= 0:
            raise ValueError(f"host_devices must be positive, got {n}")
        flag = f"{_HOST_COUNT_FLAG}={n}"
        base = want.get("XLA_FLAGS", "")
        if _HOST_COUNT_FLAG in base:   # preset carried a count: ours wins
            base = " ".join(f for f in base.split()
                            if not f.startswith(_HOST_COUNT_FLAG))
        want["XLA_FLAGS"] = (base + " " + flag).strip()
    if x64 is not None:
        want["JAX_ENABLE_X64"] = "1" if x64 else "0"
    return want


def configure(platform: Optional[str] = None,
              host_devices: Optional[int] = None,
              x64: Optional[bool] = None,
              preset: Optional[str] = None) -> Dict[str, str]:
    """Configure the jax world for this process.  Call before jax inits.

    Returns a report dict mapping each env var this call considered to
    ``"set"`` (we exported it) or ``"respected"`` (a pre-set value won
    verbatim — precedence rule 1).  Raises RuntimeError when an
    assignment is still needed but jax already initialized (rule 2);
    ``x64`` alone falls through to ``jax.config.update`` (rule 3).
    """
    want = _desired_env(platform, host_devices, x64, preset)
    report: Dict[str, str] = {}
    late_x64 = None
    for var, val in want.items():
        if var in os.environ:
            report[var] = "respected"
            continue
        if var == "JAX_ENABLE_X64" and "jax" in sys.modules:
            # runtime-togglable: route through jax.config instead of an
            # env var jax has already read
            late_x64 = val == "1"
            report[var] = "set"
            continue
        if jax_is_initialized():
            raise RuntimeError(
                f"repro.platform.configure() would set {var}={val!r}, but "
                f"jax already initialized its "
                f"{runtime_platform()!r} backend — the setting cannot take "
                f"effect.  Call configure() before the first jax device "
                f"use (typically first thing in the process), or export "
                f"the environment variable before launching.")
        os.environ[var] = val
        report[var] = "set"
    if late_x64 is not None:
        import jax

        jax.config.update("jax_enable_x64", late_x64)
    return report


def host_devices(n: int, *, x64: Optional[bool] = None) -> Dict[str, str]:
    """An ``n``-device host CPU world (tests, dry-runs, differentials).

    Sugar for ``configure(host_devices=n, x64=x64)`` — same precedence
    rules: a pre-set ``XLA_FLAGS`` wins verbatim, calling after jax
    initialized (with work left to do) raises.
    """
    return configure(host_devices=n, x64=x64)


_ENV_KEYS = ("REPRO_PLATFORM", "REPRO_HOST_DEVICES", "REPRO_X64",
             "REPRO_PRESET")


def configure_from_env() -> Optional[Dict[str, str]]:
    """Apply REPRO_* env configuration (the CI lanes' entry point).

    Reads REPRO_PLATFORM / REPRO_HOST_DEVICES / REPRO_X64 /
    REPRO_PRESET and calls :func:`configure` when any is set (no-op
    otherwise, so unconfigured local runs are untouched).  Called from
    tests/conftest.py, which runs before any test imports jax.
    """
    if not any(k in os.environ for k in _ENV_KEYS):
        return None
    hd = os.environ.get("REPRO_HOST_DEVICES")
    x64 = os.environ.get("REPRO_X64")
    return configure(
        platform=os.environ.get("REPRO_PLATFORM"),
        host_devices=int(hd) if hd else None,
        x64=(x64 not in ("0", "false", "False")) if x64 is not None else None,
        preset=os.environ.get("REPRO_PRESET"),
    )


def subprocess_env(base: Optional[Dict[str, str]] = None, *,
                   platform: Optional[str] = None,
                   host_devices: Optional[int] = None,
                   x64: Optional[bool] = None,
                   preset: Optional[str] = None,
                   override: bool = False) -> Dict[str, str]:
    """Env dict for a child process with the requested jax world.

    The one place the differential tests get their forced-device
    subprocess env from.  ``override=False`` follows the standard
    precedence (vars already in ``base`` win); ``override=True``
    assigns unconditionally — for tests that *assert* an exact world
    (e.g. ``jax.device_count() == 8``) regardless of the caller's env.
    """
    env = dict(os.environ if base is None else base)
    for var, val in _desired_env(platform, host_devices, x64,
                                 preset).items():
        if override or var not in env:
            env[var] = val
    return env


# --------------------------------------------------------------------------
# backend reporting
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendInfo:
    """What the live jax world looks like, roofline constants included."""

    platform: str            # "cpu" | "gpu" | "tpu"
    device_count: int
    device_kind: str         # jax's device_kind string
    key: str                 # stable baseline key ("cpu", "tpu-v5e", ...)
    hardware: HardwareSpec   # peak FLOPs / HBM bw / link bw preset

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hardware"] = dataclasses.asdict(self.hardware)
        return d


def _key_for(platform: str, device_kind: str) -> str:
    if platform == "cpu":
        return "cpu"
    kind = device_kind.lower()
    for sub, key in _DEVICE_KIND_MAP:
        if sub in kind:
            return key
    slug = "-".join(kind.split()) or platform
    return slug if slug.startswith(platform) else f"{platform}-{slug}"


def backend_info() -> BackendInfo:
    """Live world report.  Initializes jax (device query) if needed."""
    import jax

    platform = jax.default_backend()
    devices = jax.devices()
    kind = getattr(devices[0], "device_kind", platform)
    key = _key_for(platform, kind)
    hw = HARDWARE.get(key) or HARDWARE[_PLATFORM_DEFAULT_HW.get(
        platform, "cpu")]
    return BackendInfo(platform=platform, device_count=len(devices),
                       device_kind=kind, key=key, hardware=hw)


def backend_key(initialize: bool = False) -> str:
    """Stable backend key for baselines / tile tables ("cpu", "tpu-v5e").

    With ``initialize=False`` (default) and jax not yet initialized,
    the key is inferred from the configured env (JAX_PLATFORMS /
    REPRO_PLATFORM, default "cpu") so numpy-only benchmark runs never
    pay jax startup just to label their artifact.
    """
    if jax_is_initialized() or initialize:
        return backend_info().key
    plat = os.environ.get("JAX_PLATFORMS") \
        or os.environ.get("REPRO_PLATFORM") or "cpu"
    plat = plat.split(",")[0].strip() or "cpu"
    if plat == "cpu":
        return "cpu"
    return _PLATFORM_DEFAULT_HW.get(plat, plat)
