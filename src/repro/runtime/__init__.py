"""Runtime substrate: straggler models and fault injection.

Public surface: the ``StragglerModel`` family (``make_straggler_model``
resolves names — none / iid / fixed / deadline / correlated /
adversarial / bimodal / clustered) and the hard-fault machinery
(``FaultInjector`` / ``FaultPlan``).  Wall-clock modelling lives in
``repro.sim`` (a ``LatencyTrace`` + sync policy; the old
``runtime.latency.simulate_wallclock`` wrapper is gone — use
``sim.cluster.wallclock_summary``).
"""

from .faults import FaultInjector, FaultPlan  # noqa: F401
from .straggler import (  # noqa: F401
    AdversarialStragglers,
    BimodalStragglers,
    ClusteredStragglers,
    CorrelatedStragglers,
    DeadlineStragglers,
    FixedFractionStragglers,
    IIDStragglers,
    NoStragglers,
    StragglerModel,
    make_straggler_model,
)
