"""Runtime substrate: straggler models, wall-clock model, fault injection."""

from .faults import FaultInjector, FaultPlan  # noqa: F401
from .latency import StepTimeModel, simulate_wallclock  # noqa: F401
from .straggler import (  # noqa: F401
    AdversarialStragglers,
    BimodalStragglers,
    ClusteredStragglers,
    CorrelatedStragglers,
    DeadlineStragglers,
    FixedFractionStragglers,
    IIDStragglers,
    NoStragglers,
    StragglerModel,
    make_straggler_model,
)
