"""DEPRECATED wall-clock shims — superseded by repro.sim (ClusterSim).

The analytic runtime model now lives in ``sim.cluster``: a LatencyTrace
([steps, n] latencies from any straggler model) is mapped by a sync
policy (sync / deadline / backup / adaptive) to per-step masks and step
times, and the whole run decodes in one batched DecodeEngine call.

This module keeps the original public surface as thin wrappers so old
callers and scripts keep working:

  * ``simulate_wallclock`` delegates to ``sim.cluster.wallclock_summary``
    (bit-identical output — proven by tests/test_sim_cluster.py).  The
    old code compared ``lat * compute_scale <= deadline * compute_scale``;
    the redundant scaling cancels and is gone.
  * ``StepTimeModel`` delegates to the sim policy objects.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from .straggler import StragglerModel

__all__ = ["StepTimeModel", "simulate_wallclock"]


@dataclasses.dataclass
class StepTimeModel:
    """Deprecated: use a sim.cluster SyncPolicy."""

    policy: str = "deadline"       # sync | deadline | backup
    deadline: float = 1.5
    compute_scale: float = 1.0     # relative per-step compute (s tasks vs 1)

    def step_time(self, latencies: np.ndarray) -> float:
        lat = np.asarray(latencies) * self.compute_scale
        if self.policy == "sync":
            return float(lat.max())
        if self.policy == "deadline":
            return float(min(self.deadline * self.compute_scale, lat.max()))
        if self.policy == "backup":
            return float(np.quantile(lat, 0.95))
        raise ValueError(self.policy)


def simulate_wallclock(model: StragglerModel, n: int, steps: int,
                       policy: str = "deadline", deadline: float = 1.5,
                       compute_scale: float = 1.0) -> dict:
    """Deprecated wrapper over sim.cluster.wallclock_summary.

    Prefer building a LatencyTrace + ClusterSim directly — that path
    also co-simulates decoding, which this summary never did.
    """
    warnings.warn(
        "runtime.latency.simulate_wallclock is deprecated; use "
        "repro.sim (trace_from_model + ClusterSim / wallclock_summary)",
        DeprecationWarning, stacklevel=2)
    from ..sim.cluster import wallclock_summary
    from ..sim.traces import LatencyTrace
    # exact old semantics: the model's own latencies() rows — unit
    # latencies for mask-only models, NOT the two-point lift that
    # sim.traces.trace_from_model applies for the co-simulation
    lat = np.stack([model.latencies(t, n) for t in range(steps)])
    trace = LatencyTrace(lat, source=type(model).__name__)
    return wallclock_summary(trace, policy=policy, deadline=deadline,
                             compute_scale=compute_scale)
