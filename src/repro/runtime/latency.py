"""Analytic wall-clock model: the paper's runtime-vs-robustness trade-off.

The container is CPU-only, so step *times* are modelled, not measured:
per-worker latencies come from the straggler model's distribution, and a
synchronization policy maps them to a step time:

  * 'sync'      — wait for everyone: T = max_j L_j       (uncoded baseline)
  * 'deadline'  — coded: T = min(deadline, max_j L_j); workers missing the
                  deadline are stragglers absorbed as decode error
  * 'backup'    — Dean-style backup tasks: T = (k/n-th order statistic)

These combine with the decoder's error to reproduce the paper's central
claim: small decode error buys a large tail-latency reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .straggler import StragglerModel

__all__ = ["StepTimeModel", "simulate_wallclock"]


@dataclasses.dataclass
class StepTimeModel:
    policy: str = "deadline"       # sync | deadline | backup
    deadline: float = 1.5
    compute_scale: float = 1.0     # relative per-step compute (s tasks vs 1)

    def step_time(self, latencies: np.ndarray) -> float:
        lat = latencies * self.compute_scale
        if self.policy == "sync":
            return float(lat.max())
        if self.policy == "deadline":
            return float(min(self.deadline * self.compute_scale, lat.max()))
        if self.policy == "backup":
            return float(np.quantile(lat, 0.95))
        raise ValueError(self.policy)


def simulate_wallclock(model: StragglerModel, n: int, steps: int,
                       policy: str = "deadline", deadline: float = 1.5,
                       compute_scale: float = 1.0) -> dict:
    """Aggregate modelled wall-clock + straggler stats over `steps`."""
    tm = StepTimeModel(policy=policy, deadline=deadline,
                       compute_scale=compute_scale)
    total, masks = 0.0, []
    for t in range(steps):
        lat = model.latencies(t, n)
        total += tm.step_time(lat)
        masks.append(lat * compute_scale
                     <= deadline * compute_scale if policy == "deadline"
                     else np.ones(n, bool))
    masks = np.asarray(masks)
    return {
        "total_time": total,
        "mean_step_time": total / steps,
        "mean_stragglers": float((~masks).sum(1).mean()),
        "worst_stragglers": int((~masks).sum(1).max()),
    }
