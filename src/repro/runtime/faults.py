"""Hard-fault injection + recovery policy.

Distinct from stragglers (transient): a fault permanently removes a
worker.  Recovery options the trainer supports:

  * 'elastic'   — shrink the worker set, regenerate the gradient code for
                  n' = n - failed (O(n s): the paper's cheap-construction
                  property is exactly what makes this viable vs expander
                  codes), remap data partitions, continue.
  * 'restart'   — restore the last checkpoint with a fresh worker set.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    step: int
    workers: tuple  # worker ids to kill


class FaultInjector:
    def __init__(self, plans: Optional[List[FaultPlan]] = None):
        self.plans = sorted(plans or [], key=lambda p: p.step)
        self.dead: set = set()

    def check(self, step: int) -> Optional[FaultPlan]:
        for p in self.plans:
            if p.step == step and not set(p.workers) <= self.dead:
                self.dead |= set(p.workers)
                return p
        return None

    def alive_count(self, n0: int) -> int:
        return n0 - len(self.dead)
