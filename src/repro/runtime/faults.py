"""Hard-fault injection + recovery policy.

Distinct from stragglers (transient): a fault permanently removes a
worker.  Recovery options the trainer supports:

  * 'elastic'   — shrink the worker set, regenerate the gradient code for
                  n' = n - failed (O(n s): the paper's cheap-construction
                  property is exactly what makes this viable vs expander
                  codes), remap data partitions, continue.
  * 'restart'   — restore the last checkpoint with a fresh worker set.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    step: int
    workers: tuple  # worker ids to kill


class FaultInjector:
    def __init__(self, plans: Optional[List[FaultPlan]] = None):
        self.plans = sorted(plans or [], key=lambda p: p.step)
        self.dead: set = set()

    def check(self, step: int) -> Optional[FaultPlan]:
        """All plans scheduled for `step`, coalesced into one FaultPlan.

        Multiple co-scheduled plans merge (the old code returned the
        first match and silently dropped the rest); workers already dead
        are filtered out so the returned plan lists only NEW deaths.
        Returns None when nothing new dies at this step.
        """
        new: set = set()
        for p in self.plans:
            if p.step == step:
                new |= set(p.workers) - self.dead
        if not new:
            return None
        self.dead |= new
        return FaultPlan(step=step, workers=tuple(sorted(new)))

    def alive_count(self, n0: int) -> int:
        return n0 - len(self.dead)
