"""Straggler models: who fails to report by the aggregation deadline.

All models are deterministic given (seed, step) so every host in an SPMD
job derives the same mask without communication — the TPU-native
replacement for the paper's master observing arrivals.
"""

from __future__ import annotations

import dataclasses
import numpy as np

from ..core import adversary as ADV

__all__ = ["StragglerModel", "NoStragglers", "IIDStragglers",
           "FixedFractionStragglers", "DeadlineStragglers",
           "CorrelatedStragglers", "AdversarialStragglers",
           "BimodalStragglers", "ClusteredStragglers",
           "make_straggler_model"]


class StragglerModel:
    """mask[j] == True  <=>  worker j is a NON-straggler this step."""

    def sample(self, step: int, n: int) -> np.ndarray:
        raise NotImplementedError

    def latencies(self, step: int, n: int) -> np.ndarray:
        """Per-worker compute latencies (seconds) for the wall-clock model.

        Deterministic in (seed, step) like every mask draw, so each host
        derives the same value.  The base model is latency-free (unit
        latencies); models with a real latency distribution override
        this with a default_rng((self.seed, step)) draw.
        """
        del step
        return np.ones(n)


@dataclasses.dataclass
class NoStragglers(StragglerModel):
    def sample(self, step: int, n: int) -> np.ndarray:
        return np.ones(n, dtype=bool)


@dataclasses.dataclass
class IIDStragglers(StragglerModel):
    """Each worker independently straggles with probability delta."""
    delta: float
    seed: int = 0

    def sample(self, step: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return rng.random(n) >= self.delta


@dataclasses.dataclass
class FixedFractionStragglers(StragglerModel):
    """Exactly floor(delta*n) stragglers, uniformly chosen (the paper's
    sampling model)."""
    delta: float
    seed: int = 0

    def sample(self, step: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        mask = np.ones(n, dtype=bool)
        ns = int(self.delta * n)
        if ns:
            mask[rng.choice(n, ns, replace=False)] = False
        return mask


@dataclasses.dataclass
class DeadlineStragglers(StragglerModel):
    """Latency = base + Pareto(alpha) tail; straggler iff latency > deadline.

    Matches the empirical 'slowest nodes dictate runtime' premise; the
    latency draw is reused by repro.sim (LatencyTrace) for the
    wall-clock co-simulation.
    """
    base: float = 1.0
    tail_scale: float = 0.2
    alpha: float = 2.0
    deadline: float = 1.5
    seed: int = 0

    def latencies(self, step: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        return self.base + self.tail_scale * (rng.pareto(self.alpha, n) + 1.0)

    def sample(self, step: int, n: int) -> np.ndarray:
        return self.latencies(step, n) <= self.deadline


@dataclasses.dataclass
class CorrelatedStragglers(StragglerModel):
    """Pod-level correlated failures: a whole pod's workers straggle
    together with prob p_pod; plus iid node-level noise p_node."""
    pod_size: int
    p_pod: float = 0.05
    p_node: float = 0.05
    seed: int = 0

    def sample(self, step: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        npods = -(-n // self.pod_size)
        pod_ok = rng.random(npods) >= self.p_pod
        node_ok = rng.random(n) >= self.p_node
        mask = node_ok & np.repeat(pod_ok, self.pod_size)[:n]
        return mask


@dataclasses.dataclass
class BimodalStragglers(StragglerModel):
    """Bimodal slow-node fleet: a fixed subset of nodes is persistently
    slow (bad NIC, thermal throttling, noisy neighbour) while the rest
    are fast; every node adds per-step log-normal jitter.

    The slow set is a deterministic function of the seed alone — the
    same nodes are slow on every step, the empirically common 'that one
    bad host' regime that iid models can't express.  Stragglers are the
    nodes whose jittered latency misses the deadline, so with
    deadline between the two modes the straggler set is essentially the
    slow set.
    """
    slow_fraction: float = 0.1
    fast: float = 1.0
    slow: float = 3.0
    jitter: float = 0.05      # sigma of multiplicative log-normal noise
    deadline: float = 1.5
    seed: int = 0

    def slow_nodes(self, n: int) -> np.ndarray:
        """Boolean [n] slow-set indicator, step-independent."""
        rng = np.random.default_rng((self.seed, 0x51))
        k_slow = int(round(self.slow_fraction * n))
        slow = np.zeros(n, dtype=bool)
        if k_slow:
            slow[rng.choice(n, k_slow, replace=False)] = True
        return slow

    def latencies(self, step: int, n: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        base = np.where(self.slow_nodes(n), self.slow, self.fast)
        return base * np.exp(self.jitter * rng.standard_normal(n))

    def sample(self, step: int, n: int) -> np.ndarray:
        return self.latencies(step, n) <= self.deadline


@dataclasses.dataclass
class ClusteredStragglers(StragglerModel):
    """Cluster-correlated slow episodes: whole blocks of workers go slow
    together and STAY slow for `episode` consecutive steps.

    Workers are partitioned into `blocks` contiguous clusters by the
    same rule as the SBM code construction (core.codes.block_ids), so a
    clustered trace's failing blocks line up with an SBM code's worker
    blocks — the regime in which clustered codes and iid-style codes
    separate (Charles & Papailiopoulos).  Each block independently
    enters a slow episode with probability `p_block` per epoch (epoch =
    `episode` steps), which keeps the draw a pure function of
    (seed, step) — every SPMD host derives the same latencies with no
    communication and no Markov state to thread.
    """

    blocks: int = 4
    p_block: float = 0.15
    episode: int = 8          # steps a slow episode lasts
    fast: float = 1.0
    slow: float = 3.0
    jitter: float = 0.05      # sigma of multiplicative log-normal noise
    deadline: float = 1.5
    seed: int = 0

    def slow_blocks(self, step: int) -> np.ndarray:
        """[blocks] bool slow indicator for the epoch containing step."""
        epoch = step // max(self.episode, 1)
        rng = np.random.default_rng((self.seed, epoch, 0xC1))
        return rng.random(self.blocks) < self.p_block

    def latencies(self, step: int, n: int) -> np.ndarray:
        from ..core.codes import block_ids

        member = block_ids(n, self.blocks)
        base = np.where(self.slow_blocks(step)[member], self.slow, self.fast)
        rng = np.random.default_rng((self.seed, step))
        return base * np.exp(self.jitter * rng.standard_normal(n))

    def sample(self, step: int, n: int) -> np.ndarray:
        return self.latencies(step, n) <= self.deadline


@dataclasses.dataclass
class AdversarialStragglers(StragglerModel):
    """Poly-time adversary (paper Sec. 4): FRC-structural if the code is an
    FRC, else greedy; budget = floor(delta * n) stragglers per step.

    The adversarial mask depends only on (G, n), not on the step, so it
    is computed once per worker count and cached — the greedy search is
    O(n * budget) least-squares decodes, far too expensive to redo every
    training step.
    """
    G: np.ndarray
    delta: float
    mode: str = "auto"  # auto | frc | greedy
    _cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                     compare=False)

    def sample(self, step: int, n: int) -> np.ndarray:
        del step  # step-independent: the adversary always plays its best
        cached = self._cache.get(n)
        if cached is None:
            cached = self._compute_mask(n)
            self._cache[n] = cached
        return cached.copy()

    def _compute_mask(self, n: int) -> np.ndarray:
        budget = int(self.delta * n)
        if budget == 0:
            return np.ones(n, dtype=bool)
        mode = self.mode
        if mode == "auto":
            # detect FRC structure: duplicated columns
            cols = {self.G[:, j].tobytes() for j in range(self.G.shape[1])}
            mode = "frc" if len(cols) < self.G.shape[1] else "greedy"
        if mode == "frc":
            return ADV.frc_adversarial_mask(self.G, budget)
        return ADV.greedy_adversarial_mask(self.G, budget, objective="onestep")


def make_straggler_model(name: str, **kw) -> StragglerModel:
    models = {
        "none": NoStragglers,
        "iid": IIDStragglers,
        "fixed": FixedFractionStragglers,
        "deadline": DeadlineStragglers,
        "correlated": CorrelatedStragglers,
        "adversarial": AdversarialStragglers,
        "bimodal": BimodalStragglers,
        "clustered": ClusteredStragglers,
    }
    if name not in models:
        raise ValueError(f"unknown straggler model {name!r}")
    return models[name](**kw)
