"""Checkpointing: atomic, step-tagged, resumable, optionally async.

Layout:   <dir>/step_<N>/
            manifest.json      (tree structure, shapes, dtypes, metadata)
            arrays.npz         (flattened leaves, keyed by escaped path)
Writes go to a tmp dir + os.replace for atomicity; keep_last prunes old
steps; an async writer thread overlaps serialization with training.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _tree_structure(tree):
    return jax.tree_util.tree_map(lambda _: 0, tree)


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None,
                    keep_last: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, _ARRAYS), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(directory, keep_last)
    return final


def _prune(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:   # foreign step_* entries (editors, partial copies) are not
            steps.append(int(d.split("_", 1)[1]))   # checkpoints — skip
        except ValueError:
            continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any,
                       step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore into `template`'s structure.  Returns (tree, metadata)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, _ARRAYS))
    flat_template = _flatten(template)
    if sorted(flat_template) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_template)
        raise ValueError(f"checkpoint/template structure mismatch: {sorted(missing)[:5]}")
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    keys_in_order = []
    for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]:
        keys_in_order.append("/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                                      for q in p))
    new_leaves = []
    for key, tleaf in zip(keys_in_order, leaves_t):
        arr = data[key]
        if hasattr(tleaf, "dtype"):
            arr = arr.astype(tleaf.dtype)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["metadata"]


class AsyncCheckpointer:
    """Background-thread writer: enqueue host copies, never block the step."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save_checkpoint(self.directory, step, tree, meta,
                                self.keep_last)
            except BaseException as e:  # surfaced on next save/close
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        """Surface a background failure ONCE: the error is cleared when
        raised, so a later save() can retry instead of replaying the same
        stale exception forever."""
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self._raise_pending()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # device->host now
        self._q.put((step, host_tree, metadata))

    def wait(self):
        self._q.join()
        self._raise_pending()

    def close(self):
        # the sentinel + join run even when wait() surfaces a background
        # failure — close() must never leak the worker thread
        try:
            self.wait()
        finally:
            self._q.put(None)
            self._thread.join(timeout=10)
