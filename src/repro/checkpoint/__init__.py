"""Atomic, resumable checkpointing.

Public surface: ``save_checkpoint`` / ``restore_checkpoint`` /
``latest_step`` (atomic directory-swap persistence of params +
optimizer state + metadata) and ``AsyncCheckpointer`` (background
thread, keeps the last K checkpoints; the trainer's ``ckpt_every``
path).  Restores compose with the trainer's elastic re-coding:
optimizer state survives worker-count changes.
"""

from .checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
