"""Atomic, resumable checkpointing."""

from .checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
