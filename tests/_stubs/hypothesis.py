"""Minimal hypothesis stand-in (used only when the real package is
absent — see tests/conftest.py).

Implements the subset this repo's property tests use: @given with
positional/keyword strategies, @settings(max_examples, deadline),
assume(), and the integers / floats / booleans / sampled_from / tuples /
lists strategies.  Examples are drawn from a deterministic per-test
seed; there is no shrinking or example database.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__version__ = "0.0-stub"


class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise _Assumption()
    return True


def note(_msg) -> None:
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries: int = 100) -> "SearchStrategy":
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Assumption()
        return SearchStrategy(draw)


def integers(min_value=None, max_value=None) -> SearchStrategy:
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 - 1 if max_value is None else int(max_value)
    return SearchStrategy(lambda rng: rng.randint(lo, hi))


def floats(min_value=None, max_value=None, **_kw) -> SearchStrategy:
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)
    return SearchStrategy(lambda rng: rng.uniform(lo, hi))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def lists(elements, min_size=0, max_size=10, **_kw) -> SearchStrategy:
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(size)]
    return SearchStrategy(draw)


def one_of(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.choice(strategies).draw(rng))


_DEFAULT_MAX_EXAMPLES = 25


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


settings.register_profile = staticmethod(lambda *a, **k: None)
settings.load_profile = staticmethod(lambda *a, **k: None)


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(
                (fn.__module__ + "." + fn.__qualname__).encode())
            rng = random.Random(seed)
            ran = 0
            attempts = 0
            while ran < n and attempts < 10 * n + 10:
                attempts += 1
                try:
                    drawn = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {name: s.draw(rng)
                                for name, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _Assumption:
                    continue
                ran += 1
        # every parameter is strategy-supplied: hide the original
        # signature so pytest doesn't look for same-named fixtures
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


# `from hypothesis import strategies as st` / `import hypothesis.strategies`
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "just",
              "tuples", "lists", "one_of"):
    setattr(strategies, _name, globals()[_name])
strategies.SearchStrategy = SearchStrategy
sys.modules.setdefault("hypothesis.strategies", strategies)
