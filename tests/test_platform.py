"""repro.platform + TileConfig/autotune surface (docs/architecture.md §12).

In-process tests cover the pure pieces: TileConfig algebra and
validation, tile-resolution precedence (defaults < committed table <
explicit config < deprecated kwargs), hardware presets, backend-key
inference, and the bitwise contract of the committed autotune table.

The precedence rules that depend on a virgin jax — a pre-set env var
winning verbatim over configure(), the loud late-call RuntimeError, the
REPRO_* env entry point, and forced subprocess worlds — run in
subprocesses whose env is built by repro.platform.subprocess_env, the
same helper the differential suites use.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro import platform
from repro.core import codes
from repro.core.engine import DecodeEngine
from repro.kernels import ops
from repro.kernels.tiles import (DEFAULT_TILES, TileConfig, load_tile_table,
                                 resolve, shape_class)

REPO = Path(__file__).resolve().parent.parent

# vars the subprocess tests must own: start each child from an env with
# none of them so the test controls the whole precedence story
_JAX_VARS = ("XLA_FLAGS", "JAX_PLATFORMS", "JAX_ENABLE_X64") + \
    platform._ENV_KEYS


def _clean_env(extra=None):
    """os.environ minus every var under test, plus ``extra``.

    Children that initialize jax WITHOUT selecting a platform first
    must put JAX_PLATFORMS=cpu in ``extra``: an unpinned jax probes
    for accelerators at backend init and can hang on bare containers.
    """
    env = dict(os.environ)
    for v in _JAX_VARS:
        env.pop(v, None)
    env.update(extra or {})
    return env


def _run_child(body: str, env: dict) -> dict:
    env = dict(env)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         cwd=REPO, env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT:")]
    assert line, f"no RESULT in stdout:\n{out.stdout[-2000:]}"
    return json.loads(line[-1][len("RESULT:"):])


# ==========================================================================
# precedence rules (subprocess: each needs a virgin jax)
# ==========================================================================


@pytest.mark.slow
def test_preset_env_wins_verbatim_over_configure():
    """Rule 1: an exported XLA_FLAGS beats host_devices() outright."""
    env = _clean_env(
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
         "JAX_PLATFORMS": "cpu"})
    res = _run_child("""
        import json
        from repro.platform import host_devices
        report = host_devices(8)
        import jax
        print("RESULT:" + json.dumps(
            {"report": report, "n": jax.device_count()}))
    """, env)
    assert res["report"]["XLA_FLAGS"] == "respected"
    assert res["n"] == 4            # pre-set env won verbatim, not our 8


@pytest.mark.slow
def test_configure_after_jax_init_raises():
    """Rule 2: a late configure() with work to do fails loudly instead
    of silently not taking effect (the old setdefault failure mode)."""
    res = _run_child("""
        import json, jax
        jax.devices()                        # lock the backend
        from repro.platform import configure
        try:
            configure(host_devices=8)
        except RuntimeError as e:
            print("RESULT:" + json.dumps({"raised": True, "msg": str(e)}))
        else:
            print("RESULT:" + json.dumps({"raised": False, "msg": ""}))
    """, _clean_env({"JAX_PLATFORMS": "cpu"}))
    assert res["raised"]
    assert "already initialized" in res["msg"]


@pytest.mark.slow
def test_late_x64_routes_through_jax_config():
    """Rule 3: x64 is runtime-togglable, so a late x64= goes through
    jax.config.update instead of raising."""
    res = _run_child("""
        import json, jax
        import jax.numpy as jnp
        jax.devices()
        from repro.platform import configure
        report = configure(x64=True)
        dt = str(jnp.zeros(1, jnp.float64).dtype)
        print("RESULT:" + json.dumps({"report": report, "dtype": dt}))
    """, _clean_env({"JAX_PLATFORMS": "cpu"}))
    assert res["report"]["JAX_ENABLE_X64"] == "set"
    assert res["dtype"] == "float64"


@pytest.mark.slow
def test_subprocess_env_round_trip():
    """subprocess_env renders the world the child actually gets."""
    env = platform.subprocess_env(_clean_env(), platform="cpu",
                                  host_devices=8, x64=True, override=True)
    res = _run_child("""
        import json, jax
        import jax.numpy as jnp
        from repro.platform import backend_info
        info = backend_info()
        print("RESULT:" + json.dumps({
            "n": jax.device_count(), "platform": info.platform,
            "key": info.key, "dtype": str(jnp.zeros(3).dtype),
            "peak": info.hardware.peak_flops}))
    """, env)
    assert res["n"] == 8
    assert res["platform"] == "cpu" and res["key"] == "cpu"
    assert res["dtype"] == "float64"        # x64 made it through
    assert res["peak"] == platform.HARDWARE["cpu"].peak_flops


@pytest.mark.slow
def test_configure_from_env_applies_repro_vars():
    """The CI lanes' entry point: REPRO_* -> a real device world."""
    env = _clean_env({"REPRO_PLATFORM": "cpu", "REPRO_HOST_DEVICES": "8"})
    res = _run_child("""
        import json
        from repro.platform import configure_from_env
        report = configure_from_env()
        import jax
        print("RESULT:" + json.dumps(
            {"report": report, "n": jax.device_count(),
             "backend": jax.default_backend()}))
    """, env)
    assert res["n"] == 8
    assert res["backend"] == "cpu"
    assert res["report"]["JAX_PLATFORMS"] == "set"
    assert res["report"]["XLA_FLAGS"] == "set"


# ==========================================================================
# pure pieces (in-process)
# ==========================================================================


def test_desired_env_composition():
    want = platform._desired_env("tpu", 4, None, None)
    # host_devices strips the tpu preset's own count flag, appends ours
    flags = want["XLA_FLAGS"]
    assert flags.count(platform._HOST_COUNT_FLAG) == 1
    assert f"{platform._HOST_COUNT_FLAG}=4" in flags
    assert "--xla_step_marker_location=1" in flags
    assert want["JAX_PLATFORMS"] == "tpu"
    with pytest.raises(ValueError):
        platform._desired_env("abacus", None, None, None)
    with pytest.raises(ValueError):
        platform._desired_env(None, 0, None, None)


def test_configure_from_env_is_noop_without_vars(monkeypatch):
    for v in _JAX_VARS:
        monkeypatch.delenv(v, raising=False)
    assert platform.configure_from_env() is None


def test_backend_key_env_inference(monkeypatch):
    # label-only path: jax uninitialized, key comes from the env
    monkeypatch.setattr(platform, "jax_is_initialized", lambda: False)
    monkeypatch.delenv("JAX_PLATFORMS", raising=False)
    monkeypatch.delenv("REPRO_PLATFORM", raising=False)
    assert platform.backend_key() == "cpu"
    monkeypatch.setenv("REPRO_PLATFORM", "tpu")
    assert platform.backend_key() == "tpu-v5e"
    monkeypatch.setenv("JAX_PLATFORMS", "cpu")   # JAX_PLATFORMS wins
    assert platform.backend_key() == "cpu"


def test_resolve_hardware_and_device_kind_mapping():
    assert platform.resolve_hardware("tpu") is platform.HARDWARE["tpu-v5e"]
    assert platform.resolve_hardware("gpu-h100").peak_flops == 989e12
    spec = platform.HARDWARE["cpu"]
    assert platform.resolve_hardware(spec) is spec
    with pytest.raises(KeyError):
        platform.resolve_hardware("abacus")
    assert platform._key_for("tpu", "TPU v5 lite") == "tpu-v5e"
    assert platform._key_for("gpu", "NVIDIA A100-SXM4-80GB") == "gpu-a100"
    assert platform._key_for("cpu", "whatever") == "cpu"


def test_roofline_reads_hardware_presets():
    from repro.launch import roofline

    t_cpu = roofline.roofline_terms(1e9, 1e6, 0.0, hardware="cpu",
                                    check_backend=False)
    t_tpu = roofline.roofline_terms(1e9, 1e6, 0.0, hardware="tpu-v5e",
                                    check_backend=False)
    assert t_cpu["hardware"] == "cpu" and t_tpu["hardware"] == "tpu-v5e"
    assert t_cpu["compute_s"] > t_tpu["compute_s"]   # cpu peak << tpu peak
    assert t_cpu["dominant"] in ("compute", "memory", "collective")


# ==========================================================================
# TileConfig + resolution precedence
# ==========================================================================


def test_tileconfig_validation_and_algebra():
    for bad in (dict(bb=0), dict(bk=-4), dict(bn=True), dict(bp=2.5)):
        with pytest.raises(ValueError):
            TileConfig(**bad)
    a = TileConfig(bb=64, bk=128)
    b = TileConfig(bk=256, bp=512)
    m = a.merged(b)                         # other's non-None fields win
    assert m == TileConfig(bb=64, bk=256, bp=512)
    assert a.merged(None) is a
    assert m.kwargs("coded_accumulate_batched") == {"bb": 64, "bk": 256,
                                                    "bp": 512}
    assert m.kwargs("batched_masked_gram") == {"bb": 64}  # bk not an axis
    assert m.as_dict() == {"bb": 64, "bk": 256, "bp": 512}


def test_shape_class_buckets():
    assert shape_class(None) == "scalar"
    assert shape_class(1) == "b1"
    assert shape_class(3) == "b1"
    assert shape_class(300) == "b128"
    assert shape_class(1000) == "b512"
    assert shape_class(1024) == "b1024"
    assert shape_class(10**6) == "b4096"


def test_resolve_defaults_match_historical_values():
    # no table for the backend, no explicit config -> exactly the
    # pre-redesign hardcoded tile sizes
    for kernel, cfg in DEFAULT_TILES.items():
        assert resolve(kernel, None, backend="no-such-backend",
                       B=None) == cfg.kwargs(kernel)
    with pytest.raises(KeyError):
        resolve("no_such_kernel", None, backend="cpu")


def test_resolve_precedence_with_table(tmp_path):
    p = tmp_path / "tiles.json"
    p.write_text(json.dumps({"cpu": {"batched_onestep_decode": {
        "b128": {"bb": 300}, "b32": {"bb": 48}}}}))
    # committed table beats defaults at its shape class
    kw = resolve("batched_onestep_decode", None, backend="cpu", B=300,
                 table_path=p)
    assert kw == {"bb": 300, "bk": 256, "bn": 256}
    # explicit TileConfig beats the table
    kw = resolve("batched_onestep_decode", TileConfig(bb=16), backend="cpu",
                 B=300, table_path=p)
    assert kw["bb"] == 16
    # nearest-smaller-bucket fallback: b512 absent -> the b128 pin serves
    assert resolve("batched_onestep_decode", None, backend="cpu", B=600,
                   table_path=p)["bb"] == 300
    # a backend with no table rides the defaults untouched
    assert resolve("batched_onestep_decode", None, backend="tpu-v5e",
                   B=300, table_path=p) == \
        DEFAULT_TILES["batched_onestep_decode"].kwargs(
            "batched_onestep_decode")


def test_legacy_tile_kwargs_warn_and_match():
    rng = np.random.default_rng(1)
    G = (rng.random((32, 32)) < 0.2).astype(np.float32)
    masks = (rng.random((16, 32)) < 0.9).astype(np.float32)
    rhos = np.ones(16, np.float32)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = ops.batched_onestep_decode(G, masks, rhos,
                                            impl="pallas_interpret", bb=4)
    new = ops.batched_onestep_decode(G, masks, rhos,
                                     impl="pallas_interpret",
                                     tiles=TileConfig(bb=4))
    assert np.array_equal(np.asarray(legacy), np.asarray(new))
    with pytest.raises(TypeError, match="unexpected keyword"):
        ops.batched_onestep_decode(G, masks, rhos,
                                   impl="pallas_interpret", bz=4)


def test_engine_tiles_parameter_matches_numpy():
    code = codes.frc(k=32, n=32, s=4)
    rng = np.random.default_rng(2)
    masks = rng.random((24, 32)) < 0.85
    ref = DecodeEngine(code, backend="numpy").decode_batch(masks)
    tiled = DecodeEngine(code, backend="pallas_interpret",
                         tiles=TileConfig(bb=8)).decode_batch(masks)
    np.testing.assert_allclose(np.asarray(tiled.weights),
                               np.asarray(ref.weights),
                               atol=1e-5, rtol=1e-5)


# ==========================================================================
# the committed autotune table's bitwise contract
# ==========================================================================


def test_committed_table_bitwise_matches_defaults():
    """Every committed cpu tile entry must produce bitwise-identical
    outputs to the historical defaults (autotune only touches parallel
    grid axes; this is the acceptance check in test form)."""
    table = load_tile_table().get("cpu", {})
    assert table, "committed tile table is missing its cpu section"
    rng = np.random.default_rng(0)
    k, B, L, P = 64, 300, 32, 256
    G = (rng.random((k, k)) < 0.15).astype(np.float32)
    masks = (rng.random((B, k)) < 0.9).astype(np.float32)
    rhos = (rng.random(B) + 0.5).astype(np.float32)
    msgs = rng.standard_normal((L, P)).astype(np.float32)
    fmasks = (rng.random((B, L)) < 0.9).astype(np.float32)
    scales = (rng.random(B) + 0.5).astype(np.float32)
    grads = rng.standard_normal((k, P)).astype(np.float32)
    wts = rng.standard_normal((B, k)).astype(np.float32)
    gram = (G @ G.T).astype(np.float32)
    calls = {
        "batched_onestep_decode": lambda t: ops.batched_onestep_decode(
            G, masks, rhos, impl="pallas_interpret", tiles=t),
        "fused_decode_apply": lambda t: ops.fused_decode_apply(
            msgs, fmasks, scales, impl="pallas_interpret", tiles=t),
        "coded_accumulate_batched": lambda t: ops.coded_accumulate_batched(
            grads, wts, impl="pallas_interpret", tiles=t),
        "batched_masked_gram": lambda t: ops.batched_masked_gram(
            gram, masks, impl="pallas_interpret", tiles=t),
    }
    checked = 0
    for kernel in sorted(table):
        fn = calls.get(kernel)
        if fn is None:
            continue
        tuned = np.asarray(fn(None))        # defaults + committed table
        # a fully-specified explicit config bypasses the table outright
        default = np.asarray(fn(DEFAULT_TILES[kernel]))
        assert np.array_equal(tuned, default), kernel
        checked += 1
    assert checked >= 2


@pytest.mark.slow
def test_autotune_smoke_writes_loadable_table(tmp_path):
    from repro.launch import autotune

    p = tmp_path / "tiles.json"
    out = autotune.run(kernels=["batched_onestep_decode"], k=32,
                       batches=(32,), top=2, reps=1, table_path=p)
    assert out["backend"] == "cpu"
    assert out["records"] and all(
        r["rejected_bitwise"] == [] or r["best"] for r in out["records"])
    table = json.loads(p.read_text())
    assert set(table) <= {"cpu"}
    # whatever it pinned (possibly nothing) must load and resolve
    kw = resolve("batched_onestep_decode", None, backend="cpu", B=32,
                 table_path=p)
    assert set(kw) == {"bb", "bk", "bn"}
