"""Decoder correctness + agreement with the paper's definitions."""

import numpy as np
import pytest

from repro.core import codes as C
from repro.core import decoding as D
from repro.core import simulate as S


RNG = lambda seed=0: np.random.default_rng(seed)


def test_err_full_identity_is_zero():
    assert D.err(np.eye(10)) == pytest.approx(0.0, abs=1e-12)


def test_err_empty_matrix_is_k():
    assert D.err(np.zeros((7, 0))) == 7.0


def test_err_bounds():
    rng = RNG(0)
    for _ in range(20):
        A = (rng.random((30, 12)) < 0.2).astype(float)
        e = D.err(A)
        assert -1e-9 <= e <= 30 + 1e-9


def test_err1_geq_err():
    """One-step error dominates optimal error (Sec. 2.2)."""
    rng = RNG(1)
    for _ in range(25):
        k = 40
        A = (rng.random((k, 25)) < 0.15).astype(float)
        rho = D.default_rho(k, 25, 6)
        assert D.err1(A, rho) >= D.err(A) - 1e-9


def test_frc_full_recovery_no_stragglers():
    code = C.frc(k=12, n=12, s=3)
    mask = np.ones(12, dtype=bool)
    v, w = D.onestep_decode(code.G, mask, s=3)
    np.testing.assert_allclose(v, np.ones(12), atol=1e-12)
    v2, _ = D.optimal_decode(code.G, mask)
    np.testing.assert_allclose(v2, np.ones(12), atol=1e-9)


def test_frc_exact_recovery_one_survivor_per_block():
    """FRC recovers exactly whenever >= 1 column of each block survives."""
    code = C.frc(k=12, n=12, s=3)
    mask = np.zeros(12, dtype=bool)
    mask[[0, 4, 8, 11]] = True  # one survivor in each of the 4 blocks
    assert D.err(code.G[:, mask]) == pytest.approx(0.0, abs=1e-9)


def test_frc_block_loss_error():
    """Losing all s columns of one block costs exactly s (Sec. 4.1)."""
    s = 3
    code = C.frc(k=12, n=12, s=s)
    mask = np.ones(12, dtype=bool)
    mask[0:3] = False  # kill block 0 entirely
    assert D.err(code.G[:, mask]) == pytest.approx(s, abs=1e-9)


def test_optimal_weights_residual_matches_err():
    rng = RNG(2)
    code = C.bgc(k=60, n=60, s=6, rng=rng)
    mask = S.sample_straggler_mask(60, 20, rng)
    w = D.optimal_weights(code.G, mask)
    assert np.all(w[~mask] == 0)
    v = code.G @ w
    res = float(((v - 1) ** 2).sum())
    assert res == pytest.approx(D.err(code.G[:, mask]), rel=1e-6, abs=1e-8)


def test_onestep_weights_uniform_rho():
    code = C.bgc(k=30, n=30, s=5, rng=RNG(3))
    mask = np.ones(30, dtype=bool)
    mask[:10] = False
    w = D.onestep_weights(code.G, mask, s=5)
    rho = D.default_rho(30, 20, 5)
    assert np.all(w[~mask] == 0)
    np.testing.assert_allclose(w[mask], rho)


class TestAlgorithmicDecoder:
    def test_monotone_decrease_to_err(self):
        """Lemma 12: ||u_t||^2 decreases monotonically and converges to
        err(A); every iterate upper-bounds err(A)."""
        rng = RNG(4)
        code = C.bgc(k=50, n=50, s=8, rng=rng)
        mask = S.sample_straggler_mask(50, 15, rng)
        A = code.G[:, mask]
        curve = D.algorithmic_error_curve(A, iters=2000)
        assert np.all(np.diff(curve) <= 1e-9)
        target = D.err(A)
        assert np.all(curve >= target - 1e-7)
        # geometric convergence rate is (1 - sigma_min^2/nu); near-singular
        # A converges slowly, so allow 1% relative slack at 2000 iters
        assert curve[-1] == pytest.approx(target, rel=1e-2, abs=1e-6)

    def test_weights_reproduce_curve(self):
        rng = RNG(5)
        code = C.bgc(k=40, n=40, s=6, rng=rng)
        mask = S.sample_straggler_mask(40, 10, rng)
        A = code.G[:, mask]
        nu = float(np.linalg.norm(A, 2) ** 2)
        for t in [1, 3, 10]:
            w = D.algorithmic_weights(code.G, mask, iters=t, nu=nu)
            v = code.G @ w
            expected = D.algorithmic_error_curve(A, iters=t, nu=nu)[-1]
            assert float(((v - 1) ** 2).sum()) == pytest.approx(expected, rel=1e-9, abs=1e-10)

    def test_iterate_one_with_paper_nu_is_one_step(self):
        """With nu = r s^2 / k, u_1 equals the one-step residual when G has
        exact column sums s and row sums r s / k (paper Sec. 5.1 remark);
        approximately otherwise — here we verify the exact identity on FRC,
        whose A has exact degree structure when no block is lost."""
        code = C.frc(k=16, n=16, s=4)
        mask = np.ones(16, dtype=bool)
        mask[[0, 5]] = False  # partial block losses only
        A = code.G[:, mask]
        r = int(mask.sum())
        nu = r * 16 / 16  # r s^2 / k with s=4, k=16 -> r*1... keep general
        nu = r * 4**2 / 16
        u1 = D.algorithmic_error_curve(A, iters=1, nu=nu)[1]
        # identity holds only when A's row sums are exactly r*s/k; FRC with
        # partial losses breaks it, so we assert the documented inequality
        assert u1 >= D.err(A) - 1e-9


def test_apply_weights_matches_matrix_form():
    rng = RNG(6)
    n, d = 12, 7
    partials = rng.normal(size=(n, d))
    w = rng.normal(size=n)
    np.testing.assert_allclose(D.apply_weights(partials, w), w @ partials)


def test_decode_weights_dispatch():
    code = C.bgc(k=20, n=20, s=4, rng=RNG(7))
    mask = np.ones(20, dtype=bool)
    mask[:5] = False
    for method in ["onestep", "optimal", "algorithmic", "ignore"]:
        kw = {"iters": 3} if method == "algorithmic" else {}
        w = D.decode_weights(code.G, mask, method=method, **kw)
        assert w.shape == (20,)
        assert np.all(w[~mask] == 0)
