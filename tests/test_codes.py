"""Unit tests for gradient-code constructions."""

import numpy as np
import pytest

from repro.core import codes as C


RNG = lambda seed=0: np.random.default_rng(seed)


class TestFRC:
    def test_block_structure(self):
        code = C.frc(k=12, n=12, s=3)
        G = code.G
        assert G.shape == (12, 12)
        for b in range(4):
            blk = G[b * 3 : (b + 1) * 3, b * 3 : (b + 1) * 3]
            assert np.all(blk == 1)
        assert G.sum() == 12 * 3  # s entries per column

    def test_column_degree_exact(self):
        code = C.frc(k=20, n=20, s=5)
        assert np.all(code.col_degrees == 5)
        assert np.all(code.row_degrees == 5)

    def test_permutation_preserves_multiset(self):
        a = C.frc(k=12, n=12, s=3)
        b = C.frc(k=12, n=12, s=3, rng=RNG(7))
        cols_a = sorted(a.G[:, j].tobytes() for j in range(12))
        cols_b = sorted(b.G[:, j].tobytes() for j in range(12))
        assert cols_a == cols_b

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            C.frc(k=10, n=10, s=3)  # s does not divide k
        with pytest.raises(ValueError):
            C.frc(k=10, n=8, s=2)  # n != k


class TestBGC:
    def test_density(self):
        code = C.bgc(k=2000, n=2000, s=10, rng=RNG(1))
        p_hat = code.G.mean()
        assert abs(p_hat - 10 / 2000) < 0.001

    def test_binary_entries(self):
        code = C.bgc(k=50, n=50, s=5, rng=RNG(2))
        assert set(np.unique(code.G)) <= {0.0, 1.0}

    def test_deterministic_given_seed(self):
        a = C.bgc(k=64, n=64, s=4, rng=RNG(3))
        b = C.bgc(k=64, n=64, s=4, rng=RNG(3))
        assert np.array_equal(a.G, b.G)


class TestRBGC:
    def test_degree_cap(self):
        # Algorithm 3: no column may exceed 2s after regularization.
        for seed in range(5):
            code = C.rbgc(k=400, n=400, s=2, rng=RNG(seed))
            assert code.max_col_degree <= 2 * code.s

    def test_pruned_columns_have_degree_s(self):
        k, s = 300, 2
        raw = (np.random.default_rng(11).random((k, k)) < (s / k)).astype(float)
        code = C.rbgc(k=k, n=k, s=s, rng=RNG(11))
        heavy = raw.sum(axis=0) > 2 * s
        if heavy.any():
            assert np.all(code.G[:, heavy].sum(axis=0) == s)
        # untouched columns identical
        assert np.array_equal(code.G[:, ~heavy], raw[:, ~heavy])


class TestSRegular:
    def test_regularity_and_symmetry(self):
        code = C.sregular(k=100, n=100, s=6, rng=RNG(4))
        G = code.G
        assert np.allclose(G, G.T)
        assert np.all(G.sum(axis=0) == 6)
        assert np.all(np.diag(G) == 0)

    def test_spectral_gap_below_trivial(self):
        code = C.sregular(k=200, n=200, s=8, rng=RNG(5))
        lam = C.spectral_gap(code)
        assert lam < 8  # second eigenvalue strictly below degree
        # random regular graphs are near-Ramanujan: lambda ~ 2 sqrt(s-1)
        assert lam < 2 * np.sqrt(7) * 1.5

    def test_spectral_gap_ragged_bipartite(self):
        """Regression: spectral_gap used to raise 'requires a symmetric
        square G' on any k != n code, so the expander family could not
        be certified at ragged sizes (PR-10 tentpole fix).  Now it
        returns sigma_2 of the biadjacency matrix."""
        for name, k, n in (("expander", 96, 64), ("expander", 48, 72),
                           ("sbm", 60, 40)):
            code = C.make_code(name, k=k, n=n, s=6, seed=0)
            lam = C.spectral_gap(code)
            sig = np.linalg.svd(code.G.astype(float), compute_uv=False)
            assert lam == pytest.approx(float(sig[1]), abs=1e-9)
            assert 0.0 < lam < float(sig[0])  # gap strictly inside
        # biregular expander columns have degree exactly s: sigma_1
        # carries the (s, ns/k) degree structure, sigma_2 ~ 2 sqrt(s-1)
        code = C.make_code("expander", k=96, n=64, s=6, seed=0)
        assert C.spectral_gap(code) < 2 * np.sqrt(5) * 1.6


class TestCyclicAndUncoded:
    def test_cyclic_degrees(self):
        code = C.cyclic_repetition(k=16, n=16, s=3)
        assert np.all(code.col_degrees == 3)
        assert np.all(code.row_degrees == 3)

    def test_uncoded_identity(self):
        code = C.uncoded(k=8)
        assert np.array_equal(code.G, np.eye(8))


def test_registry_roundtrip():
    for name in ["frc", "bgc", "rbgc", "sregular", "cyclic", "uncoded"]:
        code = C.make_code(name, k=20, n=20, s=4, seed=9)
        assert code.k == 20 and code.n == 20


def test_elastic_rebuild():
    code = C.make_code("bgc", k=32, n=32, s=4, seed=0)
    smaller = code.with_workers(24, RNG(1))
    assert smaller.n == 24 and smaller.k == 24 and smaller.s == 4
