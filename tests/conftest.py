"""Test bootstrap.

The property tests use hypothesis.  When the real package is installed
(CI, dev boxes) it is used as-is; on minimal containers we fall back to
the vendored stub in tests/_stubs, which implements just the strategy /
@given surface these tests consume (fixed-seed random sampling, no
shrinking).
"""

import sys
from pathlib import Path

try:  # pragma: no cover - environment probe
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_stubs"))
