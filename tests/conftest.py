"""Test bootstrap.

Two jobs, both of which must run before any test imports jax:

* Apply the REPRO_* device-world configuration (platform / host device
  count / x64) through ``repro.platform.configure_from_env()`` — this
  is how the CI lanes export their worlds (e.g. the multidevice lane
  sets ``REPRO_HOST_DEVICES=8``) without hand-rolled XLA_FLAGS strings.
  Pre-set env (an explicit XLA_FLAGS) still wins verbatim, per the
  precedence rules documented in ``repro.platform``.

* The property tests use hypothesis.  When the real package is
  installed (CI, dev boxes) it is used as-is; on minimal containers we
  fall back to the vendored stub in tests/_stubs, which implements just
  the strategy / @given surface these tests consume (fixed-seed random
  sampling, no shrinking).
"""

import sys
from pathlib import Path

try:  # pragma: no cover - src may be on PYTHONPATH or pip-installed
    from repro.platform import configure_from_env
except ImportError:  # pragma: no cover
    pass
else:
    configure_from_env()

try:  # pragma: no cover - environment probe
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_stubs"))
