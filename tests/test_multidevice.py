"""Multi-device integration: REAL sharded execution (not just lowering)
on 8 host CPU devices in a subprocess (the device world must be
configured before jax imports — see repro.platform — so these run
out-of-process).

Covers: pjit'd coded train step on a (pod=2, data=2, model=2) mesh with
logical-axis shardings + FSDP, grouped-MoE dispatch under a data axis,
and the rwkv6 batch_shard_model rules — the executable counterpart of
the 512-device dry-run.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(body: str, timeout: int = 560) -> dict:
    """Run `body` in a subprocess with 8 host devices; it must print a
    single JSON line starting with RESULT:."""
    prog = textwrap.dedent("""
        from repro.platform import configure
        configure(platform="cpu", host_devices=8)
        import json
        import numpy as np
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import build_model
        from repro.dist.sharding import param_shardings, rules_for, \\
            use_mesh, use_rules
        from repro.launch.mesh import make_debug_mesh
        from repro.optim import OptConfig, adamw_update, init_opt_state
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", prog], cwd=REPO, env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT:")]
    assert line, f"no RESULT in stdout:\n{out.stdout[-2000:]}"
    return json.loads(line[-1][len("RESULT:"):])


def test_sharded_coded_train_step_executes():
    """Coded train step (decode-as-loss-reweighting) actually runs
    sharded on a (pod,data,model) mesh; params update; loss finite;
    a second step with a different straggler mask also runs."""
    res = _run("""
        from repro.core import codes, decoding

        cfg = get_config("starcoder2-7b", smoke=True)
        model = build_model(cfg)
        mesh = make_debug_mesh(data=2, model=2, pod=2)

        n, s = 8, 2
        code = codes.frc(k=n, n=n, s=s)
        rng = np.random.default_rng(0)

        with use_mesh(mesh), use_rules(rules_for(cfg)):
            params = model.init(jax.random.PRNGKey(0))
            p_sh = param_shardings(model.param_axes(), params, mesh,
                                   fsdp=True)
            params = jax.device_put(params, p_sh)
            opt = init_opt_state(params)
            ocfg = OptConfig(lr=1e-3)

            B, S = 8, 32
            bspec = NamedSharding(mesh, P(("pod", "data")))

            def make_batch(step):
                mask = np.ones(n, bool)
                mask[rng.choice(n, 2, replace=False)] = False
                w = decoding.decode_weights(code.G, mask, "onestep")
                lw = (code.G @ w / (n * 1.0)).astype(np.float32)
                return {
                    "tokens": jnp.asarray(
                        rng.integers(0, cfg.vocab, (B, S))),
                    "labels": jnp.asarray(
                        rng.integers(0, cfg.vocab, (B, S))),
                    "loss_weight": jnp.asarray(lw),
                }

            @jax.jit
            def step(params, opt, batch):
                (loss, m), g = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
                params, opt, _ = adamw_update(params, g, opt, ocfg,
                                              jnp.float32(1e-3))
                return params, opt, loss

            p0 = jax.tree_util.tree_leaves(params)[0]
            losses = []
            for t in range(2):
                batch = jax.device_put(
                    make_batch(t),
                    {k: bspec if v.ndim >= 1 else None
                     for k, v in make_batch(t).items()})
                params, opt, loss = step(params, opt, batch)
                losses.append(float(loss))
            p1 = jax.tree_util.tree_leaves(params)[0]
            emb_sh = p_sh["embed"].spec

        print("RESULT:" + json.dumps({
            "losses": losses,
            "params_changed": bool(abs(np.asarray(p1 - p0)).sum() > 0),
            "n_devices": jax.device_count(),
            "embed_spec": [str(x) for x in emb_sh],
        }))
    """)
    assert res["n_devices"] == 8
    assert all(np.isfinite(v) for v in res["losses"])
    assert res["params_changed"]
    assert "vocab" not in res["embed_spec"]  # logical name resolved away


import numpy as np  # noqa: E402  (used in asserts above)

pytestmark = pytest.mark.slow  # subprocess 8-device sharded execution


def test_grouped_moe_sharded_execution():
    """Grouped dispatch executes under a real data axis and matches the
    single-device global-dispatch loss."""
    res = _run("""
        import dataclasses
        cfg = get_config("granite-moe-3b-a800m", smoke=True)
        cfg_g = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped"))
        model = build_model(cfg_g)
        rng = np.random.default_rng(0)
        B, S = 4, 32
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
            "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
        }
        params = model.init(jax.random.PRNGKey(0))
        l_ref = float(model.loss_fn(params, batch)[0])  # unsharded

        mesh = make_debug_mesh(data=4, model=2)
        with use_mesh(mesh), use_rules(rules_for(cfg_g)):
            p_sh = param_shardings(model.param_axes(), params, mesh)
            params_s = jax.device_put(params, p_sh)
            bspec = NamedSharding(mesh, P("data"))
            batch_s = {k: jax.device_put(v, bspec) for k, v in batch.items()}
            loss_s = float(jax.jit(
                lambda p, b: model.loss_fn(p, b)[0])(params_s, batch_s))
        print("RESULT:" + json.dumps({"ref": l_ref, "sharded": loss_s}))
    """)
    assert abs(res["ref"] - res["sharded"]) < 5e-4


def test_rwkv6_batch_shard_model_executes():
    """batch_shard_model rules execute: batch spreads over data AND
    model axes, loss matches the unsharded reference."""
    res = _run("""
        import dataclasses
        cfg = dataclasses.replace(get_config("rwkv6-3b", smoke=True),
                                  batch_shard_model=True)
        model = build_model(cfg)
        rng = np.random.default_rng(1)
        B, S = 8, 32
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
            "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
        }
        params = model.init(jax.random.PRNGKey(0))
        l_ref = float(model.loss_fn(params, batch)[0])

        mesh = make_debug_mesh(data=4, model=2)
        rules = rules_for(cfg)
        with use_mesh(mesh), use_rules(rules):
            p_sh = param_shardings(model.param_axes(), params, mesh)
            params_s = jax.device_put(params, p_sh)
            bspec = NamedSharding(mesh, P(("data", "model")))
            batch_s = {k: jax.device_put(v, bspec) for k, v in batch.items()}
            loss_s = float(jax.jit(
                lambda p, b: model.loss_fn(p, b)[0])(params_s, batch_s))
        print("RESULT:" + json.dumps({
            "ref": l_ref, "sharded": loss_s,
            "batch_rule": str(rules["batch"][0])}))
    """)
    assert abs(res["ref"] - res["sharded"]) < 5e-4
