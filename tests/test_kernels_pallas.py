"""Per-kernel validation: Pallas (interpret=True on CPU; TPU is the
target) vs the pure-jnp oracles in repro.kernels.ref.

Covers: shape sweeps (block-aligned and ragged), dtype sweeps, GQA
grouping, causal/window/softcap variants, carried state, and
Hypothesis property tests on the decoders' coding-theoretic invariants.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float32, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


# ----------------------------- flash attention -------------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,Kv,dh", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 8, 8, 64),     # MHA
    (1, 96, 160, 4, 1, 32),      # MQA, ragged blocks
    (2, 1, 128, 4, 2, 64),       # decode: single query
    (1, 64, 64, 2, 2, 128),      # dh = 128 (MXU lane width)
])
def test_flash_attention_shapes(B, Sq, Sk, H, Kv, dh):
    q, k, v = (_rand((B, Sq, H, dh)), _rand((B, Sk, Kv, dh)),
               _rand((B, Sk, Kv, dh)))
    qo = Sk - Sq if Sq <= Sk else 0
    out = ops.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=True, q_offset=qo, impl="pallas_interpret",
                        bq=64, bk=64)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             causal=True, q_offset=qo)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 32, 0.0), (True, 0, 30.0),
    (False, 0, 0.0), (True, 16, 50.0),
])
def test_flash_attention_masks(causal, window, softcap):
    B, S, H, Kv, dh = 1, 128, 4, 2, 64
    q, k, v = _rand((B, S, H, dh)), _rand((B, S, Kv, dh)), _rand((B, S, Kv, dh))
    out = ops.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal, window=window, softcap=softcap,
                        impl="pallas_interpret", bq=32, bk=32)
    want = ref.attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             causal=causal, window=window, softcap=softcap)
    assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, S, H, Kv, dh = 1, 128, 4, 2, 64
    q = jnp.asarray(_rand((B, S, H, dh))).astype(dtype)
    k = jnp.asarray(_rand((B, S, Kv, dh))).astype(dtype)
    v = jnp.asarray(_rand((B, S, Kv, dh))).astype(dtype)
    out = ops.attention(q, k, v, impl="pallas_interpret", bq=64, bk=64)
    want = ref.attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    atol=tol, rtol=tol)


def test_flash_matches_model_attention_path():
    """The model-level attention() with impl=pallas_interpret must agree
    with its own xla_naive path (the production dry-run path)."""
    from repro.models.layers import attention
    B, S, H, Kv, dh = 2, 128, 4, 2, 64
    q, k, v = (jnp.asarray(_rand((B, S, H, dh))),
               jnp.asarray(_rand((B, S, Kv, dh))),
               jnp.asarray(_rand((B, S, Kv, dh))))
    a = attention(q, k, v, causal=True, impl="pallas_interpret")
    b = attention(q, k, v, causal=True, impl="xla_naive")
    assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ----------------------------- rglru scan ------------------------------------

@pytest.mark.parametrize("B,S,D,chunk,bd", [
    (2, 64, 128, 32, 64),
    (1, 100, 96, 32, 64),        # ragged both dims
    (3, 256, 256, 128, 128),
    (1, 1, 64, 16, 64),          # single step
])
def test_rglru_scan_shapes(B, S, D, chunk, bd):
    u = _rand((B, S, D))
    la = -np.abs(_rand((B, S, D)))
    h0 = _rand((B, D))
    out = ops.rglru_scan(jnp.asarray(u), jnp.asarray(la), jnp.asarray(h0),
                         impl="pallas_interpret", chunk=chunk, bd=bd)
    want = ref.rglru_scan_ref(jnp.asarray(u), jnp.asarray(la), jnp.asarray(h0))
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_rglru_matches_associative_scan():
    """Kernel vs the production associative-scan path in models.rglru."""
    from repro.models.rglru import rglru_scan_ref as assoc_ref
    B, S, D = 2, 96, 64
    u = jnp.asarray(_rand((B, S, D)))
    la = jnp.asarray(-np.abs(_rand((B, S, D))))
    h0 = jnp.asarray(_rand((B, D)))
    out = ops.rglru_scan(u, la, h0, impl="pallas_interpret", chunk=32, bd=64)
    want = assoc_ref(u, la, h0=h0)
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


# ----------------------------- rwkv6 wkv -------------------------------------

def _wkv_inputs(B, T, H, dh):
    r = _rand((B, T, H, dh), scale=0.5)
    k = _rand((B, T, H, dh), scale=0.5)
    v = _rand((B, T, H, dh), scale=0.5)
    wlog = np.clip(RNG.standard_normal((B, T, H, dh)), -12, 1.609)
    w = np.exp(-np.exp(wlog)).astype(np.float32)
    u = _rand((H, dh), scale=0.3)
    return tuple(map(jnp.asarray, (r, k, v, w, u)))


@pytest.mark.parametrize("B,T,H,dh,chunk", [
    (2, 64, 2, 32, 16),
    (1, 48, 3, 64, 16),
    (1, 100, 2, 32, 32),         # ragged chunk
    (2, 32, 4, 128, 32),         # dh = 128
])
def test_wkv_shapes(B, T, H, dh, chunk):
    r, k, v, w, u = _wkv_inputs(B, T, H, dh)
    s0 = jnp.asarray(_rand((B, H, dh, dh), scale=0.3))
    o, s = ops.rwkv6_wkv(r, k, v, w, u, s0, impl="pallas_interpret",
                         chunk=chunk)
    o_ref, s_ref = ref.wkv_ref(r, k, v, w, u, s0)
    assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4, rtol=5e-4)
    assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4, rtol=5e-4)


def test_wkv_matches_chunked_model_impl():
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, dh = 1, 64, 2, 32
    r, k, v, w, u = _wkv_inputs(B, T, H, dh)
    o_k, s_k = ops.rwkv6_wkv(r, k, v, w, u, impl="pallas_interpret", chunk=16)
    o_c, s_c = wkv_chunked(r, k, v, w, u, chunk=16)
    assert_allclose(np.asarray(o_k), np.asarray(o_c), atol=5e-4, rtol=5e-4)
    assert_allclose(np.asarray(s_k), np.asarray(s_c), atol=5e-4, rtol=5e-4)


# ----------------------------- coded kernels ---------------------------------

@pytest.mark.parametrize("k,P,bp", [(8, 1000, 256), (32, 4096, 2048),
                                    (5, 17, 8), (64, 8192, 1024)])
def test_coded_accumulate(k, P, bp):
    g, w = _rand((k, P)), _rand((k,))
    out = ops.coded_accumulate(jnp.asarray(g), jnp.asarray(w),
                               impl="pallas_interpret", bp=bp)
    want = ref.coded_accumulate_ref(jnp.asarray(g), jnp.asarray(w))
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("k,n,s", [(100, 100, 10), (257, 123, 7),
                                   (512, 512, 18)])
def test_onestep_decode_kernel(k, n, s):
    G = (RNG.random((k, n)) < s / k).astype(np.float32)
    mask = RNG.random(n) < 0.7
    r = max(int(mask.sum()), 1)
    rho = k / (r * s)
    out = ops.onestep_decode(jnp.asarray(G), jnp.asarray(mask), rho,
                             impl="pallas_interpret", bk=128, bn=128)
    want = ref.onestep_decode_ref(jnp.asarray(G), jnp.asarray(mask), rho)
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_onestep_kernel_matches_core_decoder():
    """Kernel output == numpy core decoder (the paper's Algorithm 1)."""
    from repro.core import codes, decoding
    code = codes.bgc(k=96, n=96, s=8, rng=np.random.default_rng(5))
    mask = np.random.default_rng(6).random(96) < 0.75
    r = int(mask.sum())
    rho = decoding.default_rho(96, r, 8)
    v_np, _ = decoding.onestep_decode(code.G, mask, rho)
    v_k = ops.onestep_decode(jnp.asarray(code.G), jnp.asarray(mask), rho,
                             impl="pallas_interpret")
    assert_allclose(np.asarray(v_k), v_np, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("k,n,s,iters", [(100, 100, 10, 4), (130, 70, 5, 8)])
def test_algorithmic_decode_kernel(k, n, s, iters):
    G = (RNG.random((k, n)) < s / k).astype(np.float32)
    mask = RNG.random(n) < 0.7
    A = G[:, mask]
    nu = float(np.linalg.norm(A, 2) ** 2) * 1.01
    out = ops.algorithmic_decode(jnp.asarray(G), jnp.asarray(mask), nu, iters,
                                 impl="pallas_interpret", bk=64, bn=64)
    want = ref.algorithmic_decode_ref(jnp.asarray(A), nu, iters)
    assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-4)


# --------------------------- property tests ----------------------------------

@settings(max_examples=15, deadline=None)
@given(k=st.integers(16, 80), n=st.integers(16, 80),
       s=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_property_onestep_full_mask_frc_exact(k, n, s, seed):
    """FRC + no stragglers + rho=k/(rs): one-step decode is EXACT (the
    paper's rho calibration, Sec. 2)."""
    from repro.core import codes
    k = (k // s) * s
    if k < 2 * s:
        k = 2 * s
    code = codes.frc(k=k, n=k, s=s)
    mask = np.ones(k, bool)
    rho = k / (k * s)
    v = ops.onestep_decode(jnp.asarray(code.G), jnp.asarray(mask), rho,
                           impl="pallas_interpret", bk=32, bn=32)
    assert_allclose(np.asarray(v), np.ones(k), atol=1e-5)


@pytest.mark.slow  # ~20s: interpret-mode kernel per hypothesis example
@settings(max_examples=15, deadline=None)
@given(k=st.integers(20, 100), s=st.integers(2, 10),
       frac=st.floats(0.3, 1.0), seed=st.integers(0, 10_000))
def test_property_algorithmic_error_monotone(k, s, frac, seed):
    """Lemma 12: ||u_t||^2 is non-increasing in t and >= err(A)."""
    rng = np.random.default_rng(seed)
    G = (rng.random((k, k)) < s / k).astype(np.float32)
    mask = rng.random(k) < frac
    A = G[:, mask]
    if A.shape[1] == 0:
        return
    nu = float(np.linalg.norm(A, 2) ** 2) * 1.05 + 1e-6
    errs = []
    for t in (1, 2, 4):
        u = ops.algorithmic_decode(jnp.asarray(G), jnp.asarray(mask), nu, t,
                                   impl="pallas_interpret", bk=32, bn=32)
        errs.append(float(jnp.sum(u * u)))
    assert errs[0] >= errs[1] - 1e-4 >= errs[2] - 2e-4
    err_opt = float(np.sum((A @ np.linalg.pinv(A) @ np.ones(k) - 1) ** 2))
    assert errs[-1] >= err_opt - 1e-3


@settings(max_examples=10, deadline=None)
@given(k=st.integers(4, 32), p=st.integers(10, 300), seed=st.integers(0, 9999))
def test_property_accumulate_linear(k, p, seed):
    """coded_accumulate is linear in the weights (decode-as-reweighting
    identity, docs/architecture.md §2.1)."""
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((k, p)).astype(np.float32)
    w1 = rng.standard_normal(k).astype(np.float32)
    w2 = rng.standard_normal(k).astype(np.float32)
    f = lambda w: np.asarray(ops.coded_accumulate(
        jnp.asarray(g), jnp.asarray(w), impl="pallas_interpret", bp=64))
    assert_allclose(f(w1) + f(w2), f(w1 + w2), atol=1e-3, rtol=1e-3)


# ------------------- model-level kernel-swap parity ---------------------------

def _tiny_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
    }


@pytest.mark.parametrize("arch,field", [
    ("starcoder2-7b", "attn_impl"),        # dense attention -> flash kernel
    ("recurrentgemma-9b", "seq_impl"),     # RG-LRU -> rglru kernel
    ("rwkv6-3b", "seq_impl"),              # WKV -> chunked kernel
])
def test_model_forward_pallas_parity(arch, field):
    """Swapping the Pallas kernel into the full model graph preserves the
    loss (reduced config, interpret mode)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _tiny_batch(cfg)
    loss_ref, _ = model.loss_fn(params, batch)

    cfg_k = dataclasses.replace(cfg, **{field: "pallas_interpret"})
    model_k = build_model(cfg_k)
    loss_k, _ = model_k.loss_fn(params, batch)
    assert_allclose(float(loss_k), float(loss_ref), atol=5e-4, rtol=5e-4)
