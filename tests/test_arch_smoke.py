"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct lowering)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as CFG
from repro.models import build_model

pytestmark = pytest.mark.slow  # e2e forward/decode across all archs

ARCHS = CFG.list_archs()


def _smoke_batch(model, rng, B=2, S=32):
    cfg = model.cfg
    i32 = jnp.int32
    rngs = np.random.default_rng(0)
    if cfg.family == "encdec":
        Sd = max(S // 4, 8)
        return {
            "frames": jnp.asarray(rngs.normal(size=(B, S, cfg.d_model)),
                                  jnp.float32),
            "tokens": jnp.asarray(rngs.integers(0, cfg.vocab, (B, Sd)), i32),
            "labels": jnp.asarray(rngs.integers(0, cfg.vocab, (B, Sd)), i32),
            "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
        }
    if cfg.frontend == "patches":
        P = cfg.frontend_tokens
        return {
            "patches": jnp.asarray(rngs.normal(size=(B, P, cfg.d_model)),
                                   jnp.float32),
            "tokens": jnp.asarray(rngs.integers(0, cfg.vocab, (B, S - P)), i32),
            "labels": jnp.asarray(rngs.integers(0, cfg.vocab, (B, S - P)), i32),
            "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
        }
    return {
        "tokens": jnp.asarray(rngs.integers(0, cfg.vocab, (B, S)), i32),
        "labels": jnp.asarray(rngs.integers(0, cfg.vocab, (B, S)), i32),
        "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad_step(arch):
    cfg = CFG.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(model, 0)

    @jax.jit
    def step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    # plausible init CE: close to log(vocab)
    assert float(metrics["mean_ce"]) < np.log(cfg.padded_vocab) + 2.0
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32)**2)
                               for g in jax.tree_util.tree_leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = CFG.get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _smoke_batch(model, 0, B=B, S=S)
    batch.pop("labels")
    batch.pop("loss_weight")
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=32))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step = jax.jit(model.decode_step)
    for _ in range(3):
        logits, caches = step(params, tok, caches)
        assert logits.shape == (B, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward's logits
    (cache correctness)."""
    cfg = CFG.get_config(arch, smoke=True)
    if cfg.family in ("vlm",):
        pytest.skip("prefix-embedding decode parity covered by lm tests")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S = 1, 12
    rngs = np.random.default_rng(3)
    toks = jnp.asarray(rngs.integers(0, cfg.vocab, (B, S)), jnp.int32)

    if cfg.family == "encdec":
        frames = jnp.asarray(rngs.normal(size=(B, 16, cfg.d_model)), jnp.float32)
        from repro.models.encdec import _cast, _encode, _make_cross_caches, _decode_tokens
        p = _cast(params, cfg)
        enc = _encode(p, cfg, frames)
        cross = _make_cross_caches(p, cfg, enc)
        full_logits, _ = _decode_tokens(p, cfg, toks, jnp.arange(S), cross)
        # prefill on the first half, decode the rest token by token
        half = S // 2
        logits, caches = model.prefill(params, {"frames": frames,
                                                "tokens": toks[:, :half]},
                                       cache_len=S)
    else:
        from repro.models import lm as LM
        full_logits, _ = LM.lm_forward(params, cfg, {"tokens": toks})
        half = S // 2
        logits, caches = model.prefill(params, {"tokens": toks[:, :half]},
                                       cache_len=S)

    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits[:, half - 1], np.float32),
        rtol=2e-2, atol=2e-2)
    for t in range(half, S - 1):
        logits, caches = model.decode_step(params, toks[:, t:t + 1], caches)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step t={t} diverges from forward")


def test_param_counts_match_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "internvl2-76b": (60e9, 90e9),
        "dbrx-132b": (110e9, 150e9),
        "command-r-plus-104b": (90e9, 115e9),
        "qwen1.5-32b": (28e9, 36e9),
        "starcoder2-7b": (6e9, 9e9),
        "minicpm-2b": (2e9, 4e9),
        "rwkv6-3b": (2.5e9, 4.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "recurrentgemma-9b": (7e9, 11e9),
        "granite-moe-3b-a800m": (2e9, 4.5e9),
    }
    from repro.models import build_model
    for arch, (lo, hi) in expect.items():
        cfg = CFG.get_config(arch)
        n = build_model(cfg).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"
