"""Property tests for the straggler layer (ISSUE 2 satellite).

Invariants:
  * every model is deterministic in (seed, step) — the SPMD
    no-communication contract — for masks AND latencies;
  * DeadlineStragglers.sample is literally `latencies <= deadline`;
  * sample_straggler_masks puts exactly num_stragglers in every row and
    matches the scalar sample_straggler_mask distributionally (uniform
    marginals over positions).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.simulate import sample_straggler_mask, sample_straggler_masks
from repro.runtime.straggler import (BimodalStragglers, ClusteredStragglers,
                                     CorrelatedStragglers,
                                     DeadlineStragglers,
                                     FixedFractionStragglers, IIDStragglers,
                                     NoStragglers, StragglerModel)

MODEL_BUILDERS = {
    "none": lambda seed: NoStragglers(),
    "iid": lambda seed: IIDStragglers(delta=0.3, seed=seed),
    "fixed": lambda seed: FixedFractionStragglers(delta=0.25, seed=seed),
    "deadline": lambda seed: DeadlineStragglers(seed=seed, tail_scale=0.4),
    "correlated": lambda seed: CorrelatedStragglers(pod_size=4, p_pod=0.1,
                                                    seed=seed),
    "bimodal": lambda seed: BimodalStragglers(slow_fraction=0.2, seed=seed),
    "clustered": lambda seed: ClusteredStragglers(blocks=4, p_block=0.3,
                                                  seed=seed),
}


# ----------------------- determinism in (seed, step) ------------------------

@settings(max_examples=40, deadline=None)
@given(name=st.sampled_from(sorted(MODEL_BUILDERS)),
       seed=st.integers(0, 2**31 - 1),
       step=st.integers(0, 10_000),
       n=st.integers(1, 96))
def test_models_deterministic_in_seed_step(name, seed, step, n):
    """Two independently constructed models with the same seed agree on
    every (step, n) — no hidden per-process or call-order state."""
    a = MODEL_BUILDERS[name](seed)
    b = MODEL_BUILDERS[name](seed)
    ma = a.sample(step, n)
    # interleave extra draws to catch stateful RNG misuse
    b.sample(step + 1, n)
    b.latencies(step + 3, n)
    mb = b.sample(step, n)
    assert ma.dtype == np.bool_ and ma.shape == (n,)
    np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(a.latencies(step, n),
                                  b.latencies(step, n))


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(["deadline", "bimodal"]),
       seed=st.integers(0, 2**31 - 1),
       n=st.integers(4, 64))
def test_different_steps_give_different_draws(name, seed, n):
    """Sanity: the (seed, step) keying actually varies with step."""
    m = MODEL_BUILDERS[name](seed)
    lat = np.stack([m.latencies(t, n) for t in range(8)])
    assert not all(np.array_equal(lat[0], lat[t]) for t in range(1, 8))
    m2 = MODEL_BUILDERS["iid"](seed)
    masks = np.stack([m2.sample(t, 64) for t in range(8)])
    assert not all(np.array_equal(masks[0], masks[t]) for t in range(1, 8))


# ----------------------- deadline model consistency -------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 1000),
       n=st.integers(1, 128), deadline=st.floats(0.5, 4.0),
       tail=st.floats(0.01, 1.0))
def test_deadline_sample_equals_latency_threshold(seed, step, n, deadline,
                                                  tail):
    m = DeadlineStragglers(deadline=deadline, tail_scale=tail, seed=seed)
    np.testing.assert_array_equal(m.sample(step, n),
                                  m.latencies(step, n) <= deadline)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 64))
def test_bimodal_slow_set_is_persistent(seed, n):
    m = BimodalStragglers(slow_fraction=0.25, seed=seed)
    slow = m.slow_nodes(n)
    assert slow.sum() == int(round(0.25 * n))
    np.testing.assert_array_equal(slow, m.slow_nodes(n))
    # slow nodes are slower on every step (jitter is small vs the gap)
    for step in (0, 3):
        lat = m.latencies(step, n)
        if slow.any() and (~slow).any():
            assert lat[slow].min() > lat[~slow].max()
    np.testing.assert_array_equal(m.sample(5, n), m.latencies(5, n) <= 1.5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 64),
       step=st.integers(0, 500))
def test_clustered_blocks_fail_together(seed, n, step):
    """ClusteredStragglers: within one step, every worker of a block
    shares the block's fast/slow mode, the block partition matches the
    SBM code's block_ids rule, and the slow set is constant across an
    episode."""
    from repro.core.codes import block_ids

    m = ClusteredStragglers(blocks=4, p_block=0.3, episode=8, seed=seed)
    member = block_ids(n, 4)
    lat = m.latencies(step, n)
    slow_blocks = m.slow_blocks(step)
    # jitter is multiplicative and small: mode = latency rounded to the
    # nearer of (fast, slow)
    is_slow = np.abs(lat - m.slow) < np.abs(lat - m.fast)
    np.testing.assert_array_equal(is_slow, slow_blocks[member])
    # episode persistence: steps in the same epoch share slow blocks
    epoch_start = (step // 8) * 8
    np.testing.assert_array_equal(m.slow_blocks(epoch_start), slow_blocks)
    np.testing.assert_array_equal(m.sample(step, n),
                                  lat <= m.deadline)


# ----------------------- batched mask sampling ------------------------------

@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 128), trials=st.integers(1, 64),
       frac=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_sample_straggler_masks_exact_count_per_row(n, trials, frac, seed):
    num = int(frac * n)
    rng = np.random.default_rng(seed)
    masks = sample_straggler_masks(n, num, trials, rng)
    assert masks.shape == (trials, n) and masks.dtype == np.bool_
    np.testing.assert_array_equal((~masks).sum(axis=1),
                                  np.full(trials, num))


def test_sample_straggler_masks_matches_scalar_distribution():
    """Batched and scalar samplers draw from the same distribution:
    per-position straggle frequency is uniform (= num/n) for both, and
    the two empirical marginals agree within Monte-Carlo error."""
    n, num, trials = 20, 5, 8000
    batched = sample_straggler_masks(n, num, trials,
                                     np.random.default_rng(0))
    rng = np.random.default_rng(1)
    scalar = np.stack([sample_straggler_mask(n, num, rng)
                       for _ in range(trials)])
    p = num / n
    freq_b = (~batched).mean(axis=0)
    freq_s = (~scalar).mean(axis=0)
    # 4-sigma band for a Bernoulli(p) mean over `trials` draws
    band = 4 * np.sqrt(p * (1 - p) / trials)
    np.testing.assert_allclose(freq_b, p, atol=band)
    np.testing.assert_allclose(freq_s, p, atol=band)
    np.testing.assert_allclose(freq_b, freq_s, atol=2 * band)
    # pairwise exchangeability spot-check: P(i and j both straggle)
    pair = num * (num - 1) / (n * (n - 1))
    got_pair = ((~batched[:, 0]) & (~batched[:, 1])).mean()
    assert abs(got_pair - pair) <= 4 * np.sqrt(pair * (1 - pair) / trials)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_sample_straggler_masks_edge_counts(n, seed):
    rng = np.random.default_rng(seed)
    assert sample_straggler_masks(n, 0, 3, rng).all()
    assert not sample_straggler_masks(n, n, 3, rng).any()


def test_base_model_latency_contract():
    """Mask-only models inherit unit latencies (the lift point for
    sim.traces.trace_from_model)."""
    assert np.array_equal(StragglerModel().latencies(7, 5), np.ones(5))
    assert np.array_equal(NoStragglers().latencies(7, 5), np.ones(5))
