"""Sec-Perf feature correctness: grouped MoE dispatch, chunked CE,
bf16 norm I/O, bf16 param storage, per-arch sharding-rule overrides and
FSDP param shardings (EXPERIMENTS.md Sec. 4)."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.configs import get_config
from repro.dist.sharding import DEFAULT_RULES, logical_to_pspec, \
    param_pspec, rules_for, use_rules
from repro.launch import perf as PERF
from repro.models import build_model

pytestmark = pytest.mark.slow  # model-level e2e: full forwards + grads


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
        "loss_weight": jnp.full((B,), 1.0 / B, jnp.float32),
    }


# --------------------- grouped MoE dispatch ----------------------------------

@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "dbrx-132b"])
def test_grouped_dispatch_matches_global(arch):
    """At smoke capacity (no drops) grouped == global dispatch exactly."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l_global, m_global = model.loss_fn(params, batch)
    cfg_g = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped"))
    l_grouped, m_grouped = build_model(cfg_g).loss_fn(params, batch)
    assert_allclose(float(l_grouped), float(l_global), rtol=5e-5, atol=5e-5)
    assert_allclose(float(m_grouped["aux_loss"]),
                    float(m_global["aux_loss"]), rtol=5e-5, atol=5e-5)


def test_grouped_dispatch_grads_flow():
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="grouped"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    g = jax.grad(lambda p: model.loss_fn(p, _batch(cfg))[0])(params)
    norms = [float(jnp.sum(jnp.abs(x)))
             for x in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


# --------------------- chunked CE / norm io / bf16 params --------------------

def test_chunked_ce_matches_naive():
    cfg = get_config("starcoder2-7b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    l0, _ = model.loss_fn(params, batch)
    for chunk in (8, 16, 32):  # incl. chunk == S
        lc, _ = build_model(
            dataclasses.replace(cfg, loss_chunk=chunk)).loss_fn(params, batch)
        assert_allclose(float(lc), float(l0), rtol=1e-5, atol=1e-5)


def test_chunked_ce_grad_matches_naive():
    cfg = get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = _batch(cfg)
    g0 = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    mc = build_model(dataclasses.replace(cfg, loss_chunk=8))
    g1 = jax.grad(lambda p: mc.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_norm_io_bf16_close():
    cfg = get_config("qwen1.5-32b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(cfg)
    l0, _ = model.loss_fn(params, batch)
    l1, _ = build_model(
        dataclasses.replace(cfg, norm_io="bf16")).loss_fn(params, batch)
    # smoke runs fp32 compute; the io path change must be numerically tiny
    assert abs(float(l1) - float(l0)) < 5e-3


def test_bf16_param_storage():
    cfg = dataclasses.replace(get_config("minicpm-2b", smoke=True),
                              param_dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    mats = [p for p in jax.tree_util.tree_leaves(params) if p.ndim >= 2]
    vecs = [p for p in jax.tree_util.tree_leaves(params) if p.ndim < 2]
    assert all(p.dtype == jnp.bfloat16 for p in mats)
    assert all(p.dtype in (jnp.float32, jnp.int32) for p in vecs)
    loss, _ = model.loss_fn(params, _batch(cfg))
    assert np.isfinite(float(loss))


# --------------------- sharding rules / FSDP ----------------------------------

class _FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape, dtype=object)


def test_rules_for_batch_shard_model():
    cfg = PERF.optimize(get_config("rwkv6-3b"))
    assert cfg.batch_shard_model
    rules = rules_for(cfg)
    assert rules["batch"][0] == ("pod", "data", "model")
    # default rules untouched for other archs
    assert rules_for(get_config("qwen1.5-32b")) is DEFAULT_RULES


def test_batch_rule_divisibility_fallback():
    """On the pod2 mesh 256 % 512 != 0 -> falls back to ('data','model')."""
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    cfg = PERF.optimize(get_config("rwkv6-3b"))
    with use_rules(rules_for(cfg)):
        spec = logical_to_pspec(("batch", None, None), (256, 4096, 2560),
                                mesh=mesh)
    assert spec[0] == ("data", "model")
    # single-pod: ('data','model') fits directly
    mesh1 = _FakeMesh((16, 16), ("data", "model"))
    with use_rules(rules_for(cfg)):
        spec1 = logical_to_pspec(("batch", None, None), (256, 4096, 2560),
                                 mesh=mesh1)
    assert spec1[0] == ("data", "model")


def test_fsdp_param_shardings_prefers_non_layers_dim():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    axes = ("layers", None, "mlp")
    shape = (64, 12288, 33792)
    spec = param_pspec(axes, shape, mesh, fsdp=True)
    assert spec[1] == "data"      # d_model dim, not the layers dim
    assert spec[2] == "model"
    spec0 = param_pspec(axes, shape, mesh, fsdp=False)
    assert spec0[1] is None


def test_perf_optimize_is_identity_for_unlisted():
    cfg = get_config("starcoder2-7b")
    assert PERF.optimize(cfg) is cfg
    assert PERF.microbatches_for("starcoder2-7b", "train_4k", True) == 1
    assert PERF.microbatches_for("command-r-plus-104b", "train_4k", True) == 8
    assert PERF.microbatches_for("command-r-plus-104b", "train_4k", False) == 1


def test_padded_ep_experts_exact():
    """pad_experts_to (Sec-Perf granite iter-2): dummy experts are
    zero-routed — copying unpadded weights into the padded tree gives the
    identical loss, and grouped==global under padding."""
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l0, _ = model.loss_fn(params, batch)

    cfg_p = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, pad_experts_to=6, dispatch="grouped"))
    model_p = build_model(cfg_p)
    params_p = model_p.init(jax.random.PRNGKey(0))

    def pad_tree(a, b):
        def one(x, y):
            if x.shape == y.shape:
                return x
            out = jnp.zeros_like(y)
            return out.at[tuple(slice(0, s) for s in x.shape)].set(x)
        return jax.tree_util.tree_map(one, a, b)

    lps, _ = model_p.loss_fn(pad_tree(params, params_p), batch)
    assert_allclose(float(lps), float(l0), rtol=2e-5, atol=2e-5)

    cfg_pg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, pad_experts_to=6, dispatch="global"))
    lpg, _ = build_model(cfg_pg).loss_fn(params_p, batch)
    lp, _ = model_p.loss_fn(params_p, batch)
    assert_allclose(float(lp), float(lpg), rtol=2e-5, atol=2e-5)
