"""Property tests for the theory-certification layer (PR 10).

Runs under real hypothesis when installed, else the vendored stub in
tests/_stubs (deterministic per-test seeds, no shrinking — see
tests/conftest.py).  Sizes are kept small: every example computes an
SVD or an eigendecomposition.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import codes as C
from repro.core import registry
from repro.core import theory as T
from repro.core.certify import adversarial_err1_bound, certify

EXAMPLES = settings(max_examples=40)


# --------------------------------------------------------------------------
# fundamental lower bound: monotonicity + normalization
# --------------------------------------------------------------------------


class TestFundamentalBoundProperties:
    @EXAMPLES
    @given(k=st.integers(4, 64), s=st.integers(1, 12), r=st.integers(0, 64))
    def test_normalized_to_unit_interval(self, k, s, r):
        r = min(r, k)
        s = min(s, k)
        lb = T.fundamental_err_lower_bound(k, s, r)
        assert 0.0 <= lb / k <= 1.0

    @EXAMPLES
    @given(k=st.integers(4, 64), s=st.integers(1, 11), r=st.integers(1, 64))
    def test_non_increasing_in_s(self, k, s, r):
        r = min(r, k)
        s = min(s, k - 1)
        assert (T.fundamental_err_lower_bound(k, s + 1, r)
                <= T.fundamental_err_lower_bound(k, s, r) + 1e-12)

    @EXAMPLES
    @given(k=st.integers(4, 64), s=st.integers(1, 12), r=st.integers(1, 63))
    def test_non_increasing_in_survivors(self, k, s, r):
        # NOTE on conventions: this repo's r counts SURVIVORS, so the
        # bound is non-increasing in r; papers whose r counts stragglers
        # state the same monotonicity as "non-decreasing in r"
        r = min(r, k - 1)
        s = min(s, k)
        assert (T.fundamental_err_lower_bound(k, s, r + 1)
                <= T.fundamental_err_lower_bound(k, s, r) + 1e-12)

    @EXAMPLES
    @given(k=st.integers(4, 64), s=st.integers(1, 12),
           delta=st.floats(0.0, 1.0))
    def test_load_form_unit_interval_and_monotone_in_delta(self, k, s,
                                                           delta):
        s = min(s, k)
        lb = T.fundamental_err_lower_bound_load(k, s, delta)
        assert 0.0 <= lb / k <= 1.0
        d2 = min(1.0, delta + 0.1)
        assert (T.fundamental_err_lower_bound_load(k, s, d2)
                >= lb - 1e-12)


# --------------------------------------------------------------------------
# spectral certificates
# --------------------------------------------------------------------------


class TestCertificateProperties:
    @EXAMPLES
    @given(k=st.integers(8, 48), s=st.integers(2, 6),
           delta=st.floats(0.0, 0.9))
    def test_bound_monotone_in_delta_and_s(self, k, s, delta):
        lam = 2.0 * math.sqrt(s)
        b = adversarial_err1_bound(k, k, s, delta, lam)
        assert b >= 0.0
        assert (adversarial_err1_bound(k, k, s, min(delta + 0.05, 0.9), lam)
                >= b - 1e-12)
        assert adversarial_err1_bound(k, k, s + 1, delta, lam) <= b + 1e-12

    @EXAMPLES
    @given(k=st.integers(8, 40), mult=st.integers(1, 3),
           s=st.integers(2, 5), seed=st.integers(0, 10**6))
    def test_err_frac_bound_normalized(self, k, mult, s, seed):
        n = k * mult
        code = registry.make("expander", k=k, n=n, s=min(s, k - 1),
                             seed=seed)
        cert = certify(code)
        for delta in (0.0, 0.2, 0.5):
            assert 0.0 <= cert.err_frac_bound(delta) <= 1.0

    @EXAMPLES
    @given(k=st.integers(6, 32), s=st.integers(2, 5),
           seed=st.integers(0, 10**6))
    def test_bipartite_gap_agrees_with_symmetric_square(self, k, s, seed):
        """sigma_2(G) == second-largest singular value read off the dense
        symmetric square [[0, G], [G^T, 0]]: its |eigenvalues| are each
        sigma_i twice (plus |k - n| zeros), so the 3rd largest is
        sigma_2 — the bipartite spectral_gap must match it."""
        n = max(4, k - (k % 2) - 2)  # ragged: n != k
        code = registry.make("expander", k=k, n=n, s=min(s, k - 1),
                             seed=seed)
        gap = C.spectral_gap(code)
        G = code.G.astype(np.float64)
        B = np.block([[np.zeros((k, k)), G],
                      [G.T, np.zeros((n, n))]])
        ev = np.sort(np.abs(np.linalg.eigvalsh(B)))[::-1]
        assert gap == pytest.approx(float(ev[2]), abs=1e-8)

    @EXAMPLES
    @given(k=st.integers(6, 32), s=st.integers(2, 5),
           seed=st.integers(0, 10**6))
    def test_square_symmetric_path_equals_svd_path(self, k, s, seed):
        """For symmetric nonnegative G the legacy eig formula
        max(|lambda_2|, |lambda_k|) IS sigma_2 — the two spectral_gap
        branches agree on sregular codes."""
        if (k * s) % 2:
            k += 1
        s = min(s, k - 1)
        code = registry.make("sregular", k=k, n=k, s=s, seed=seed)
        gap = C.spectral_gap(code)
        sig = np.linalg.svd(code.G.astype(np.float64), compute_uv=False)
        assert gap == pytest.approx(float(sig[1]), abs=1e-8)


# --------------------------------------------------------------------------
# legal_s floor consistency (registry + fundamental limit)
# --------------------------------------------------------------------------


class TestLegalSFloor:
    @EXAMPLES
    @given(family=st.sampled_from(("bgc", "expander", "sregular", "frc")),
           k=st.integers(16, 64), delta=st.floats(0.1, 0.5),
           budget=st.floats(0.01, 0.2))
    def test_make_succeeds_at_floor_and_raises_below(self, family, k,
                                                     delta, budget):
        fam = registry.get(family)
        try:
            floor = fam.s_floor(k, k, delta=delta, error_budget=budget)
        except ValueError:
            return  # budget infeasible at every legal s: nothing to check
        # at the floor: construction succeeds under the budget contract
        code = fam.make(k, k, floor, seed=0, delta=delta,
                        error_budget=budget)
        assert code.s >= 1
        # below the floor: every legal rung must raise, actionably
        below = [x for x in fam.legal_s(k, k, hi=floor - 1)]
        for s_bad in below[-2:]:
            with pytest.raises(ValueError, match="fundamental-limit floor"):
                fam.make(k, k, s_bad, seed=0, delta=delta,
                         error_budget=budget)

    @EXAMPLES
    @given(k=st.integers(16, 64), delta=st.floats(0.1, 0.5),
           budget=st.floats(0.01, 0.2))
    def test_floor_is_minimal(self, k, delta, budget):
        fam = registry.get("bgc")
        try:
            floor = fam.s_floor(k, k, delta=delta, error_budget=budget)
        except ValueError:
            return
        feasible = fam.legal_s(k, k, delta=delta, error_budget=budget)
        assert feasible and feasible[0] == floor
        # nothing below the floor is feasible
        assert all(s >= floor for s in feasible)

    def test_infeasible_budget_raises_actionably(self):
        fam = registry.get("bgc")
        with pytest.raises(ValueError, match="raise the error budget"):
            fam.s_floor(32, 32, delta=1.0, error_budget=0.5)

    def test_budget_without_delta_raises(self):
        fam = registry.get("bgc")
        with pytest.raises(ValueError, match="requires delta"):
            fam.make(32, 32, 4, seed=0, error_budget=0.1)
        with pytest.raises(ValueError, match="requires delta"):
            fam.legal_s(32, 32, error_budget=0.1)
