"""Hypothesis property-based tests for system invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import adversary as ADV
from repro.core import assignment as ASG
from repro.core import codes as C
from repro.core import decoding as D


# ------------------------- strategies -------------------------------------

def code_params():
    return st.tuples(
        st.sampled_from([12, 20, 24, 40, 60]),       # k (= n)
        st.integers(min_value=1, max_value=6),        # s
        st.integers(min_value=0, max_value=2**31 - 1),
    )


def _make(scheme, k, s, seed):
    rng = np.random.default_rng(seed)
    if scheme == "frc":
        s = max(1, s)
        while k % s:
            s -= 1
        return C.frc(k, k, s, rng=rng)
    if scheme == "sregular":
        s = min(max(2, s), k - 1)
        if (k * s) % 2:
            s += 1
        return C.sregular(k, k, s, rng=rng)
    return C.make_code(scheme, k=k, n=k, s=s, rng=rng)


SCHEMES = ["frc", "bgc", "rbgc", "cyclic"]


# ------------------------- invariants --------------------------------------

@settings(max_examples=60, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES), st.floats(0.0, 0.8))
def test_err_bounded_by_k(params, scheme, delta):
    """0 <= err(A) <= k for any code and any straggler set (Def. 1)."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 1)
    mask = np.ones(k, dtype=bool)
    nstr = int(delta * k)
    if nstr:
        mask[rng.choice(k, nstr, replace=False)] = False
    e = D.err(code.G[:, mask])
    assert -1e-8 <= e <= k + 1e-8


@settings(max_examples=60, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES), st.floats(0.0, 0.8))
def test_onestep_dominates_optimal(params, scheme, delta):
    """err_1(A) >= err(A) always (optimal decoding is optimal)."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 2)
    mask = np.ones(k, dtype=bool)
    nstr = int(delta * k)
    if nstr:
        mask[rng.choice(k, nstr, replace=False)] = False
    A = code.G[:, mask]
    rho = D.default_rho(k, int(mask.sum()), code.s)
    assert D.err1(A, rho) >= D.err(A) - 1e-8


@settings(max_examples=40, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES))
def test_algorithmic_curve_monotone(params, scheme):
    """Lemma 12: ||u_t||^2 is non-increasing and lower-bounded by err(A)."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 3)
    mask = np.ones(k, dtype=bool)
    mask[rng.choice(k, k // 4, replace=False)] = False
    A = code.G[:, mask]
    curve = D.algorithmic_error_curve(A, iters=30)
    assert np.all(np.diff(curve) <= 1e-7)
    assert np.all(curve >= D.err(A) - 1e-6)


@settings(max_examples=40, deadline=None)
@given(code_params())
def test_rbgc_degree_cap(params):
    """Algorithm 3 invariant: max column degree <= 2s."""
    k, s, seed = params
    code = _make("rbgc", k, s, seed)
    assert code.max_col_degree <= 2 * code.s


@settings(max_examples=40, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES))
def test_adding_workers_never_hurts(params, scheme):
    """err(A') <= err(A) when A' has a superset of A's columns (more
    non-stragglers can only improve the optimal decode)."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 4)
    mask = np.ones(k, dtype=bool)
    mask[rng.choice(k, k // 2, replace=False)] = False
    bigger = mask.copy()
    bigger[rng.choice(np.flatnonzero(~mask))] = True
    assert D.err(code.G[:, bigger]) <= D.err(code.G[:, mask]) + 1e-8


@settings(max_examples=40, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES))
def test_column_permutation_invariance(params, scheme):
    """err is invariant to worker relabeling."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 5)
    mask = np.ones(k, dtype=bool)
    mask[rng.choice(k, k // 3, replace=False)] = False
    perm = rng.permutation(k)
    e1 = D.err(code.G[:, mask])
    e2 = D.err(code.G[:, perm][:, mask[perm]])
    assert abs(e1 - e2) <= 1e-7


@settings(max_examples=40, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES), st.floats(0.0, 0.6))
def test_decode_weights_zero_on_stragglers(params, scheme, delta):
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    rng = np.random.default_rng(seed + 6)
    mask = np.ones(k, dtype=bool)
    nstr = int(delta * k)
    if nstr:
        mask[rng.choice(k, nstr, replace=False)] = False
    for method in ["onestep", "optimal"]:
        w = D.decode_weights(code.G, mask, method=method)
        assert np.all(w[~mask] == 0.0)


@settings(max_examples=30, deadline=None)
@given(code_params(), st.sampled_from(SCHEMES))
def test_assignment_reconstructs_mean_loss(params, scheme):
    """With no stragglers + an exact-decode code (or optimal weights), the
    reweighted physical batch reproduces the mean over unique examples."""
    k, s, seed = params
    code = _make(scheme, k, s, seed)
    asg = ASG.build_assignment(code)
    rng = np.random.default_rng(seed + 7)
    T = 3  # rows per slot
    losses_unique = rng.normal(size=(k, T))  # per unique example
    mask = np.ones(code.n, dtype=bool)
    w = D.optimal_weights(code.G, mask)
    v = code.G @ w
    if not np.allclose(v, 1.0, atol=1e-8):
        return  # decode not exact for this draw; identity holds only then
    rows = asg.unique_row_of_slot(T)
    weights = asg.row_weights(w, T)
    flat = np.where(rows >= 0, losses_unique.reshape(-1)[np.maximum(rows, 0)], 0.0)
    got = float((weights * flat).sum())
    want = float(losses_unique.mean())
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 40), st.integers(2, 5), st.integers(0, 1000))
def test_frc_adversary_matches_thm10(k_blocks, s, seed):
    """Adversarial FRC error == k - r whenever budget is a multiple of s."""
    k = k_blocks * s
    code = C.frc(k, k, s, rng=np.random.default_rng(seed))
    budget = s * max(1, k_blocks // 3)
    mask = ADV.frc_adversarial_mask(code.G, budget)
    r = k - budget
    assert D.err(code.G[:, mask]) == np.float64(k - r)
