"""Interpret-mode Pallas coverage for kernels/batched_decode.py at the
shapes the tiled grids are most likely to get wrong (ISSUE 2 satellite):
n and k not multiples of the 8/128 TPU tile units, B = 1 (single-mask
batch), and the all-stragglers / no-stragglers edge masks."""

import numpy as np
import pytest
import jax.numpy as jnp
from numpy.testing import assert_allclose

from repro.core import codes as C
from repro.core import decoding as D
from repro.core.engine import DecodeEngine
from repro.kernels import ops

RAGGED_SHAPES = [
    (29, 37, 1),    # neither dim a multiple of 8; B = 1
    (29, 37, 3),
    (100, 52, 5),   # k multiple of 4 only, n = 52
    (7, 5, 1),      # smaller than any tile
    (127, 129, 2),  # one off the 128 lane width on both sides
]


def _problem(k, n, B, seed=0, mask_frac=0.7):
    rng = np.random.default_rng(seed)
    G = (rng.random((k, n)) < max(3 / n, 0.15)).astype(np.float32)
    masks = rng.random((B, n)) < mask_frac
    rhos = (rng.random(B) + 0.5).astype(np.float32)
    return G, masks, rhos


@pytest.mark.parametrize("k,n,B", RAGGED_SHAPES)
def test_ragged_batched_onestep_matches_xla(k, n, B):
    G, masks, rhos = _problem(k, n, B)
    args = (jnp.asarray(G), jnp.asarray(masks), jnp.asarray(rhos))
    want = np.asarray(ops.batched_onestep_decode(*args, impl="xla"))
    # block sizes > padded dims AND blocks that force ragged final tiles
    for bb, bk, bn in [(128, 256, 256), (8, 16, 16)]:
        got = np.asarray(ops.batched_onestep_decode(
            *args, impl="pallas_interpret", bb=bb, bk=bk, bn=bn))
        assert got.shape == (B, k)
        assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("edge", ["none", "all"])
@pytest.mark.parametrize("k,n,B", [(29, 37, 1), (100, 52, 4)])
def test_edge_masks_batched_onestep(k, n, B, edge):
    """All-stragglers (empty mask) and no-stragglers (full mask) rows."""
    G, _, rhos = _problem(k, n, B)
    masks = np.zeros((B, n), bool) if edge == "none" \
        else np.ones((B, n), bool)
    got = np.asarray(ops.batched_onestep_decode(
        jnp.asarray(G), jnp.asarray(masks), jnp.asarray(rhos),
        impl="pallas_interpret", bb=8, bk=16, bn=16))
    if edge == "none":
        assert_allclose(got, np.zeros((B, k)), atol=0)
    else:
        want = rhos[:, None] * G.sum(axis=1)[None, :]
        assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("k,n", [(29, 37), (40, 52)])
def test_ragged_ell_matches_dense(k, n):
    code = C.make_code("bgc", k=k, n=n, s=4, rng=np.random.default_rng(7))
    idx, val = code.ell()
    for B, frac in [(1, 0.7), (5, 0.0), (5, 1.0)]:
        rng = np.random.default_rng(B)
        masks = rng.random((B, n)) < frac
        rhos = (rng.random(B) + 0.5).astype(np.float32)
        dense = np.asarray(ops.batched_onestep_decode(
            jnp.asarray(code.G.astype(np.float32)), jnp.asarray(masks),
            jnp.asarray(rhos), impl="pallas_interpret", bb=8, bk=16, bn=16))
        ell = np.asarray(ops.batched_onestep_decode_ell(
            jnp.asarray(idx), jnp.asarray(val), jnp.asarray(masks),
            jnp.asarray(rhos), impl="pallas_interpret", bb=8, bk=16))
        assert_allclose(ell, dense, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("k,n,B", [(29, 37, 1), (100, 52, 3)])
def test_ragged_batched_algorithmic_matches_numpy(k, n, B):
    G, masks, _ = _problem(k, n, B, seed=3)
    nus = D.spectral_norm_sq_batch(G, masks).astype(np.float32) * 1.01
    U, X = ops.batched_algorithmic_decode(
        jnp.asarray(G), jnp.asarray(masks), jnp.asarray(nus), 3,
        impl="pallas_interpret", bb=8, bk=16, bn=16, return_weights=True)
    W_np, errs_np = D.algorithmic_weights_batch(
        G.astype(np.float64), masks, 3, nu=nus.astype(np.float64),
        return_errors=True)
    assert_allclose(np.asarray(X) * masks, W_np, atol=1e-4, rtol=1e-3)
    assert_allclose((np.asarray(U) ** 2).sum(axis=1), errs_np,
                    atol=1e-3, rtol=1e-3)


def test_ragged_algorithmic_edge_masks():
    """Empty mask: A = 0, so U stays 1_k and the weights stay 0. Full
    mask: matches the numpy batch decoder."""
    G, _, _ = _problem(29, 37, 1, seed=4)
    empty = np.zeros((1, 37), bool)
    nus = np.ones(1, np.float32)
    U, X = ops.batched_algorithmic_decode(
        jnp.asarray(G), jnp.asarray(empty), jnp.asarray(nus), 4,
        impl="pallas_interpret", bb=8, bk=16, bn=16, return_weights=True)
    assert_allclose(np.asarray(U), np.ones((1, 29)), atol=1e-6)
    assert_allclose(np.asarray(X) * empty, np.zeros((1, 37)), atol=0)

    full = np.ones((1, 37), bool)
    nus = D.spectral_norm_sq_batch(G, full).astype(np.float32) * 1.01
    U, X = ops.batched_algorithmic_decode(
        jnp.asarray(G), jnp.asarray(full), jnp.asarray(nus), 4,
        impl="pallas_interpret", bb=8, bk=16, bn=16, return_weights=True)
    W_np = D.algorithmic_weights_batch(G.astype(np.float64), full, 4,
                                       nu=nus.astype(np.float64))
    assert_allclose(np.asarray(X), W_np, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("n,B", [(29, 1), (52, 5), (127, 3), (7, 2)])
def test_ragged_masked_gram_matches_xla(n, B):
    """The batched masked-Gram kernel (normal-equations ensemble of the
    least-squares decoder) at n not a multiple of the tile units."""
    rng = np.random.default_rng(n)
    G = (rng.random((n + 3, n)) < 0.2).astype(np.float32)
    gram = G.T @ G
    masks = rng.random((B, n)) < 0.6
    want = np.asarray(ops.batched_masked_gram(
        jnp.asarray(gram), jnp.asarray(masks), impl="xla"))
    for bb, bi, bj in [(8, 128, 128), (2, 16, 16)]:
        got = np.asarray(ops.batched_masked_gram(
            jnp.asarray(gram), jnp.asarray(masks), impl="pallas_interpret",
            bb=bb, bi=bi, bj=bj))
        assert got.shape == (B, n, n)
        # 0/1 supports: small-integer Gram entries are exact in fp32
        assert_allclose(got, want, atol=0)
    # straggler rows/columns are exactly zero
    dead = ~masks[0]
    assert np.all(want[0][dead, :] == 0) and np.all(want[0][:, dead] == 0)


def test_ragged_masked_gram_edge_masks():
    rng = np.random.default_rng(0)
    G = (rng.random((29, 37)) < 0.2).astype(np.float32)
    gram = G.T @ G
    empty = np.zeros((1, 37), bool)
    full = np.ones((1, 37), bool)
    ge = np.asarray(ops.batched_masked_gram(
        jnp.asarray(gram), jnp.asarray(empty), impl="pallas_interpret",
        bb=2, bi=16, bj=16))
    gf = np.asarray(ops.batched_masked_gram(
        jnp.asarray(gram), jnp.asarray(full), impl="pallas_interpret",
        bb=2, bi=16, bj=16))
    assert_allclose(ge[0], np.zeros((37, 37)), atol=0)
    assert_allclose(gf[0], gram, atol=0)


def test_engine_gram_optimal_interpret_matches_numpy_ragged():
    """DecodeEngine optimal decode through the kernel-backed gram path
    equals the numpy gram path (same ridge) at a ragged n."""
    code = C.make_code("expander", k=29, n=29, s=4,
                       rng=np.random.default_rng(11))
    rng = np.random.default_rng(12)
    masks = rng.random((6, 29)) < 0.6
    masks[0] = False
    masks[1] = True
    res_np = DecodeEngine(code, optimal_impl="gram").decode_batch(
        masks, "optimal")
    res_k = DecodeEngine(code, backend="pallas_interpret").decode_batch(
        masks, "optimal")
    assert_allclose(res_k.weights, res_np.weights, atol=0)
    assert_allclose(res_k.errors, res_np.errors, atol=0)


def test_engine_interpret_backend_ragged_code_and_edges():
    """DecodeEngine end-to-end on a ragged-n code with edge-mask rows
    mixed into the batch, pallas_interpret vs numpy, dense and ELL."""
    code = C.make_code("bgc", k=52, n=52, s=5, rng=np.random.default_rng(9))
    rng = np.random.default_rng(10)
    masks = rng.random((6, 52)) < 0.7
    masks[0] = False   # all stragglers
    masks[1] = True    # no stragglers
    res_np = DecodeEngine(code, backend="numpy").decode_batch(masks)
    for sparse in ("always", "never"):
        res_k = DecodeEngine(code, backend="pallas_interpret",
                             sparse=sparse).decode_batch(masks)
        assert_allclose(res_k.weights, res_np.weights, atol=1e-5)
        assert_allclose(res_k.errors, res_np.errors, atol=1e-3, rtol=1e-4)
