"""Runtime substrate tests: serving engine queue semantics, straggler
models (SPMD determinism), analytic latency model, checkpoint pruning /
async writer, and the launcher CLIs end-to-end (subprocess)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.checkpoint.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.models import build_model
from repro.runtime import CorrelatedStragglers, DeadlineStragglers, \
    FixedFractionStragglers, IIDStragglers, make_straggler_model
from repro.sim import trace_from_model, wallclock_summary
from repro.serving import Request, ServingEngine

REPO = Path(__file__).resolve().parent.parent


# ----------------------------- serving ---------------------------------------

@pytest.fixture(scope="module")
def engine():
    cfg = get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, batch_slots=3, cache_len=64)


def test_serve_queue_all_requests_served(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(7)]  # 7 requests > 3 slots -> multiple waves
    out = eng.serve_queue(reqs)
    assert sorted(out) == list(range(7))
    for r in reqs:
        assert len(out[r.rid]) == r.max_new_tokens
        assert all(0 <= t < cfg.padded_vocab for t in out[r.rid])


def test_serve_deterministic(engine):
    cfg, eng = engine
    rng = np.random.default_rng(1)
    p = rng.integers(1, cfg.vocab, 12).astype(np.int32)
    a = eng.serve_queue([Request(rid=0, prompt=p, max_new_tokens=6)])[0]
    b = eng.serve_queue([Request(rid=0, prompt=p, max_new_tokens=6)])[0]
    assert a == b


def test_prefill_decode_consistency(engine):
    """Greedy decode via the engine == teacher-forced argmax of the
    uncached forward (KV-cache correctness at the serving level)."""
    cfg, eng = engine
    model, params = eng.model, eng.params
    rng = np.random.default_rng(2)
    p = rng.integers(1, cfg.vocab, 10).astype(np.int32)
    got = eng.generate_batch([p], max_new=3)[0]
    # uncached reference, token by token
    seq = list(p)
    want = []
    for _ in range(3):
        batch = {"tokens": jnp.asarray(np.asarray(seq)[None])}
        from repro.models.lm import lm_forward
        logits, _ = lm_forward(params, cfg, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        seq.append(nxt)
    assert got == want


# ----------------------------- stragglers ------------------------------------

@pytest.mark.parametrize("model", [
    IIDStragglers(delta=0.3, seed=7),
    FixedFractionStragglers(delta=0.25, seed=7),
    DeadlineStragglers(seed=7),
    CorrelatedStragglers(pod_size=4, seed=7),
])
def test_straggler_masks_deterministic_per_step(model):
    """Every host derives the identical mask from (seed, step) — the
    SPMD no-communication property (docs/architecture.md §2.1)."""
    for step in (0, 1, 17):
        a = model.sample(step, 16)
        b = model.sample(step, 16)
        assert a.dtype == bool and a.shape == (16,)
        assert np.array_equal(a, b)


def test_fixed_fraction_exact_count():
    m = FixedFractionStragglers(delta=0.25, seed=0)
    for step in range(5):
        assert (~m.sample(step, 16)).sum() == 4


def test_deadline_mask_consistent_with_latencies():
    m = DeadlineStragglers(deadline=1.5, seed=3)
    lat = m.latencies(5, 32)
    assert np.array_equal(m.sample(5, 32), lat <= 1.5)


def test_make_straggler_model_registry():
    assert isinstance(make_straggler_model("iid", delta=0.1), IIDStragglers)
    with pytest.raises(ValueError):
        make_straggler_model("nope")


def test_wallclock_deadline_beats_sync():
    m = DeadlineStragglers(deadline=1.5, tail_scale=0.4, seed=0)
    trace = trace_from_model(m, 50, 32)
    sync = wallclock_summary(trace, policy="sync")
    dead = wallclock_summary(trace, policy="deadline", deadline=1.5)
    assert dead["mean_step_time"] <= 1.5 + 1e-9
    assert sync["mean_step_time"] > dead["mean_step_time"]
    assert dead["mean_stragglers"] > 0  # the trade: time bought with error


# ----------------------------- checkpoint ------------------------------------

def test_checkpoint_keep_last_prunes(tmp_path):
    tree = {"a": np.arange(4.0), "b": {"c": np.ones((2, 2))}}
    for step in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), step, tree, keep_last=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert latest_step(str(tmp_path)) == 4


def test_async_checkpointer_roundtrip(tmp_path):
    tree = {"w": np.random.default_rng(0).standard_normal((8, 8)),
            "step": np.int32(5)}
    ck = AsyncCheckpointer(str(tmp_path), keep_last=3)
    ck.save(10, tree, {"next_step": 11})
    ck.close()
    got, meta = restore_checkpoint(str(tmp_path), tree)
    assert meta["next_step"] == 11
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_restore_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": np.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"b": np.zeros(3)})


# ----------------------------- launcher CLIs ---------------------------------

def _run_cli(args, timeout=480):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-m", *args], cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    hist = tmp_path / "hist.json"
    out = _run_cli(["repro.launch.train", "--arch", "minicpm-2b", "--smoke",
                    "--code", "bgc", "--decoder", "onestep", "--steps", "6",
                    "--workers", "4", "--s", "2", "--seq-len", "32",
                    "--straggler", "fixed", "--history-out", str(hist)])
    assert out.returncode == 0, out.stderr[-2000:]
    h = json.loads(hist.read_text())
    assert h[-1]["step"] == 5
    assert np.isfinite(h[-1]["mean_ce"])


@pytest.mark.slow
def test_serve_cli_smoke():
    out = _run_cli(["repro.launch.serve", "--arch", "minicpm-2b", "--smoke",
                    "--requests", "3", "--max-new", "3",
                    "--prompt-len", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
