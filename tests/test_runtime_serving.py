"""Runtime substrate tests: serving engine queue semantics, straggler
models (SPMD determinism), analytic latency model, checkpoint pruning /
async writer, and the launcher CLIs end-to-end (subprocess)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.checkpoint.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.models import build_model
from repro.runtime import CorrelatedStragglers, DeadlineStragglers, \
    FixedFractionStragglers, IIDStragglers, make_straggler_model
from repro.sim import trace_from_model, wallclock_summary
from repro.sim.traces import TraceCursor, make_trace
from repro.serving import HedgePolicy, Request, ServingEngine, \
    hedge_outcomes, simulate_serving

REPO = Path(__file__).resolve().parent.parent


# ----------------------------- serving ---------------------------------------

@pytest.fixture(scope="module")
def engine():
    cfg = get_config("minicpm-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, batch_slots=3, cache_len=64)


def test_serve_queue_all_requests_served(engine):
    cfg, eng = engine
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, 12).astype(np.int32),
                    max_new_tokens=4 + (i % 3))
            for i in range(7)]  # 7 requests > 3 slots -> multiple waves
    out = eng.serve_queue(reqs)
    assert sorted(out) == list(range(7))
    for r in reqs:
        assert len(out[r.rid]) == r.max_new_tokens
        assert all(0 <= t < cfg.padded_vocab for t in out[r.rid])


def test_serve_deterministic(engine):
    cfg, eng = engine
    rng = np.random.default_rng(1)
    p = rng.integers(1, cfg.vocab, 12).astype(np.int32)
    a = eng.serve_queue([Request(rid=0, prompt=p, max_new_tokens=6)])[0]
    b = eng.serve_queue([Request(rid=0, prompt=p, max_new_tokens=6)])[0]
    assert a == b


def test_prefill_decode_consistency(engine):
    """Greedy decode via the engine == teacher-forced argmax of the
    uncached forward (KV-cache correctness at the serving level)."""
    cfg, eng = engine
    model, params = eng.model, eng.params
    rng = np.random.default_rng(2)
    p = rng.integers(1, cfg.vocab, 10).astype(np.int32)
    got = eng.generate_batch([p], max_new=3)[0]
    # uncached reference, token by token
    seq = list(p)
    want = []
    for _ in range(3):
        batch = {"tokens": jnp.asarray(np.asarray(seq)[None])}
        from repro.models.lm import lm_forward
        logits, _ = lm_forward(params, cfg, batch)
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        seq.append(nxt)
    assert got == want


def _ragged_prompts(cfg, lengths, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, L).astype(np.int32) for L in lengths]


def test_masked_prefill_matches_per_request(engine):
    """Left-padded batched prefill with a length mask is BITWISE equal
    to prefilling each prompt alone (the batching-correctness bug this
    PR fixes: pad tokens must not attend, positions must stay
    unpadded)."""
    cfg, eng = engine
    model, params = eng.model, eng.params
    assert model.supports_masked_prefill
    prompts = _ragged_prompts(cfg, (5, 9, 12))
    L = max(len(p) for p in prompts)
    toks = np.zeros((len(prompts), L), np.int32)
    mask = np.zeros((len(prompts), L), bool)
    for i, p in enumerate(prompts):
        toks[i, L - len(p):] = p
        mask[i, L - len(p):] = True
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(toks),
                 "length_mask": jnp.asarray(mask)}, cache_len=32)
    assert caches["pos"].tolist() == [len(p) for p in prompts]
    for i, p in enumerate(prompts):
        solo, _ = model.prefill(params, {"tokens": jnp.asarray(p[None])},
                                cache_len=32)
        np.testing.assert_array_equal(np.asarray(logits[i]),
                                      np.asarray(solo[0]))


def test_serve_queue_ragged_parity(engine):
    """Continuous batching with mixed prompt lengths AND mixed
    max_new_tokens produces exactly the per-request tokens, each request
    stops at its own budget, and Request.done is set."""
    cfg, eng = engine
    prompts = _ragged_prompts(cfg, (5, 9, 12, 7, 3, 10), seed=4)
    max_news = [4, 9, 2, 6, 1, 5]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, max_news))]
    out = eng.serve_queue(reqs)
    solo_eng = ServingEngine(eng.model, eng.params, batch_slots=1,
                             cache_len=eng.cache_len)
    for i, (p, m) in enumerate(zip(prompts, max_news)):
        assert reqs[i].done
        assert len(out[i]) == m
        solo = solo_eng.serve_queue(
            [Request(rid=i, prompt=p, max_new_tokens=m)])[i]
        assert out[i] == solo


def test_generate_batch_ragged_parity(engine):
    """Batched generation over ragged prompts (the masked-prefill path)
    matches generating each prompt alone, token for token."""
    cfg, eng = engine
    prompts = _ragged_prompts(cfg, (6, 11, 4, 9), seed=5)
    batched = eng.generate_batch(prompts, max_new=5)
    for i, p in enumerate(prompts):
        assert batched[i] == eng.generate_batch([p], max_new=5,
                                                rids=[i])[0]


def test_slot_recycling_occupancy(engine):
    """A freed slot admits the next pending request immediately (same
    tick as the retirement) while longer requests keep decoding; no
    slot ever holds two live requests and occupancy never exceeds the
    slot count."""
    cfg, eng = engine
    eng2 = ServingEngine(eng.model, eng.params, batch_slots=2,
                         cache_len=32)
    prompts = _ragged_prompts(cfg, (6, 6, 6), seed=6)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=m)
            for i, (p, m) in enumerate(zip(prompts, (2, 8, 2)))]
    out = eng2.serve_queue(reqs)
    assert sorted(out) == [0, 1, 2]
    ev = eng2.events
    assert [e.kind for e in ev].count("admit") == 3
    assert [e.kind for e in ev].count("retire") == 3
    # interval-overlap check per slot + global occupancy bound
    live = {}
    occupancy = 0
    for e in ev:
        if e.kind == "admit":
            assert e.slot not in live, "slot admitted while occupied"
            live[e.slot] = e.rid
            occupancy += 1
        else:
            assert live.pop(e.slot) == e.rid
            occupancy -= 1
        assert 0 <= occupancy <= eng2.B
    assert not live
    # rid 0 (max_new=2) retires at tick 2 and rid 2 is admitted at the
    # SAME tick, while rid 1 (max_new=8) is still mid-flight
    by = {(e.kind, e.rid): e for e in ev}
    assert by[("retire", 0)].tick == by[("admit", 2)].tick
    assert by[("retire", 1)].tick > by[("admit", 2)].tick


def test_sampling_honors_greedy_flag(engine):
    """greedy=False actually samples (the dead-flag bug): sampled
    output is deterministic in (seed, rid, token index) and independent
    of batch composition, and a different seed samples a different
    continuation."""
    cfg, eng = engine
    model, params = eng.model, eng.params
    p, q = _ragged_prompts(cfg, (8, 8), seed=7)
    greedy = eng.generate_batch([p], max_new=12)[0]
    s0 = ServingEngine(model, params, batch_slots=2, cache_len=32,
                       greedy=False, temperature=1.0, seed=0)
    s0b = ServingEngine(model, params, batch_slots=2, cache_len=32,
                        greedy=False, temperature=1.0, seed=0)
    s1 = ServingEngine(model, params, batch_slots=2, cache_len=32,
                       greedy=False, temperature=1.0, seed=1)
    alone = s0.generate_batch([p], max_new=12, rids=[0])[0]
    packed = s0b.generate_batch([p, q], max_new=12, rids=[0, 1])[0]
    assert alone == packed          # batch-composition independent
    assert alone != greedy          # the flag does something
    assert alone != s1.generate_batch([p], max_new=12, rids=[0])[0]
    # serve_queue uses the same (seed, rid, index) keys
    queued = s0.serve_queue([Request(rid=0, prompt=p,
                                     max_new_tokens=12)])[0]
    assert queued == alone


# ------------------------- hedged serving (sim) -------------------------------

def test_trace_cursor_replay_order():
    tr = make_trace("bimodal", steps=5, n=3, seed=1)
    c = TraceCursor(tr)
    got = c.take(np.array([0, 0, 1, 0, 2, 2]))
    want = [tr.latencies[0, 0], tr.latencies[1, 0], tr.latencies[0, 1],
            tr.latencies[2, 0], tr.latencies[0, 2], tr.latencies[1, 2]]
    np.testing.assert_array_equal(got, want)
    # wrap-around: replica 0 has consumed rows 0..2, next are 3, 4, 0
    np.testing.assert_array_equal(c.take(np.array([0, 0, 0])),
                                  tr.latencies[[3, 4, 0], 0])


def test_hedge_outcomes_semantics():
    p = np.array([1.0, 3.0, 3.0])
    b = np.array([9.0, 1.0, 9.0])
    # warmup: infinite threshold never fires and is exactly unhedged
    lat, comp, fired = hedge_outcomes(p, b, float("inf"))
    np.testing.assert_array_equal(lat, p)
    np.testing.assert_array_equal(comp, p)
    assert not fired.any()
    lat, comp, fired = hedge_outcomes(p, b, 1.5)
    assert fired.tolist() == [False, True, True]
    # fast primary untouched; slow primary rescued by fast backup at
    # thr + T_b; slow backup loses, primary finishes first
    np.testing.assert_allclose(lat, [1.0, 2.5, 3.0])
    # winner runs lat, fired loser is cancelled after lat - thr
    np.testing.assert_allclose(comp, [1.0, 2.5 + 1.0, 3.0 + 1.5])


def test_hedge_simulation_deterministic():
    """The whole replay is a pure function of (seed, trace): reruns are
    bitwise identical, a different seed routes differently."""
    trace = make_trace("bimodal", steps=512, n=8, seed=0)
    kw = dict(policy=HedgePolicy(quantile=0.85), seed=3, chunk=1000)
    a = simulate_serving(trace, 20_000, **kw)
    b = simulate_serving(trace, 20_000, **kw)
    np.testing.assert_array_equal(a.latency, b.latency)
    np.testing.assert_array_equal(a.compute, b.compute)
    np.testing.assert_array_equal(a.fired, b.fired)
    np.testing.assert_array_equal(a.primary, b.primary)
    c = simulate_serving(trace, 20_000, policy=HedgePolicy(quantile=0.85),
                         seed=4, chunk=1000)
    assert (c.primary != a.primary).any()


def test_serving_tail_smoke(tmp_path, monkeypatch):
    """E12-shaped smoke at reduced scale: hedging collapses the bimodal
    p99 within the 1.1x compute budget, the too-high quantile does not,
    and the artifact lands with its gate results."""
    from benchmarks import serving_tail
    monkeypatch.chdir(tmp_path)     # artifacts under tmp, not the repo
    rep = serving_tail.run(requests=30_000, steps=2048)
    checks = rep["checks"]
    assert checks["hedged_p99_beats_unhedged_at_le_1.1x"]
    assert checks["best_overhead_le_1.1x"]
    assert checks["replay_deterministic"]
    assert checks["q99_does_not_fire_on_slow_mode"]
    assert not checks["requests_ge_1M"]     # reduced scale, by design
    assert rep["best"]["p99"] < rep["unhedged"]["p99"]
    assert (tmp_path / "artifacts/bench/serving_tail.json").exists()


def test_p2c_routing_avoids_slow_replica():
    """Tail-aware power-of-two-choices routing beats uniform on a
    persistently-slow replica without any hedging at all."""
    trace = make_trace("bimodal", steps=2048, n=8, seed=0)
    uni = simulate_serving(trace, 100_000, policy=None, seed=5)
    p2c = simulate_serving(trace, 100_000, policy=None,
                           router_policy="p2c", seed=5)
    assert p2c.p99 < uni.p99
    assert p2c.quantiles[0.9] < uni.quantiles[0.9]


# ----------------------------- stragglers ------------------------------------

@pytest.mark.parametrize("model", [
    IIDStragglers(delta=0.3, seed=7),
    FixedFractionStragglers(delta=0.25, seed=7),
    DeadlineStragglers(seed=7),
    CorrelatedStragglers(pod_size=4, seed=7),
])
def test_straggler_masks_deterministic_per_step(model):
    """Every host derives the identical mask from (seed, step) — the
    SPMD no-communication property (docs/architecture.md §2.1)."""
    for step in (0, 1, 17):
        a = model.sample(step, 16)
        b = model.sample(step, 16)
        assert a.dtype == bool and a.shape == (16,)
        assert np.array_equal(a, b)


def test_fixed_fraction_exact_count():
    m = FixedFractionStragglers(delta=0.25, seed=0)
    for step in range(5):
        assert (~m.sample(step, 16)).sum() == 4


def test_deadline_mask_consistent_with_latencies():
    m = DeadlineStragglers(deadline=1.5, seed=3)
    lat = m.latencies(5, 32)
    assert np.array_equal(m.sample(5, 32), lat <= 1.5)


def test_make_straggler_model_registry():
    assert isinstance(make_straggler_model("iid", delta=0.1), IIDStragglers)
    with pytest.raises(ValueError):
        make_straggler_model("nope")


def test_wallclock_deadline_beats_sync():
    m = DeadlineStragglers(deadline=1.5, tail_scale=0.4, seed=0)
    trace = trace_from_model(m, 50, 32)
    sync = wallclock_summary(trace, policy="sync")
    dead = wallclock_summary(trace, policy="deadline", deadline=1.5)
    assert dead["mean_step_time"] <= 1.5 + 1e-9
    assert sync["mean_step_time"] > dead["mean_step_time"]
    assert dead["mean_stragglers"] > 0  # the trade: time bought with error


# ----------------------------- checkpoint ------------------------------------

def test_checkpoint_keep_last_prunes(tmp_path):
    tree = {"a": np.arange(4.0), "b": {"c": np.ones((2, 2))}}
    for step in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), step, tree, keep_last=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert latest_step(str(tmp_path)) == 4


def test_async_checkpointer_roundtrip(tmp_path):
    tree = {"w": np.random.default_rng(0).standard_normal((8, 8)),
            "step": np.int32(5)}
    ck = AsyncCheckpointer(str(tmp_path), keep_last=3)
    ck.save(10, tree, {"next_step": 11})
    ck.close()
    got, meta = restore_checkpoint(str(tmp_path), tree)
    assert meta["next_step"] == 11
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_restore_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": np.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"b": np.zeros(3)})


# ----------------------------- launcher CLIs ---------------------------------

def _run_cli(args, timeout=480):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run([sys.executable, "-m", *args], cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    hist = tmp_path / "hist.json"
    out = _run_cli(["repro.launch.train", "--arch", "minicpm-2b", "--smoke",
                    "--code", "bgc", "--decoder", "onestep", "--steps", "6",
                    "--workers", "4", "--s", "2", "--seq-len", "32",
                    "--straggler", "fixed", "--history-out", str(hist)])
    assert out.returncode == 0, out.stderr[-2000:]
    h = json.loads(hist.read_text())
    assert h[-1]["step"] == 5
    assert np.isfinite(h[-1]["mean_ce"])


@pytest.mark.slow
def test_serve_cli_smoke():
    out = _run_cli(["repro.launch.serve", "--arch", "minicpm-2b", "--smoke",
                    "--requests", "3", "--max-new", "3",
                    "--prompt-len", "8"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
