"""Integration tests: coded training loop, fused-vs-master-decode
equivalence, checkpoint/restart, elasticity, compression."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as CFG
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import (FaultInjector, FaultPlan,
                           FixedFractionStragglers)
from repro.training import (CodedTrainConfig, CodedTrainer,
                            explicit_master_decode_grads)

pytestmark = pytest.mark.slow  # training e2e: jit + multi-step loops


def tiny_model():
    cfg = CFG.get_config("minicpm-2b", smoke=True)
    return build_model(cfg)


def make_trainer(model, straggler=None, faults=None, **kw):
    defaults = dict(code="frc", n_workers=8, s=2, decoder="onestep",
                    rows_per_slot=1, seq_len=16, steps=6, seed=0,
                    opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                    log_every=1)
    defaults.update(kw)
    return CodedTrainer(model, CodedTrainConfig(**defaults),
                        straggler_model=straggler, fault_injector=faults)


class TestFusedDecodeEquivalence:
    """docs/architecture.md §2.1: loss-reweighted all-reduce == explicit master decode."""

    @pytest.mark.parametrize("code,decoder", [
        ("frc", "onestep"), ("bgc", "onestep"),
        ("frc", "optimal"), ("bgc", "optimal"),
    ])
    def test_grads_identical(self, code, decoder):
        model = tiny_model()
        tr = make_trainer(model, code=code, decoder=decoder,
                          exact_decode_renorm=False)
        params = model.init(jax.random.PRNGKey(0))
        mask = np.ones(8, dtype=bool)
        mask[[1, 5]] = False
        # explicit: per-worker partials, decoded on the 'master'
        explicit, w = explicit_master_decode_grads(model, params, tr, 0, mask)
        # fused: one loss-reweighted grad
        batch_np = tr.pipeline.batch_for_step(0, w)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        fused = jnp.concatenate([g.reshape(-1).astype(jnp.float32)
                                 for g in jax.tree_util.tree_leaves(grads)])
        np.testing.assert_allclose(np.asarray(fused), np.asarray(explicit),
                                   rtol=5e-4, atol=5e-6)

    def test_no_stragglers_equals_uncoded_gradient(self):
        """With zero stragglers and an exact-decoding code, the coded
        gradient equals the plain uncoded gradient over unique data."""
        model = tiny_model()
        # pinv: the exact-oracle opt-in — the gram default's ridge floor
        # perturbs G@w at the ~1e-7 scale this test pins
        tr = make_trainer(model, code="frc", decoder="optimal",
                          exact_decode_renorm=False, optimal_impl="pinv")
        params = model.init(jax.random.PRNGKey(1))
        mask = np.ones(8, dtype=bool)
        w = tr.decode_weights_for(mask)
        v = tr.code.G @ w
        np.testing.assert_allclose(v, 1.0, atol=1e-7)  # exact decode
        coded_np = tr.pipeline.batch_for_step(0, w)
        uncoded_np = tr.pipeline.uncoded_batch_for_step(0)
        g_coded = jax.grad(lambda p: model.loss_fn(
            p, {k: jnp.asarray(x) for k, x in coded_np.items()})[0])(params)
        g_ref = jax.grad(lambda p: model.loss_fn(
            p, {k: jnp.asarray(x) for k, x in uncoded_np.items()})[0])(params)
        for a, b in zip(jax.tree_util.tree_leaves(g_coded),
                        jax.tree_util.tree_leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-4, atol=5e-6)


class TestTrainerLoop:
    def test_loss_decreases_no_stragglers(self):
        model = tiny_model()
        tr = make_trainer(model, steps=16, code="uncoded", s=1)
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0], f"no learning: {losses}"
        assert all(np.isfinite(l) for l in losses)

    def test_coded_training_with_stragglers_learns(self):
        model = tiny_model()
        tr = make_trainer(model, steps=16, code="frc", s=2,
                          straggler=FixedFractionStragglers(0.25, seed=3))
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0]
        assert any(h["stragglers"] > 0 for h in out["history"])

    def test_decode_error_logged_matches_theory_scale(self):
        model = tiny_model()
        tr = make_trainer(model, steps=4, code="frc", s=2,
                          straggler=FixedFractionStragglers(0.25, seed=5))
        out = tr.run()
        errs = [h["decode_err"] for h in out["history"]]
        assert all(0 <= e <= 1 for e in errs)


class TestStalenessPipelining:
    """docs/architecture.md §10: stale-weighted decode overlap."""

    def test_staleness_zero_weight_stream_bitwise_synchronous(self):
        """staleness=0 IS the synchronous mode — the applied per-step
        weight stream matches the default trainer bit for bit."""
        model = tiny_model()
        a = make_trainer(model, steps=5, code="bgc",
                         straggler=FixedFractionStragglers(0.25, seed=3))
        a.run()
        b = make_trainer(model, steps=5, code="bgc", staleness=0,
                         straggler=FixedFractionStragglers(0.25, seed=3))
        b.run()
        assert len(a.weight_log) == len(b.weight_log) == 5
        for wa, wb in zip(a.weight_log, b.weight_log):
            np.testing.assert_array_equal(wa, wb)

    def test_staleness_one_applies_previous_steps_weights(self):
        """Step t applies the decode of step t-1's mask re-masked by
        step t's stragglers; step 0 warm-starts from all-alive."""
        model = tiny_model()
        tr = make_trainer(model, steps=5, code="bgc", staleness=1,
                          straggler=FixedFractionStragglers(0.25, seed=5))
        tr.run()
        ref = make_trainer(model, code="bgc")      # same seed -> same code
        np.testing.assert_array_equal(ref.code.G, tr.code.G)
        sampler = FixedFractionStragglers(0.25, seed=5)
        masks = [sampler.sample(t, 8) for t in range(5)]
        for t in range(5):
            prev = np.ones(8, bool) if t == 0 else masks[t - 1]
            want = ref.decode_weights_for(prev) * masks[t]
            np.testing.assert_array_equal(tr.weight_log[t], want)

    def test_staleness_flush_on_recode_and_set_decoder(self):
        """Elastic re-codes and decoder switches drop in-flight stale
        weights; the next step warm-starts against the NEW code."""
        from repro.control.policy import Action

        model = tiny_model()
        strag = FixedFractionStragglers(0.25, seed=7)
        tr = make_trainer(model, steps=2, code="bgc", staleness=1,
                          straggler=strag)
        out = tr.run()
        assert tr._pending_w is not None and len(tr._pending_w) == 1
        tr._apply_action(Action(kind="set_decoder", value="onestep"))
        assert tr._pending_w is None               # decoder switch flushes
        tr._build_code(6)                          # elastic re-code path
        tr._step_fn = tr._make_step_fn()
        assert tr._pending_w is None               # rebuild flushes too
        out = tr.run(state=out["state"], start_step=2, steps=1)
        # step 2 warm-started: all-alive decode of the NEW 6-worker code
        m2 = strag.sample(2, 6)
        want = tr.decode_weights_for(np.ones(6, bool)) * m2
        np.testing.assert_array_equal(tr.weight_log[2], want)
        assert all(np.isfinite(h["mean_ce"]) for h in tr.history)

    def test_staleness_validation(self):
        model = tiny_model()
        with pytest.raises(ValueError):
            make_trainer(model, staleness=-1)


class TestCheckpointRestart:
    def test_resume_bitexact(self, tmp_path):
        model = tiny_model()
        d = str(tmp_path / "ckpt")
        # run 6 steps with checkpoint every 3
        tr1 = make_trainer(model, steps=6, ckpt_dir=d, ckpt_every=3)
        tr1.run()
        # fresh trainer restores step-6 state and continues to 9
        tr2 = make_trainer(model, steps=6, ckpt_dir=d, ckpt_every=3)
        state = tr2.init_state()
        state, start = tr2.maybe_restore(state)
        assert start == 6
        out2 = tr2.run(state=state, start_step=start, steps=3)
        # compare to an uninterrupted 9-step run
        tr3 = make_trainer(model, steps=9)
        out3 = tr3.run()
        p_resumed = jax.tree_util.tree_leaves(out2["state"]["params"])
        p_straight = jax.tree_util.tree_leaves(out3["state"]["params"])
        for a, b in zip(p_resumed, p_straight):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestElasticity:
    def test_shrink_on_fault_and_keep_training(self):
        model = tiny_model()
        faults = FaultInjector([FaultPlan(step=3, workers=(6, 7))])
        tr = make_trainer(model, steps=8, code="bgc", faults=faults)
        out = tr.run()
        ns = [h["n_workers"] for h in out["history"]]
        assert ns[0] == 8 and ns[-1] == 6
        assert all(np.isfinite(h["mean_ce"]) for h in out["history"])


class TestCompression:
    def test_int8_roundtrip_error_small(self):
        from repro.optim.compress import fake_quantize_int8
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 0.01,
                        jnp.float32)
        y = fake_quantize_int8(x)
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.01

    def test_training_with_compression_learns(self):
        model = tiny_model()
        tr = make_trainer(model, steps=12,
                          opt=OptConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=50, compress="int8"))
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0]


class TestServing:
    def test_generate_batch(self):
        model = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        from repro.serving import ServingEngine
        eng = ServingEngine(model, params, batch_slots=2, cache_len=32)
        prompts = [np.array([1, 2, 3, 4], np.int32),
                   np.array([5, 6, 7, 8], np.int32)]
        outs = eng.generate_batch(prompts, max_new=4)
        assert len(outs) == 2 and all(len(o) == 4 for o in outs)
        assert all(0 <= t < model.cfg.padded_vocab for o in outs for t in o)
