"""Integration tests: coded training loop, fused-vs-master-decode
equivalence, checkpoint/restart, elasticity, compression."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as CFG
from repro.models import build_model
from repro.optim import OptConfig
from repro.runtime import (FaultInjector, FaultPlan,
                           FixedFractionStragglers)
from repro.training import (CodedTrainConfig, CodedTrainer,
                            explicit_master_decode_grads)

pytestmark = pytest.mark.slow  # training e2e: jit + multi-step loops


def tiny_model():
    cfg = CFG.get_config("minicpm-2b", smoke=True)
    return build_model(cfg)


def make_trainer(model, straggler=None, faults=None, **kw):
    defaults = dict(code="frc", n_workers=8, s=2, decoder="onestep",
                    rows_per_slot=1, seq_len=16, steps=6, seed=0,
                    opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                    log_every=1)
    defaults.update(kw)
    return CodedTrainer(model, CodedTrainConfig(**defaults),
                        straggler_model=straggler, fault_injector=faults)


class TestFusedDecodeEquivalence:
    """docs/architecture.md §2.1: loss-reweighted all-reduce == explicit master decode."""

    @pytest.mark.parametrize("code,decoder", [
        ("frc", "onestep"), ("bgc", "onestep"),
        ("frc", "optimal"), ("bgc", "optimal"),
    ])
    def test_grads_identical(self, code, decoder):
        model = tiny_model()
        tr = make_trainer(model, code=code, decoder=decoder,
                          exact_decode_renorm=False)
        params = model.init(jax.random.PRNGKey(0))
        mask = np.ones(8, dtype=bool)
        mask[[1, 5]] = False
        # explicit: per-worker partials, decoded on the 'master'
        explicit, w = explicit_master_decode_grads(model, params, tr, 0, mask)
        # fused: one loss-reweighted grad
        batch_np = tr.pipeline.batch_for_step(0, w)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        fused = jnp.concatenate([g.reshape(-1).astype(jnp.float32)
                                 for g in jax.tree_util.tree_leaves(grads)])
        np.testing.assert_allclose(np.asarray(fused), np.asarray(explicit),
                                   rtol=5e-4, atol=5e-6)

    def test_no_stragglers_equals_uncoded_gradient(self):
        """With zero stragglers and an exact-decoding code, the coded
        gradient equals the plain uncoded gradient over unique data."""
        model = tiny_model()
        tr = make_trainer(model, code="frc", decoder="optimal",
                          exact_decode_renorm=False)
        params = model.init(jax.random.PRNGKey(1))
        mask = np.ones(8, dtype=bool)
        w = tr.decode_weights_for(mask)
        v = tr.code.G @ w
        np.testing.assert_allclose(v, 1.0, atol=1e-7)  # exact decode
        coded_np = tr.pipeline.batch_for_step(0, w)
        uncoded_np = tr.pipeline.uncoded_batch_for_step(0)
        g_coded = jax.grad(lambda p: model.loss_fn(
            p, {k: jnp.asarray(x) for k, x in coded_np.items()})[0])(params)
        g_ref = jax.grad(lambda p: model.loss_fn(
            p, {k: jnp.asarray(x) for k, x in uncoded_np.items()})[0])(params)
        for a, b in zip(jax.tree_util.tree_leaves(g_coded),
                        jax.tree_util.tree_leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-4, atol=5e-6)


class TestTrainerLoop:
    def test_loss_decreases_no_stragglers(self):
        model = tiny_model()
        tr = make_trainer(model, steps=16, code="uncoded", s=1)
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0], f"no learning: {losses}"
        assert all(np.isfinite(l) for l in losses)

    def test_coded_training_with_stragglers_learns(self):
        model = tiny_model()
        tr = make_trainer(model, steps=16, code="frc", s=2,
                          straggler=FixedFractionStragglers(0.25, seed=3))
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0]
        assert any(h["stragglers"] > 0 for h in out["history"])

    def test_decode_error_logged_matches_theory_scale(self):
        model = tiny_model()
        tr = make_trainer(model, steps=4, code="frc", s=2,
                          straggler=FixedFractionStragglers(0.25, seed=5))
        out = tr.run()
        errs = [h["decode_err"] for h in out["history"]]
        assert all(0 <= e <= 1 for e in errs)


class TestCheckpointRestart:
    def test_resume_bitexact(self, tmp_path):
        model = tiny_model()
        d = str(tmp_path / "ckpt")
        # run 6 steps with checkpoint every 3
        tr1 = make_trainer(model, steps=6, ckpt_dir=d, ckpt_every=3)
        tr1.run()
        # fresh trainer restores step-6 state and continues to 9
        tr2 = make_trainer(model, steps=6, ckpt_dir=d, ckpt_every=3)
        state = tr2.init_state()
        state, start = tr2.maybe_restore(state)
        assert start == 6
        out2 = tr2.run(state=state, start_step=start, steps=3)
        # compare to an uninterrupted 9-step run
        tr3 = make_trainer(model, steps=9)
        out3 = tr3.run()
        p_resumed = jax.tree_util.tree_leaves(out2["state"]["params"])
        p_straight = jax.tree_util.tree_leaves(out3["state"]["params"])
        for a, b in zip(p_resumed, p_straight):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


class TestElasticity:
    def test_shrink_on_fault_and_keep_training(self):
        model = tiny_model()
        faults = FaultInjector([FaultPlan(step=3, workers=(6, 7))])
        tr = make_trainer(model, steps=8, code="bgc", faults=faults)
        out = tr.run()
        ns = [h["n_workers"] for h in out["history"]]
        assert ns[0] == 8 and ns[-1] == 6
        assert all(np.isfinite(h["mean_ce"]) for h in out["history"])


class TestCompression:
    def test_int8_roundtrip_error_small(self):
        from repro.optim.compress import fake_quantize_int8
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 0.01,
                        jnp.float32)
        y = fake_quantize_int8(x)
        rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
        assert rel < 0.01

    def test_training_with_compression_learns(self):
        model = tiny_model()
        tr = make_trainer(model, steps=12,
                          opt=OptConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=50, compress="int8"))
        out = tr.run()
        losses = [h["mean_ce"] for h in out["history"]]
        assert losses[-1] < losses[0]


class TestServing:
    def test_generate_batch(self):
        model = tiny_model()
        params = model.init(jax.random.PRNGKey(0))
        from repro.serving import ServingEngine
        eng = ServingEngine(model, params, batch_slots=2, cache_len=32)
        prompts = [np.array([1, 2, 3, 4], np.int32),
                   np.array([5, 6, 7, 8], np.int32)]
        outs = eng.generate_batch(prompts, max_new=4)
        assert len(outs) == 2 and all(len(o) == 4 for o in outs)
        assert all(0 <= t < model.cfg.padded_vocab for o in outs for t in o)
