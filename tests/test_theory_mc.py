"""Monte-Carlo validation of the paper's closed-form theorems.

Two generations of suite live here:

  * the original per-theorem scalar-loop classes (TestTheorem5 ...
    TestExpanderBaseline), kept as-is;
  * the PR-10 batched suite (TestFundamentalLowerBound,
    TestSpectralCertificateMC, TestBatchedUpperBounds) driving
    DecodeEngine.decode_batch over a pinned (k, s, r) grid, plus
    TestExportedBoundCoverage — a completeness gate asserting EVERY
    export of repro.core.theory is classified and MC-validated, so a
    new closed form cannot land untested.

Tolerances: two-sided closed-form matches use relative tolerances
sized to the MC noise at the pinned B (documented per test); lower-
bound dominance checks allow 4 standard errors of downward noise plus
a 1e-3 absolute floor, because FRC sits EXACTLY on the fundamental
limit (the bound is achieved, so its MC mean fluctuates around the
bound, and rare-event cells can see zero error events at feasible B).
Seeds are pinned — these are regression tests, not statistical
hypothesis tests.
"""

import math

import numpy as np
import pytest

from repro.core import codes as C
from repro.core import decoding as D
from repro.core import registry
from repro.core import simulate as S
from repro.core import theory as T
from repro.core.certify import certify
from repro.core.engine import DecodeEngine


RNG = lambda seed=0: np.random.default_rng(seed)


def fixed_r_masks(n: int, r: int, B: int, rng) -> np.ndarray:
    """[B, n] bool, exactly r survivors per row (uniform over masks)."""
    return rng.random((B, n)).argsort(axis=1) < r


def iid_masks(n: int, delta: float, B: int, rng) -> np.ndarray:
    """[B, n] bool, each worker survives independently w.p. 1 - delta."""
    return rng.random((B, n)) >= delta


class TestTheorem5:
    """E[err_1(A_frac)] closed form vs Monte Carlo.

    NOTE: the paper's Lemma 4 uses P(duplicate) = (s-1)/k; the exact
    without-replacement probability is (s-1)/(k-1).  MC matches the
    corrected closed form (thm5_expected_err1_frc_exact); the paper's
    formula is its k->inf limit (off by Theta(1) at k=100).
    """

    @pytest.mark.parametrize("delta,s", [(0.1, 5), (0.3, 5), (0.5, 10)])
    def test_mc_matches_exact_closed_form(self, delta, s):
        k = 100
        r = int(round((1 - delta) * k))
        rng = RNG(42)
        code = C.frc(k=k, n=k, s=s)
        trials = 3000
        acc = 0.0
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        expected = T.thm5_expected_err1_frc_exact(k, s, r)
        assert mc == pytest.approx(expected, rel=0.08, abs=0.05)

    def test_paper_formula_gap_characterized(self):
        """The stated Thm-5 formula understates the exact expectation by an
        additive term k(r-1)(s-1)/(r s (k-1)) -> (s-1)/s; the *relative*
        error vanishes as k grows (the formula is correct to leading
        order)."""
        s, delta = 5, 0.2
        for k in [100, 1000, 10000]:
            r = int((1 - delta) * k)
            exact = T.thm5_expected_err1_frc_exact(k, s, r)
            paper = T.thm5_expected_err1_frc(k, s, delta)
            gap = exact - paper
            predicted_gap = k * (r - 1) * (s - 1) / (r * s * (k - 1))
            assert gap == pytest.approx(predicted_gap, rel=1e-9)
            assert gap == pytest.approx((s - 1) / s, abs=0.01)
        # relative error vanishes
        r = int((1 - delta) * 10000)
        assert (T.thm5_expected_err1_frc_exact(10000, s, r)
                - T.thm5_expected_err1_frc(10000, s, delta)) \
            / T.thm5_expected_err1_frc_exact(10000, s, r) < 0.01


class TestTheorem6:
    @pytest.mark.parametrize("delta,s", [(0.2, 5), (0.4, 10)])
    def test_mc_matches_closed_form(self, delta, s):
        k = 100
        r = int(round((1 - delta) * k))
        rng = RNG(7)
        code = C.frc(k=k, n=k, s=s)
        trials = 4000
        acc = 0.0
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err(code.G[:, mask])
        mc = acc / trials
        expected = T.thm6_expected_err_frc(k, s, r)
        assert mc == pytest.approx(expected, rel=0.2, abs=0.05)

    def test_distribution_sums_to_one(self):
        pmf = T.frc_err_distribution(k=100, s=5, r=70)
        assert pmf.sum() == pytest.approx(1.0, abs=1e-9)
        # expected value from pmf must equal Thm 6 / s
        mean_blocks = float((np.arange(len(pmf)) * pmf).sum())
        assert mean_blocks * 5 == pytest.approx(
            T.thm6_expected_err_frc(100, 5, 70), rel=1e-9)


class TestTheorem7and8:
    def test_tail_bound_holds_empirically(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(3)
        code = C.frc(k=k, n=k, s=s)
        trials = 2000
        for alpha in [0, 1, 2]:
            bound = T.thm7_tail_frc(k, s, r, alpha)
            emp = 0
            for _ in range(trials):
                mask = S.sample_straggler_mask(k, k - r, rng)
                if D.err(code.G[:, mask]) > alpha * s + 1e-9:
                    emp += 1
            assert emp / trials <= bound + 0.02

    def test_thm8_threshold_implies_small_tail(self):
        k, delta, alpha = 100, 0.3, 1
        s_star = T.thm8_s_threshold(k, delta, alpha)
        # the smallest admissible FRC s above the threshold (s | k)
        s = next(x for x in range(math.ceil(s_star), k) if k % x == 0)
        r = int((1 - delta) * k)
        assert T.thm7_tail_frc(k, s, r, alpha) <= 1 / k + 1e-12

    def test_cor9_zero_error_probability(self):
        k, delta = 100, 0.2
        s_star = T.cor9_s_zero_error(k, delta)
        s = next(x for x in range(math.ceil(s_star), k) if k % x == 0)
        r = int((1 - delta) * k)
        rng = RNG(5)
        code = C.frc(k=k, n=k, s=s)
        fails = 0
        trials = 1000
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            if D.err(code.G[:, mask]) > 1e-9:
                fails += 1
        assert fails / trials <= 1 / k + 0.01


class TestLemma4:
    def test_gram_expectations(self):
        k, s = 60, 6
        rng = RNG(9)
        code = C.frc(k=k, n=k, s=s)
        diag_exp, off_exp = T.lemma4_expected_gram_frc(k, s)
        trials = 4000
        acc_d = acc_o = 0.0
        for _ in range(trials):
            cols = rng.choice(k, size=2, replace=False)
            a_i, a_j = code.G[:, cols[0]], code.G[:, cols[1]]
            acc_d += a_i @ a_i
            acc_o += a_i @ a_j
        assert acc_d / trials == pytest.approx(diag_exp, rel=1e-9)
        assert acc_o / trials == pytest.approx(off_exp, rel=0.25, abs=0.05)


class TestBGCTheory:
    def test_exact_expected_err1(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(11)
        trials = 1500
        acc = 0.0
        for _ in range(trials):
            code = C.bgc(k=k, n=k, s=s, rng=rng)
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        expected = T.expected_err1_bgc_exact(k, s, r)
        assert mc == pytest.approx(expected, rel=0.06)

    def test_thm21_bound_shape(self):
        """Calibrate C from one (k, s) and check the k/((1-d)s) scaling
        predicts other settings within a constant factor."""
        rng = RNG(13)

        def mc(k, s, delta, trials=400):
            r = int((1 - delta) * k)
            acc = 0.0
            for _ in range(trials):
                code = C.bgc(k=k, n=k, s=s, rng=rng)
                mask = S.sample_straggler_mask(k, k - r, rng)
                acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
            return acc / trials

        base = mc(100, 8, 0.2)
        c2 = base * (1 - 0.2) * 8 / 100  # implied C^2
        for (k, s, delta) in [(200, 8, 0.2), (100, 16, 0.2), (100, 8, 0.5)]:
            pred = T.thm21_bgc_err1_bound(k, s, delta, c=np.sqrt(c2))
            got = mc(k, s, delta)
            assert got <= 3.0 * pred  # bound within small constant factor
            assert got >= pred / 3.0  # and the scaling is tight-ish


class TestRBGC:
    def test_thm24_applies_below_log_k(self):
        """rBGC keeps err_1 = O(k/((1-delta) s)) even for s < log k, where
        the unregularized BGC concentration can fail."""
        k, s, delta = 256, 2, 0.2  # log k ~ 5.5 > s
        r = int((1 - delta) * k)
        rng = RNG(17)
        trials = 400
        acc = 0.0
        for _ in range(trials):
            code = C.rbgc(k=k, n=k, s=s, rng=rng)
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        # Thm 24 with a modest constant; the point is O(k/s) not O(k)
        assert mc <= 6.0 * k / ((1 - delta) * s)


class TestExpanderBaseline:
    def test_thm3_bound_holds_for_random_regular(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(19)
        code = C.sregular(k=k, n=k, s=s, rng=rng)
        lam = C.spectral_gap(code)
        bound = T.thm3_expander_err1_bound(k, s, delta, lam)
        worst = 0.0
        for _ in range(300):
            mask = S.sample_straggler_mask(k, k - r, rng)
            worst = max(worst, D.err1(code.G[:, mask], D.default_rho(k, r, s)))
        assert worst <= bound + 1e-6


# --------------------------------------------------------------------------
# PR 10: batched MC over a pinned grid (DecodeEngine.decode_batch)
# --------------------------------------------------------------------------

# the pinned validation grid: (k, s, r) with k = n.  Chosen so every
# registry family is constructible (s | k for frc, k*s even for
# sregular) and the straggler fractions span light (0.25) to heavy (0.5).
GRID = ((64, 4, 48), (64, 8, 48), (100, 5, 70), (100, 10, 50))

# ragged bipartite points (k != n) for the families that support them
RAGGED = (("expander", 96, 64, 6), ("sbm", 60, 40, 6), ("bgc", 80, 50, 8))


def _best_decoder(fam) -> str:
    return "optimal" if fam.supports_decoder("optimal") else "onestep"


def _mc_mean_err(code, r: int, decoder: str, seed: int, B: int = 1500):
    """(mean, sem) of batched-decode error over B uniform fixed-r masks."""
    masks = fixed_r_masks(code.n, r, B, RNG(seed))
    errs = DecodeEngine(code).decode_batch(masks, decoder).errors
    return float(errs.mean()), float(errs.std(ddof=1) / math.sqrt(len(errs)))


class TestFundamentalLowerBound:
    """fundamental_err_lower_bound is a true LOWER bound: every family's
    measured error dominates it, and FRC + optimal decoding ACHIEVES it
    (the bound is tight, which is what makes gap_to_optimal = 1 mean
    something)."""

    @pytest.mark.parametrize("k,s,r", GRID)
    def test_frc_optimal_sits_exactly_on_the_bound(self, k, s, r):
        assert T.thm6_expected_err_frc(k, s, r) == pytest.approx(
            T.fundamental_err_lower_bound(k, s, r), rel=1e-12)

    @pytest.mark.parametrize("family", sorted(f.name for f in
                                              registry.families()))
    @pytest.mark.parametrize("k,s,r", GRID)
    def test_every_family_dominates_the_bound(self, family, k, s, r):
        fam = registry.get(family)
        if fam.check(k, k, s) is not None:
            pytest.skip(f"{family} not constructible at (k={k}, s={s})")
        s_eff = 1 if family == "uncoded" else s
        lb = T.fundamental_err_lower_bound(k, s_eff, r)
        code = fam.make(k=k, n=k, s=s, seed=1)
        mc, sem = _mc_mean_err(code, r, _best_decoder(fam), seed=k + s + r)
        # FRC/uncoded sit EXACTLY on the bound, so the MC mean
        # fluctuates around it — allow 4 standard errors of downward
        # noise plus 1e-3 absolute (covers rare-event cells like FRC at
        # (64, 8): LB ~ 2e-4 means ~0 block-death events in B = 1500
        # masks, so the mean alone carries no signal there); every
        # other family clears the bound with real margin
        assert mc + 4.0 * sem + 1e-3 >= lb, (mc, sem, lb)

    @pytest.mark.parametrize("family,k,n,s", RAGGED)
    def test_ragged_bipartite_dominates_the_bound(self, family, k, n, s):
        r = int(round(0.75 * n))
        lb = T.fundamental_err_lower_bound(k, s, r, n)
        code = registry.make(family, k=k, n=n, s=s, seed=2)
        mc, sem = _mc_mean_err(code, r, "optimal", seed=k + n + s)
        assert mc + 4.0 * sem + 1e-3 >= lb, (mc, sem, lb)

    @pytest.mark.parametrize("family", ("bgc", "expander", "frc"))
    def test_load_form_under_iid_straggling(self, family):
        """The normalized-load form bounds iid-Bernoulli straggling (the
        masks the ClusterSim deadline policies actually produce)."""
        k = s = None
        k, s, delta = 64, 4, 0.3
        fam = registry.get(family)
        lb = T.fundamental_err_lower_bound_load(k, s, delta)
        code = fam.make(k=k, n=k, s=s, seed=3)
        masks = iid_masks(k, delta, 2000, RNG(23))
        errs = DecodeEngine(code).decode_batch(
            masks, _best_decoder(fam)).errors
        sem = float(errs.std(ddof=1) / math.sqrt(len(errs)))
        assert float(errs.mean()) + 4.0 * sem + 1e-3 >= lb

    @pytest.mark.parametrize("k,s,r", GRID)
    def test_hypergeometric_form_is_tighter_than_load_form(self, k, s, r):
        """At matched mean load delta = 1 - r/n the fixed-r bound is the
        smaller one: C(n-d, r)/C(n, r) <= (1 - r/n)**d, so each form is
        only valid under its own straggler model (fixed count vs iid)."""
        assert (T.fundamental_err_lower_bound(k, s, r)
                <= T.fundamental_err_lower_bound_load(k, s, 1 - r / k) + 1e-12)

    def test_monotone_in_s_and_survivors(self):
        # non-increasing in s (more replication can only help) and
        # non-increasing in r = SURVIVORS, i.e. non-decreasing in the
        # number of stragglers (this repo's r counts survivors; papers
        # that write "non-decreasing in r" count stragglers)
        for s1, s2 in ((2, 4), (4, 8)):
            assert (T.fundamental_err_lower_bound(64, s2, 48)
                    <= T.fundamental_err_lower_bound(64, s1, 48))
        for r1, r2 in ((32, 48), (48, 56)):
            assert (T.fundamental_err_lower_bound(64, 4, r2)
                    <= T.fundamental_err_lower_bound(64, 4, r1))

    def test_gap_to_optimal_helper(self):
        lb = T.fundamental_err_lower_bound(64, 4, 48)
        assert T.gap_to_optimal(2 * lb, 64, 4, r=48) == pytest.approx(2.0)
        assert T.gap_to_optimal(0.0, 64, 4, delta=0.0) == 1.0
        assert math.isinf(T.gap_to_optimal(0.5, 64, 4, delta=0.0))
        with pytest.raises(ValueError):
            T.gap_to_optimal(1.0, 64, 4)  # needs exactly one of r/delta
        with pytest.raises(ValueError):
            T.gap_to_optimal(1.0, 64, 4, r=48, delta=0.2)


class TestSpectralCertificateMC:
    """certify() emits a WORST-CASE bound: no sampled mask — one-step or
    optimal decoding — may exceed it, at square or ragged sizes."""

    @pytest.mark.parametrize("family,k,n,s",
                             (("sregular", 64, 64, 6),) + RAGGED)
    @pytest.mark.parametrize("delta", (0.125, 0.25))
    def test_certificate_dominates_sampled_worst_case(self, family, k, n,
                                                      s, delta):
        code = registry.make(family, k=k, n=n, s=s, seed=1)
        cert = certify(code)
        r = int(round((1 - delta) * n))
        masks = fixed_r_masks(n, r, 400, RNG(29))
        eng = DecodeEngine(code)
        bound = cert.err1_bound(delta)
        for decoder in ("onestep", "optimal"):
            worst = float(eng.decode_batch(masks, decoder).errors.max())
            assert worst <= bound + 1e-8, (decoder, worst, bound)

    def test_reduces_to_thm3_for_biregular_square(self):
        code = registry.make("sregular", k=64, n=64, s=6, seed=5)
        cert = certify(code)
        assert cert.irregularity == pytest.approx(0.0, abs=1e-9)
        for delta in (0.1, 0.25, 0.4):
            assert cert.err1_bound(delta) == pytest.approx(
                T.thm3_expander_err1_bound(64, 6, delta, cert.lam), rel=1e-9)


class TestBatchedUpperBounds:
    """The paper's in-expectation forms re-validated through the batched
    engine (the scalar loops above validate the same identities; this
    proves the engine path the benchmarks and the policy bands use)."""

    @pytest.mark.parametrize("k,s,r", GRID)
    def test_bgc_exact_err1_matches_batched_mc(self, k, s, r):
        rng = RNG(31)
        acc, draws = 0.0, 25
        for _ in range(draws):
            code = C.bgc(k=k, n=k, s=s, rng=rng)
            masks = fixed_r_masks(k, r, 120, rng)
            acc += float(DecodeEngine(code).decode_batch(
                masks, "onestep").errors.mean())
        assert acc / draws == pytest.approx(
            T.expected_err1_bgc_exact(k, s, r), rel=0.1)

    @pytest.mark.parametrize("k,s,r", GRID[:2])
    def test_frc_exact_err1_matches_batched_mc(self, k, s, r):
        code = C.frc(k=k, n=k, s=s)
        masks = fixed_r_masks(k, r, 3000, RNG(37))
        mc = float(DecodeEngine(code).decode_batch(
            masks, "onestep").errors.mean())
        assert mc == pytest.approx(
            T.thm5_expected_err1_frc_exact(k, s, r), rel=0.1, abs=0.05)

    def test_thm10_adversarial_worst_case_exact(self):
        """Theorem 10: kill whole FRC blocks (the block adversary) and
        optimal decoding loses exactly the straggled blocks."""
        k, s, r = 64, 4, 48  # k - r = 16 stragglers = 4 whole blocks
        code = C.frc(k=k, n=k, s=s)
        mask = np.ones((1, k), dtype=bool)
        mask[0, : k - r] = False  # first 4 blocks fully straggled
        err = float(DecodeEngine(code).decode_batch(
            mask, "optimal").errors[0])
        assert err == pytest.approx(T.thm10_frc_worstcase_err(k, r),
                                    rel=1e-9)


class TestExportedBoundCoverage:
    """Every export of repro.core.theory is classified below and each
    class has an MC-validating test in this file; a new export fails
    this gate until it is classified AND tested."""

    EXACT = {  # two-sided: MC mean must MATCH (not just bound)
        "thm5_expected_err1_frc_exact",  # TestTheorem5 + batched FRC
        "thm6_expected_err_frc",         # TestTheorem6 (+ LB equality)
        "lemma4_expected_gram_frc",      # TestLemma4
        "expected_err1_bgc_exact",       # TestBGCTheory + batched
        "thm10_frc_worstcase_err",       # TestBatchedUpperBounds (adv.)
    }
    ASYMPTOTIC = {  # stated k->inf forms; MC-tested via exact sibling
        "thm5_expected_err1_frc",        # TestTheorem5 (gap characterized)
    }
    ERRATA = {  # the paper's printed (incorrect) form, kept for E14
        "thm6_expected_err_frc_as_printed",
    }
    UPPER = {  # one-sided: MC must stay below
        "thm7_tail_frc",                 # TestTheorem7and8
        "thm3_expander_err1_bound",      # TestExpanderBaseline + certify
        "thm21_bgc_err1_bound",          # TestBGCTheory (calibrated C)
        "thm24_rbgc_err1_bound",         # TestRBGC (calibrated C)
    }
    LOWER = {  # one-sided: MC must stay above
        "fundamental_err_lower_bound",       # TestFundamentalLowerBound
        "fundamental_err_lower_bound_load",  # (load form, iid masks)
    }
    THRESHOLD = {  # s-thresholds implying a tail bound, checked via thm7
        "thm8_s_threshold",              # TestTheorem7and8
        "cor9_s_zero_error",
    }
    DERIVED = {  # ratios/helpers over the bounds above
        "gap_to_optimal",                # TestFundamentalLowerBound
    }

    def test_every_export_is_classified_and_validated(self):
        classified = (self.EXACT | self.ASYMPTOTIC | self.ERRATA
                      | self.UPPER | self.LOWER | self.THRESHOLD
                      | self.DERIVED)
        assert classified == set(T.__all__), (
            "unclassified/stale theory exports: "
            f"{sorted(classified ^ set(T.__all__))} — add an MC test and "
            "classify the export here")
