"""Monte-Carlo validation of the paper's closed-form theorems."""

import math

import numpy as np
import pytest

from repro.core import codes as C
from repro.core import decoding as D
from repro.core import simulate as S
from repro.core import theory as T


RNG = lambda seed=0: np.random.default_rng(seed)


class TestTheorem5:
    """E[err_1(A_frac)] closed form vs Monte Carlo.

    NOTE: the paper's Lemma 4 uses P(duplicate) = (s-1)/k; the exact
    without-replacement probability is (s-1)/(k-1).  MC matches the
    corrected closed form (thm5_expected_err1_frc_exact); the paper's
    formula is its k->inf limit (off by Theta(1) at k=100).
    """

    @pytest.mark.parametrize("delta,s", [(0.1, 5), (0.3, 5), (0.5, 10)])
    def test_mc_matches_exact_closed_form(self, delta, s):
        k = 100
        r = int(round((1 - delta) * k))
        rng = RNG(42)
        code = C.frc(k=k, n=k, s=s)
        trials = 3000
        acc = 0.0
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        expected = T.thm5_expected_err1_frc_exact(k, s, r)
        assert mc == pytest.approx(expected, rel=0.08, abs=0.05)

    def test_paper_formula_gap_characterized(self):
        """The stated Thm-5 formula understates the exact expectation by an
        additive term k(r-1)(s-1)/(r s (k-1)) -> (s-1)/s; the *relative*
        error vanishes as k grows (the formula is correct to leading
        order)."""
        s, delta = 5, 0.2
        for k in [100, 1000, 10000]:
            r = int((1 - delta) * k)
            exact = T.thm5_expected_err1_frc_exact(k, s, r)
            paper = T.thm5_expected_err1_frc(k, s, delta)
            gap = exact - paper
            predicted_gap = k * (r - 1) * (s - 1) / (r * s * (k - 1))
            assert gap == pytest.approx(predicted_gap, rel=1e-9)
            assert gap == pytest.approx((s - 1) / s, abs=0.01)
        # relative error vanishes
        r = int((1 - delta) * 10000)
        assert (T.thm5_expected_err1_frc_exact(10000, s, r)
                - T.thm5_expected_err1_frc(10000, s, delta)) \
            / T.thm5_expected_err1_frc_exact(10000, s, r) < 0.01


class TestTheorem6:
    @pytest.mark.parametrize("delta,s", [(0.2, 5), (0.4, 10)])
    def test_mc_matches_closed_form(self, delta, s):
        k = 100
        r = int(round((1 - delta) * k))
        rng = RNG(7)
        code = C.frc(k=k, n=k, s=s)
        trials = 4000
        acc = 0.0
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err(code.G[:, mask])
        mc = acc / trials
        expected = T.thm6_expected_err_frc(k, s, r)
        assert mc == pytest.approx(expected, rel=0.2, abs=0.05)

    def test_distribution_sums_to_one(self):
        pmf = T.frc_err_distribution(k=100, s=5, r=70)
        assert pmf.sum() == pytest.approx(1.0, abs=1e-9)
        # expected value from pmf must equal Thm 6 / s
        mean_blocks = float((np.arange(len(pmf)) * pmf).sum())
        assert mean_blocks * 5 == pytest.approx(
            T.thm6_expected_err_frc(100, 5, 70), rel=1e-9)


class TestTheorem7and8:
    def test_tail_bound_holds_empirically(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(3)
        code = C.frc(k=k, n=k, s=s)
        trials = 2000
        for alpha in [0, 1, 2]:
            bound = T.thm7_tail_frc(k, s, r, alpha)
            emp = 0
            for _ in range(trials):
                mask = S.sample_straggler_mask(k, k - r, rng)
                if D.err(code.G[:, mask]) > alpha * s + 1e-9:
                    emp += 1
            assert emp / trials <= bound + 0.02

    def test_thm8_threshold_implies_small_tail(self):
        k, delta, alpha = 100, 0.3, 1
        s_star = T.thm8_s_threshold(k, delta, alpha)
        # the smallest admissible FRC s above the threshold (s | k)
        s = next(x for x in range(math.ceil(s_star), k) if k % x == 0)
        r = int((1 - delta) * k)
        assert T.thm7_tail_frc(k, s, r, alpha) <= 1 / k + 1e-12

    def test_cor9_zero_error_probability(self):
        k, delta = 100, 0.2
        s_star = T.cor9_s_zero_error(k, delta)
        s = next(x for x in range(math.ceil(s_star), k) if k % x == 0)
        r = int((1 - delta) * k)
        rng = RNG(5)
        code = C.frc(k=k, n=k, s=s)
        fails = 0
        trials = 1000
        for _ in range(trials):
            mask = S.sample_straggler_mask(k, k - r, rng)
            if D.err(code.G[:, mask]) > 1e-9:
                fails += 1
        assert fails / trials <= 1 / k + 0.01


class TestLemma4:
    def test_gram_expectations(self):
        k, s = 60, 6
        rng = RNG(9)
        code = C.frc(k=k, n=k, s=s)
        diag_exp, off_exp = T.lemma4_expected_gram_frc(k, s)
        trials = 4000
        acc_d = acc_o = 0.0
        for _ in range(trials):
            cols = rng.choice(k, size=2, replace=False)
            a_i, a_j = code.G[:, cols[0]], code.G[:, cols[1]]
            acc_d += a_i @ a_i
            acc_o += a_i @ a_j
        assert acc_d / trials == pytest.approx(diag_exp, rel=1e-9)
        assert acc_o / trials == pytest.approx(off_exp, rel=0.25, abs=0.05)


class TestBGCTheory:
    def test_exact_expected_err1(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(11)
        trials = 1500
        acc = 0.0
        for _ in range(trials):
            code = C.bgc(k=k, n=k, s=s, rng=rng)
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        expected = T.expected_err1_bgc_exact(k, s, r)
        assert mc == pytest.approx(expected, rel=0.06)

    def test_thm21_bound_shape(self):
        """Calibrate C from one (k, s) and check the k/((1-d)s) scaling
        predicts other settings within a constant factor."""
        rng = RNG(13)

        def mc(k, s, delta, trials=400):
            r = int((1 - delta) * k)
            acc = 0.0
            for _ in range(trials):
                code = C.bgc(k=k, n=k, s=s, rng=rng)
                mask = S.sample_straggler_mask(k, k - r, rng)
                acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
            return acc / trials

        base = mc(100, 8, 0.2)
        c2 = base * (1 - 0.2) * 8 / 100  # implied C^2
        for (k, s, delta) in [(200, 8, 0.2), (100, 16, 0.2), (100, 8, 0.5)]:
            pred = T.thm21_bgc_err1_bound(k, s, delta, c=np.sqrt(c2))
            got = mc(k, s, delta)
            assert got <= 3.0 * pred  # bound within small constant factor
            assert got >= pred / 3.0  # and the scaling is tight-ish


class TestRBGC:
    def test_thm24_applies_below_log_k(self):
        """rBGC keeps err_1 = O(k/((1-delta) s)) even for s < log k, where
        the unregularized BGC concentration can fail."""
        k, s, delta = 256, 2, 0.2  # log k ~ 5.5 > s
        r = int((1 - delta) * k)
        rng = RNG(17)
        trials = 400
        acc = 0.0
        for _ in range(trials):
            code = C.rbgc(k=k, n=k, s=s, rng=rng)
            mask = S.sample_straggler_mask(k, k - r, rng)
            acc += D.err1(code.G[:, mask], D.default_rho(k, r, s))
        mc = acc / trials
        # Thm 24 with a modest constant; the point is O(k/s) not O(k)
        assert mc <= 6.0 * k / ((1 - delta) * s)


class TestExpanderBaseline:
    def test_thm3_bound_holds_for_random_regular(self):
        k, s, delta = 100, 10, 0.3
        r = int((1 - delta) * k)
        rng = RNG(19)
        code = C.sregular(k=k, n=k, s=s, rng=rng)
        lam = C.spectral_gap(code)
        bound = T.thm3_expander_err1_bound(k, s, delta, lam)
        worst = 0.0
        for _ in range(300):
            mask = S.sample_straggler_mask(k, k - r, rng)
            worst = max(worst, D.err1(code.G[:, mask], D.default_rho(k, r, s)))
        assert worst <= bound + 1e-6
