"""Dry-run machinery smoke test (subprocess: importing
repro.launch.dryrun forces the 512-placeholder-device world, which must
never leak into the main test process).

Exercises the grading-critical path end-to-end at smoke width: a REAL
production-shaped mesh (16x16 = 256 of the 512 host devices), build_cell
for all three step kinds, lower + compile, memory/cost analysis and the
collective-byte HLO parse — i.e. exactly what produced
artifacts/dryrun/*.json, on a config small enough for CI."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # subprocess 512-device compile dry-run

REPO = Path(__file__).resolve().parent.parent

PROG = textwrap.dedent("""
    import json
    # dryrun's first two lines set XLA_FLAGS=512 host devices BEFORE jax
    from repro.launch.dryrun import build_cell, _cost_analysis, \\
        _memory_analysis, _reduced_cfg
    import jax
    from repro.configs import get_config
    from repro.dist.sharding import rules_for, use_mesh, use_rules
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_production_mesh
    from repro.models import SHAPES, build_model

    assert jax.device_count() == 512, jax.device_count()
    cfg = get_config("starcoder2-7b", smoke=True)
    model = build_model(cfg)
    mesh = make_production_mesh()           # (data=16, model=16)
    out = {}
    for shape, micro in (("train_4k", 2), ("prefill_32k", 1),
                         ("decode_32k", 1)):
        cell = SHAPES[shape]
        with use_mesh(mesh), use_rules(rules_for(cfg)):
            fn, args, insh, outsh = build_cell(model, cell, mesh,
                                               microbatches=micro)
            comp = jax.jit(fn, in_shardings=insh,
                           out_shardings=outsh).lower(*args).compile()
        ca = _cost_analysis(comp)
        ma = _memory_analysis(comp)
        coll = RL.parse_collectives(comp.as_text())
        terms = RL.roofline_terms(ca.get("flops", 0.0),
                                  ca.get("bytes accessed", 0.0),
                                  coll.total_bytes)
        out[shape] = {
            "flops": ca.get("flops"),
            "bytes": ca.get("bytes accessed"),
            "coll": coll.total_bytes,
            "n_collectives": sum(coll.count_by_kind.values()),
            "temp": ma.get("temp_size_in_bytes"),
            "dominant": terms["dominant"],
        }
    print("RESULT:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dryrun_result():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", PROG], cwd=REPO, env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
    return json.loads(line[-1][len("RESULT:"):])


def test_all_three_step_kinds_compile(dryrun_result):
    assert set(dryrun_result) == {"train_4k", "prefill_32k", "decode_32k"}
    for shape, r in dryrun_result.items():
        assert r["flops"] and r["flops"] > 0, shape
        assert r["bytes"] and r["bytes"] > 0, shape
        assert r["temp"] is not None, shape


def test_sharded_graphs_contain_collectives(dryrun_result):
    """A 256-way TP/DP training graph without collectives would mean the
    sharding silently degenerated to replication."""
    assert dryrun_result["train_4k"]["n_collectives"] > 0
    assert dryrun_result["train_4k"]["coll"] > 0


def test_train_costs_dominate_decode(dryrun_result):
    """Ordering sanity for the roofline terms: full fwd+bwd+opt >>
    single-token decode."""
    assert dryrun_result["train_4k"]["flops"] > \
        10 * dryrun_result["decode_32k"]["flops"]
