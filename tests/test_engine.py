"""DecodeEngine: batched-vs-scalar decoder equivalence (property tests),
batched Pallas kernels in interpret mode, the mask->weights LRU cache,
and the batched Monte-Carlo path."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from repro.core import codes as C
from repro.core import decoding as D
from repro.core import simulate as S
from repro.core.engine import DecodeEngine
from repro.kernels import ops


def _code(scheme, k, s, seed):
    rng = np.random.default_rng(seed)
    if scheme == "frc":
        while k % s:
            s -= 1
        return C.frc(k, k, max(s, 1), rng=rng)
    return C.make_code(scheme, k=k, n=k, s=s, rng=rng)


def _masks(n, B, seed, frac=0.7):
    rng = np.random.default_rng(seed)
    return rng.random((B, n)) < frac


# ------------------- batched == scalar, per decoder -------------------------

@settings(max_examples=25, deadline=None)
@given(k=st.integers(12, 64), s=st.integers(1, 6),
       scheme=st.sampled_from(["frc", "bgc", "rbgc", "cyclic"]),
       seed=st.integers(0, 10_000))
def test_property_batched_matches_scalar(k, s, scheme, seed):
    """For random codes and masks, DecodeEngine batched weights match
    the scalar decoding.* oracles per mask (the ISSUE acceptance
    property), and errors match the scalar error definitions."""
    code = _code(scheme, k, s, seed)
    masks = _masks(code.n, 9, seed + 1)
    # pinv: the scalar-oracle-equivalent path (the gram default agrees
    # on errors but not on weights at rank-deficient supports)
    eng = DecodeEngine(code, iters=5, optimal_impl="pinv")

    one = eng.decode_batch(masks, "onestep")
    opt = eng.decode_batch(masks, "optimal")
    alg = eng.decode_batch(masks, "algorithmic")
    s_eff = max(1, int(round((code.G != 0).sum() / code.n)))
    for b, m in enumerate(masks):
        assert_allclose(one.weights[b], D.onestep_weights(code.G, m),
                        atol=1e-10)
        r = int(m.sum())
        assert_allclose(one.errors[b],
                        D.err1(code.G[:, m], D.default_rho(code.k, r, s_eff)),
                        atol=1e-8, rtol=1e-8)
        assert_allclose(opt.weights[b], D.optimal_weights(code.G, m),
                        atol=1e-6)
        assert_allclose(alg.weights[b],
                        D.algorithmic_weights(code.G, m, iters=5),
                        atol=1e-8)


def test_batched_optimal_error_matches_lstsq():
    code = _code("bgc", 48, 5, 3)
    masks = _masks(48, 12, 4)
    res = DecodeEngine(code, optimal_impl="pinv").decode_batch(
        masks, "optimal")
    for b, m in enumerate(masks):
        assert_allclose(res.errors[b], D.err(code.G[:, m]),
                        atol=1e-7, rtol=1e-6)
    # the gram DEFAULT lands on the same least-squares errors to its
    # ridge floor (the weights may differ on rank-deficient supports)
    dflt = DecodeEngine(code).decode_batch(masks, "optimal")
    assert_allclose(dflt.errors, res.errors, atol=1e-4, rtol=1e-4)


def test_degenerate_masks():
    code = _code("bgc", 24, 3, 0)
    masks = np.zeros((3, 24), bool)        # every worker straggles
    for method in ("onestep", "optimal", "algorithmic", "ignore"):
        res = DecodeEngine(code).decode_batch(masks, method)
        assert np.all(res.weights == 0) or method == "ignore"
        assert res.weights.shape == (3, 24)
        assert np.all(np.isfinite(res.errors))


def test_unknown_method_raises():
    code = _code("bgc", 16, 3, 0)
    with pytest.raises(ValueError):
        DecodeEngine(code).decode_batch(np.ones((1, 16), bool), "nope")


# ------------------- ELL packing ---------------------------------------------

@settings(max_examples=20, deadline=None)
@given(k=st.integers(8, 60), s=st.integers(1, 6),
       scheme=st.sampled_from(["frc", "bgc", "rbgc", "cyclic"]),
       seed=st.integers(0, 10_000))
def test_property_ell_roundtrip(k, s, scheme, seed):
    """The row-ELL packing reconstructs G exactly (padding adds 0)."""
    code = _code(scheme, k, s, seed)
    idx, val = code.ell()
    assert idx.shape == val.shape and idx.shape[0] == code.k
    G2 = np.zeros_like(code.G)
    for i in range(code.k):
        np.add.at(G2[i], idx[i], val[i])
    assert_allclose(G2, code.G)
    # cached: second call returns the identical objects
    assert code.ell()[0] is idx


# ------------------- batched Pallas kernels (interpret) ----------------------

@pytest.mark.parametrize("k,n,s,B", [(100, 100, 10, 7), (130, 70, 5, 9),
                                     (64, 64, 4, 33)])
def test_batched_onestep_kernel_matches_ref(k, n, s, B):
    rng = np.random.default_rng(0)
    G = (rng.random((k, n)) < s / k).astype(np.float32)
    masks = rng.random((B, n)) < 0.7
    rhos = (rng.random(B) + 0.5).astype(np.float32)
    want = np.asarray(ops.batched_onestep_decode(
        jnp.asarray(G), jnp.asarray(masks), jnp.asarray(rhos), impl="xla"))
    got = np.asarray(ops.batched_onestep_decode(
        jnp.asarray(G), jnp.asarray(masks), jnp.asarray(rhos),
        impl="pallas_interpret", bb=16, bk=64, bn=64))
    assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_batched_onestep_ell_kernel_matches_dense():
    rng = np.random.default_rng(1)
    code = C.bgc(k=96, n=96, s=6, rng=rng)
    masks = rng.random((11, 96)) < 0.75
    rhos = (rng.random(11) + 0.5).astype(np.float32)
    idx, val = code.ell()
    dense = np.asarray(ops.batched_onestep_decode(
        jnp.asarray(code.G.astype(np.float32)), jnp.asarray(masks),
        jnp.asarray(rhos), impl="pallas_interpret", bb=8, bk=32, bn=32))
    ell = np.asarray(ops.batched_onestep_decode_ell(
        jnp.asarray(idx), jnp.asarray(val), jnp.asarray(masks),
        jnp.asarray(rhos), impl="pallas_interpret", bb=8, bk=32))
    assert_allclose(ell, dense, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("k,n,s,iters", [(100, 100, 10, 4), (130, 70, 5, 2)])
def test_batched_algorithmic_kernel_matches_scalar_kernel(k, n, s, iters):
    """Each batch row of the batched kernel equals the scalar kernel run
    on that mask, and the returned weights match the numpy batch path."""
    rng = np.random.default_rng(2)
    G = (rng.random((k, n)) < s / k).astype(np.float32)
    masks = rng.random((6, n)) < 0.7
    nus = D.spectral_norm_sq_batch(G, masks).astype(np.float32) * 1.01
    U, X = ops.batched_algorithmic_decode(
        jnp.asarray(G), jnp.asarray(masks), jnp.asarray(nus), iters,
        impl="pallas_interpret", bb=8, bk=64, bn=64, return_weights=True)
    U, X = np.asarray(U), np.asarray(X)
    for b in range(masks.shape[0]):
        u1 = np.asarray(ops.algorithmic_decode(
            jnp.asarray(G), jnp.asarray(masks[b]), float(nus[b]), iters,
            impl="pallas_interpret", bk=64, bn=64))
        assert_allclose(U[b], u1, atol=1e-4, rtol=1e-4)
    W_np = D.algorithmic_weights_batch(G.astype(np.float64), masks, iters,
                                       nu=nus.astype(np.float64))
    assert_allclose(X * masks, W_np, atol=1e-4, rtol=1e-3)


def test_engine_pallas_interpret_backend_matches_numpy():
    code = C.bgc(k=64, n=64, s=5, rng=np.random.default_rng(3))
    masks = _masks(64, 10, 5)
    res_np = DecodeEngine(code, backend="numpy").decode_batch(masks)
    for sparse in ("always", "never"):
        res_k = DecodeEngine(code, backend="pallas_interpret",
                             sparse=sparse).decode_batch(masks)
        assert_allclose(res_k.weights, res_np.weights, atol=1e-5)
        assert_allclose(res_k.errors, res_np.errors, atol=1e-3, rtol=1e-4)


# ------------------- LRU cache -----------------------------------------------

def test_decode_cache_hits_on_repeated_masks():
    code = C.bgc(k=32, n=32, s=4, rng=np.random.default_rng(7))
    eng = DecodeEngine(code, cache_size=8)
    mask = np.ones(32, bool)
    mask[[3, 7]] = False
    w1 = eng.decode(mask)
    w2 = eng.decode(mask)
    assert w1 is w2                      # memoized object
    assert eng.cache_info()["hits"] == 1
    assert eng.cache_info()["misses"] == 1
    assert_allclose(w1, D.onestep_weights(code.G, mask), atol=1e-12)
    # different method -> distinct entry
    eng.decode(mask, method="optimal")
    assert eng.cache_info()["misses"] == 2


def test_decode_cache_evicts_lru():
    code = C.bgc(k=16, n=16, s=3, rng=np.random.default_rng(8))
    eng = DecodeEngine(code, cache_size=2)
    rng = np.random.default_rng(9)
    m = [rng.random(16) < 0.7 for _ in range(3)]
    eng.decode(m[0]); eng.decode(m[1]); eng.decode(m[2])  # evicts m[0]
    assert eng.cache_info()["size"] == 2
    eng.decode(m[0])
    assert eng.cache_info()["misses"] == 4  # m[0] was evicted -> re-decoded


def test_cached_weights_are_immutable():
    code = C.bgc(k=16, n=16, s=3, rng=np.random.default_rng(10))
    eng = DecodeEngine(code)
    w = eng.decode(np.ones(16, bool))
    with pytest.raises(ValueError):
        w[0] = 99.0


# ------------------- trainer integration ------------------------------------

def test_trainer_decode_weights_cached_and_renormed():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import build_model
    from repro.training import CodedTrainConfig, CodedTrainer

    model = build_model(get_config("minicpm-2b", smoke=True))
    tr = CodedTrainer(model, CodedTrainConfig(code="frc", n_workers=8, s=2,
                                              decoder="onestep", seq_len=16))
    mask = np.ones(8, bool)
    mask[[1, 5]] = False
    w1 = tr.decode_weights_for(mask)
    w2 = tr.decode_weights_for(mask)
    assert_allclose(w1, w2)
    assert tr.engine.cache_info()["hits"] >= 1
    # renorm invariant: sum(G @ w) == k
    assert abs(float((tr.code.G @ w1).sum()) - tr.code.k) < 1e-6


# ------------------- batched Monte-Carlo path --------------------------------

def test_simulate_batched_matches_manual_loop():
    """monte_carlo_error's batched cell equals a hand-rolled loop over
    the same masks/codes (same rng stream => identical draws)."""
    k, s, delta, trials = 40, 4, 0.25, 64
    res = S.monte_carlo_error("frc", k=k, n=k, s=s, delta=delta,
                              trials=trials, decoder="onestep", seed=11)
    rng = np.random.default_rng(11)
    code = C.make_code("frc", k=k, n=k, s=s, rng=rng)
    masks = S.sample_straggler_masks(k, int(round(delta * k)), trials, rng)
    errs = np.array([D.err1(code.G[:, m],
                            D.default_rho(k, int(m.sum()), s))
                     for m in masks]) / k
    assert res.mean == pytest.approx(float(errs.mean()), abs=1e-12)
    assert res.p_zero == pytest.approx(float((errs < 1e-9).mean()))


def test_sample_straggler_masks_counts_and_determinism():
    masks = S.sample_straggler_masks(30, 7, 100, np.random.default_rng(0))
    assert masks.shape == (100, 30)
    assert np.all((~masks).sum(axis=1) == 7)
    again = S.sample_straggler_masks(30, 7, 100, np.random.default_rng(0))
    assert np.array_equal(masks, again)
