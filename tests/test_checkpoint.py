"""Checkpoint layer: async-writer error handling, prune/restore round
trips, metadata-applying restore, and the fp64 restart-recovery
equivalence differential (docs/architecture.md §11)."""

import threading
import time

import numpy as np
import pytest

import repro.checkpoint.checkpoint as CKPT
from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(x=1.0):
    return {"a": np.full((3,), x), "b": {"c": np.full((2, 2), 2 * x)}}


# ==========================================================================
# AsyncCheckpointer: stale errors + thread lifecycle (regression)
# ==========================================================================


class TestAsyncCheckpointer:
    def test_error_surfaces_once_then_clears(self, tmp_path, monkeypatch):
        calls = {"n": 0}
        real = CKPT.save_checkpoint

        def flaky(directory, step, tree, metadata=None, keep_last=3):
            calls["n"] += 1
            if calls["n"] == 1:
                raise OSError("disk full")
            return real(directory, step, tree, metadata, keep_last)

        monkeypatch.setattr(CKPT, "save_checkpoint", flaky)
        ck = AsyncCheckpointer(str(tmp_path))
        ck.save(1, _tree())
        with pytest.raises(OSError, match="disk full"):
            ck.wait()
        # the old code replayed the same stale exception on every later
        # save()/wait(), wedging checkpointing for the rest of the run
        ck.save(2, _tree())
        ck.wait()  # must NOT re-raise the step-1 failure
        assert latest_step(str(tmp_path)) == 2
        ck.close()
        assert not ck._thread.is_alive()

    def test_close_joins_thread_even_when_wait_raises(self, tmp_path,
                                                      monkeypatch):
        def broken(directory, step, tree, metadata=None, keep_last=3):
            raise RuntimeError("boom")

        monkeypatch.setattr(CKPT, "save_checkpoint", broken)
        ck = AsyncCheckpointer(str(tmp_path))
        ck.save(1, _tree())
        with pytest.raises(RuntimeError, match="boom"):
            ck.close()
        # the old close() leaked the daemon worker when wait() raised
        deadline = time.time() + 10
        while ck._thread.is_alive() and time.time() < deadline:
            time.sleep(0.01)
        assert not ck._thread.is_alive()

    def test_async_matches_sync(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep_last=2)
        for step in (1, 2, 3):
            ck.save(step, _tree(step), {"next_step": step})
        ck.close()
        tree, meta = restore_checkpoint(str(tmp_path), _tree())
        assert meta["next_step"] == 3
        assert np.array_equal(tree["a"], np.full((3,), 3.0))

    def test_worker_is_single_thread(self, tmp_path):
        before = threading.active_count()
        ck = AsyncCheckpointer(str(tmp_path))
        ck.close()
        deadline = time.time() + 10
        while threading.active_count() > before and time.time() < deadline:
            time.sleep(0.01)
        assert threading.active_count() <= before


# ==========================================================================
# save -> prune -> restore round trips
# ==========================================================================


class TestSaveRestore:
    def test_keep_last_prunes(self, tmp_path):
        d = str(tmp_path)
        for step in range(1, 6):
            save_checkpoint(d, step, _tree(step), keep_last=2)
        kept = sorted(p.name for p in tmp_path.iterdir())
        assert kept == ["step_00000004", "step_00000005"]
        tree, _ = restore_checkpoint(d, _tree())
        assert np.array_equal(tree["a"], np.full((3,), 5.0))
        # an explicitly requested pruned step is a clean miss
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(d, _tree(), step=1)

    def test_latest_step_ignores_foreign_entries(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 7, _tree())
        # junk a restore must not trip over: editor droppings, partial
        # copies, non-numeric step_* names (the old int() call raised)
        (tmp_path / "step_backup").mkdir()
        (tmp_path / "step_00000009.tmp").mkdir()
        (tmp_path / "notes.txt").write_text("hi")
        assert latest_step(d) == 7
        tree, _ = restore_checkpoint(d, _tree())
        assert np.array_equal(tree["b"]["c"], np.full((2, 2), 2.0))

    def test_empty_dir(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        assert latest_step(str(tmp_path / "missing")) is None
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(str(tmp_path), _tree())

    def test_structure_mismatch_rejected(self, tmp_path):
        d = str(tmp_path)
        save_checkpoint(d, 1, _tree())
        with pytest.raises(ValueError, match="mismatch"):
            restore_checkpoint(d, {"other": np.zeros(2)})


# ==========================================================================
# Controller serialization (checkpoint metadata payload)
# ==========================================================================


class TestControllerStateDict:
    def test_estimator_roundtrip(self):
        from repro.control.estimator import StragglerEstimator

        rng = np.random.default_rng(0)
        est = StragglerEstimator(8, alpha=0.2, blocks=4, window=16)
        for t in range(40):
            mask = rng.random(8) > 0.2
            est.update(mask, latencies=rng.random(8) + 0.5,
                       decode_err=float(rng.random() * 0.1))
        clone = StragglerEstimator(8)
        clone.load_state_dict(est.state_dict())
        a, b = est.state(), clone.state()
        assert a.steps == b.steps
        assert np.allclose(a.erasure, b.erasure)
        assert a.block_corr == b.block_corr
        assert a.err_ew == b.err_ew
        assert a.quantiles == b.quantiles

    def test_adaptive_coder_roundtrip_decides_identically(self):
        from repro.control import AdaptiveCoder

        rng = np.random.default_rng(1)
        def feed(coder, lo, hi):
            for t in range(lo, hi):
                coder.decide(t)
                mask = rng.random(16) > 0.3
                coder.observe(t, mask, latencies=rng.random(16) + 0.5,
                              decode_err=float(rng.random() * 0.2))

        a = AdaptiveCoder("bgc", 16, s=4)
        feed(a, 0, 60)
        snap = a.state_dict()
        b = AdaptiveCoder("bgc", 16, s=4)
        b.load_state_dict(snap)
        assert (b.s, b.decoder, b.deadline) == (a.s, a.decoder, a.deadline)
        # identical observations after the snapshot -> identical actions
        rng_a, rng_b = (np.random.default_rng(9) for _ in range(2))
        for t in range(60, 120):
            act_a, act_b = a.decide(t), b.decide(t)
            assert (act_a is None) == (act_b is None)
            if act_a is not None:
                assert (act_a.kind, act_a.value) == (act_b.kind, act_b.value)
            mask = rng_a.random(16) > 0.3
            lat = rng_b.random(16) + 0.5
            a.observe(t, mask, latencies=lat)
            b.observe(t, mask, latencies=lat)

    def test_scripted_controller_roundtrip(self):
        from repro.control import ScriptedController
        from repro.control.policy import Action

        sc = ScriptedController({5: Action("set_s", 3)})
        sc.decide(4)
        sc.decide(5)
        clone = ScriptedController({5: Action("set_s", 3)})
        clone.load_state_dict(sc.state_dict())
        assert clone.actions == sc.actions


# ==========================================================================
# Trainer restore semantics (slow: jitted training)
# ==========================================================================


@pytest.mark.slow
class TestTrainerRestore:
    def _make(self, d, **kw):
        from repro import configs as CFG
        from repro.models import build_model
        from repro.optim import OptConfig
        from repro.training import CodedTrainConfig, CodedTrainer

        model = build_model(CFG.get_config("minicpm-2b", smoke=True))
        tcfg = CodedTrainConfig(
            code="bgc", n_workers=8, s=2, steps=9, seq_len=8, seed=0,
            opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
            log_every=1, ckpt_dir=str(d), ckpt_every=3, **kw)
        return CodedTrainer(model, tcfg)

    def test_restore_fires_with_explicit_state(self, tmp_path):
        # regression: maybe_restore only fired when state was None, so
        # run(state=init_state()) silently restarted from scratch
        tr1 = self._make(tmp_path)
        out1 = tr1.run()
        tr2 = self._make(tmp_path)
        out2 = tr2.run(state=tr2.init_state())   # explicit state, step 0
        # the whole job is already done: the restore was applied (the
        # old behavior would have re-trained all 9 steps from scratch)
        assert out2["history"] == []
        assert out2["final_step"] == 9

    def test_restore_resumes_at_next_step(self, tmp_path):
        tr1 = self._make(tmp_path)
        tr1.run(steps=7)  # ckpts at 3, 6
        tr2 = self._make(tmp_path)
        out = tr2.run()
        assert out["history"][0]["step"] == 6
        assert out["history"][-1]["step"] == 8
        assert out["final_step"] == 9

    def test_restore_applies_code_metadata(self, tmp_path):
        # a checkpoint taken at a different operating point (s raised by
        # a controller, say) must restore at THAT point, not the config
        # default
        import dataclasses as dc

        tr1 = self._make(tmp_path)
        tr1.tcfg = dc.replace(tr1.tcfg, s=4)
        tr1._build_code(8)
        tr1._step_fn = tr1._make_step_fn()
        tr1.run(state=tr1.init_state(), start_step=0, steps=3)
        tr2 = self._make(tmp_path)          # config says s=2
        state, start = tr2.maybe_restore(tr2.init_state())
        assert start == 3
        assert tr2.code.s == 4              # metadata won
        assert tr2.tcfg.s == 4


@pytest.mark.slow
def test_restore_equivalence_fp64_8dev():
    """Killed-then-restarted == uninterrupted at fp64 on 8 host devices:
    per-step mean_ce stream and final params bitwise through a churn
    scenario (preempt + scale_up), via checkpoint metadata alone."""
    pytest.importorskip("jax")
    from test_coded_allreduce import _TOY_MODEL, _run_subprocess

    body = """
    import tempfile
    from repro.optim import OptConfig
    from repro.sim import make_churn_scenario
    from repro.training import CodedTrainConfig, CodedTrainer

    scn = make_churn_scenario("bimodal", steps=18, n0=8, preempt_rate=0.15,
                              scaleup_rate=0.08, min_workers=3, seed=11)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)

    def cfg(d):
        return CodedTrainConfig(code="bgc", n_workers=8, s=2, steps=18,
                                seq_len=16, seed=0, opt=opt, log_every=1,
                                ckpt_dir=d, ckpt_every=5)

    with tempfile.TemporaryDirectory() as d_ref:
        ref = CodedTrainer(ToyModel(), cfg(d_ref), churn=scn)
        out_ref = ref.run()
    with tempfile.TemporaryDirectory() as d:
        first = CodedTrainer(ToyModel(), cfg(d), churn=scn)
        first.run(steps=12)                      # killed at step 12
        resumed = CodedTrainer(ToyModel(), cfg(d), churn=scn)
        out_res = resumed.run()                  # restores at 10, finishes

    ce_ref = {r["step"]: r["mean_ce"] for r in out_ref["history"]}
    ce_gap = max(abs(ce_ref[r["step"]] - r["mean_ce"])
                 for r in out_res["history"])
    p_gap = float(np.abs(flat(out_ref["state"]["params"])
                         - flat(out_res["state"]["params"])).max())
    print("RESULT:" + json.dumps({
        "resumed_from": out_res["history"][0]["step"],
        "events": len(scn.events), "ce_gap": ce_gap, "p_gap": p_gap}))
    """
    res = _run_subprocess(body, prelude=_TOY_MODEL)
    assert res["events"] >= 1           # churn actually happened
    assert res["resumed_from"] == 10    # restored, not cold-started
    assert res["ce_gap"] == 0.0         # fp64 bitwise, not just close
    assert res["p_gap"] == 0.0
