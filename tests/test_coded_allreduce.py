"""CodedAllReduce: differential, property, and golden tests (docs/architecture.md §9).

Three layers of trust for the shard_map coded aggregation:

  * DIFFERENTIAL — an fp64 subprocess (8 forced host devices, x64 on)
    proves the shard_map path identical to the single-process oracle
    ``explicit_master_decode_grads`` to 1e-10 for every
    registry-family x {onestep, optimal} x {all-alive, deadline-mask}
    cell (the scheme list is DERIVED from core.registry, so new
    families — sbm, expander — hit the 8-device lane the day they are
    registered), the decoded gradient identical to the plain
    uncoded gradient when the mask is all-alive and the decode exact,
    and mean_ce parity across mid-run AdaptiveCoder re-codes (set_s /
    set_decoder / set_deadline through a scripted controller).
  * PROPERTY — worker->device partitioning, per-device batch slicing and
    the ELL packing hold at ragged shapes (n not a multiple of the
    device count, k not a multiple of n, a single-device mesh).
  * GOLDEN — the coded trainer's loss curve under dist_mode=
    "coded_allreduce" (frc, n=8, deadline policy) is pinned at a fixed
    seed like test_golden_mc.GOLDEN_MEANS.

The in-process tests run on whatever devices exist (1 locally; the CI
multi-device lane exports REPRO_HOST_DEVICES=8 — applied by conftest via
repro.platform.configure_from_env() — so the same tests exercise a real
8-way mesh).  Subprocess tests force their own device world through
repro.platform.subprocess_env and never touch this process's jax.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import codes as CODES
from repro.core import registry as REG
from repro.core.assignment import build_assignment
from repro.core.engine import DecodeEngine
from repro.data import CodedDataPipeline, PipelineConfig
from repro.dist.coded_allreduce import (CodedAllReduce, partition_workers)
from repro.platform import subprocess_env
from repro.sim.cluster import ClusterSim
from repro.sim.traces import make_trace

REPO = Path(__file__).resolve().parent.parent

# The differential scheme list comes from the registry: every family
# that constructs at the (n=8, s=2) differential cell joins the fp64
# suite automatically.  uncoded is skipped (no redundancy to decode);
# rbgc / sregular are column-regularized members of the same Bernoulli
# class as bgc and are left to the cheaper property suites to keep the
# 8-device lane inside its time budget.
DIFF_SCHEMES = tuple(
    f.name for f in REG.families()
    if f.name not in ("uncoded", "rbgc", "sregular")
    and f.check(8, 8, 2) is None)


# ==========================================================================
# properties: partition / device batch / ELL at ragged shapes
# ==========================================================================


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_partition_covers_every_worker_once(n, n_devices):
    part = partition_workers(n, n_devices)
    ids = part.worker_ids
    assert ids.shape == (n_devices, part.lanes)
    assert part.lanes == max(-(-n // n_devices), 1)
    real = ids[ids >= 0]
    assert sorted(real.tolist()) == list(range(n))
    # every device sees identical shapes; pads are exactly the overhang
    assert (ids < 0).sum() == part.padded_n - n


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 12), st.integers(1, 3))
def test_partition_scatter_gather_roundtrip(n, n_devices, trailing):
    part = partition_workers(n, n_devices)
    rng = np.random.default_rng(n * 131 + n_devices)
    x = rng.normal(size=(n, trailing))
    s = part.scatter(x, fill=-7.0)
    assert s.shape == (n_devices, part.lanes, trailing)
    assert np.array_equal(part.gather(s), x)
    assert np.all(s[~part.lane_mask] == -7.0)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 12), st.integers(1, 5), st.integers(2, 7))
def test_device_batch_matches_flat_batch_ragged(n, n_devices, s):
    """Per-device microbatches are a pure re-layout of the fused batch:
    lane (d, l) holds exactly worker worker_ids[d, l]'s rows; padding
    lanes are all-zero.  Exercises k != n (bgc) and n % D != 0."""
    k = n + 3   # k not a multiple of n
    rng = np.random.default_rng(1000 * n + n_devices)
    code = CODES.bgc(k=k, n=n, s=min(s, k), rng=rng)
    asg = build_assignment(code)
    pipe = CodedDataPipeline(asg, PipelineConfig(vocab=32, seq_len=8,
                                                 rows_per_slot=2, seed=3))
    part = partition_workers(n, n_devices)
    w = rng.normal(size=n)
    flat = pipe.batch_for_step(0, w)
    dev = pipe.device_batch_for_step(0, w, part)
    rpw = asg.slots * 2
    for name in ("tokens", "labels", "loss_weight"):
        assert dev[name].shape[:2] == (n_devices, part.lanes * rpw)
        for d in range(n_devices):
            for l in range(part.lanes):
                j = part.worker_ids[d, l]
                got = dev[name][d, l * rpw: (l + 1) * rpw]
                if j >= 0:
                    want = flat[name][j * rpw: (j + 1) * rpw]
                    assert np.array_equal(got, want), (name, d, l)
                else:
                    assert np.all(got == 0), (name, d, l)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 12), st.integers(1, 4), st.integers(0, 500))
def test_ell_roundtrip_ragged(n, s, seed):
    """Row-ELL packing reconstructs G exactly at k != n shapes (the
    packing feeds the per-device assignment tables)."""
    k = n + seed % 5
    code = CODES.bgc(k=k, n=n, s=min(s, k),
                     rng=np.random.default_rng(seed))
    idx, val = code.ell()
    dense = np.zeros((code.k, code.n))
    for i in range(code.k):
        for r in range(idx.shape[1]):
            dense[i, idx[i, r]] += val[i, r]
    np.testing.assert_array_equal(dense, code.G)


def test_partition_single_device_mesh():
    part = partition_workers(8, 1)
    assert part.lanes == 8 and part.n_devices == 1
    assert np.array_equal(part.worker_ids[0], np.arange(8))


def test_partition_more_devices_than_workers():
    part = partition_workers(3, 8)
    assert part.lanes == 1
    assert (part.worker_ids >= 0).sum() == 3


# ==========================================================================
# kernel: batched weighted accumulate
# ==========================================================================


@pytest.mark.parametrize("k,P,B", [(8, 64, 4), (7, 33, 5), (1, 9, 1)])
def test_coded_accumulate_batched_interpret_matches_ref(k, P, B):
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(k * 100 + P)
    g = rng.normal(size=(k, P)).astype(np.float32)
    w = rng.normal(size=(B, k)).astype(np.float32)
    ref = np.asarray(ops.coded_accumulate_batched(
        jnp.asarray(g), jnp.asarray(w), impl="xla"))
    got = np.asarray(ops.coded_accumulate_batched(
        jnp.asarray(g), jnp.asarray(w), impl="pallas_interpret"))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ref, w @ g, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("L,P,B", [(8, 64, 4), (13, 37, 9), (1, 9, 1)])
def test_fused_decode_apply_interpret_matches_ref(L, P, B):
    """The fused decode-apply kernel (interpret mode) == the xla
    reference AND the two-pass composition it replaces (materialize
    weights = scales * masks, then coded_accumulate_batched)."""
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(L * 100 + P)
    msgs = rng.normal(size=(L, P)).astype(np.float32)
    masks = rng.random((B, L)) < 0.7
    scales = rng.normal(size=B).astype(np.float32)
    ref = np.asarray(ops.fused_decode_apply(
        jnp.asarray(msgs), jnp.asarray(masks), jnp.asarray(scales),
        impl="xla"))
    got = np.asarray(ops.fused_decode_apply(
        jnp.asarray(msgs), jnp.asarray(masks), jnp.asarray(scales),
        impl="pallas_interpret"))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    W = (scales[:, None] * masks).astype(np.float32)
    comp = np.asarray(ops.coded_accumulate_batched(
        jnp.asarray(msgs), jnp.asarray(W), impl="xla"))
    np.testing.assert_allclose(ref, comp, rtol=1e-5, atol=1e-5)


# ==========================================================================
# aggregation on the live mesh (1 device locally, 8 in the CI lane)
# ==========================================================================


@pytest.mark.parametrize("decoder", ["onestep", "optimal", "algorithmic",
                                     "ignore"])
def test_aggregate_messages_matches_numpy(decoder):
    rng = np.random.default_rng(5)
    code = CODES.bgc(k=12, n=12, s=4, rng=rng)
    engine = DecodeEngine(code)
    ar = CodedAllReduce(code, engine=engine)
    masks = rng.random((6, 12)) < 0.8
    W = ar.weights_for_masks(masks, decoder, renorm=False)
    msgs = rng.normal(size=(12, 40))
    out = ar.aggregate_messages_batch(msgs, W)
    np.testing.assert_allclose(out, W @ msgs, rtol=1e-5, atol=1e-6)
    assert engine.batch_calls == 1   # the whole ensemble, one decode


@pytest.mark.parametrize("renorm", [False, True])
def test_aggregate_messages_fused_matches_weights_then_psum(renorm):
    """Fused one-step aggregation == the weights-then-psum composition
    on the live mesh — without materializing the [S, n] weight ensemble
    and without spending a decode_batch call."""
    rng = np.random.default_rng(9)
    code = CODES.bgc(k=12, n=12, s=4, rng=rng)
    engine = DecodeEngine(code)
    ar = CodedAllReduce(code, engine=engine)
    masks = rng.random((5, 12)) < 0.75
    masks[0] = True                        # no stragglers
    masks[1] = False                       # all stragglers -> exact zeros
    msgs = rng.normal(size=(12, 24))
    W = ar.weights_for_masks(masks, "onestep", renorm=renorm)
    want = np.asarray(ar.aggregate_messages_batch(msgs, W))
    got = np.asarray(ar.aggregate_messages_fused(msgs, masks,
                                                 renorm=renorm))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert np.all(got[1] == 0)             # dead row decodes to exact 0
    assert engine.fused_calls == 1         # scales only on the fused path
    assert engine.batch_calls == 1         # just the W reference above


def test_weights_for_masks_matches_engine_decode():
    """Batched trace decode == the per-mask LRU path the fused trainer
    uses (same renorm), so the two dist modes share one weight stream."""
    code = CODES.frc(k=8, n=8, s=2)
    ar = CodedAllReduce(code, engine=DecodeEngine(code))
    masks = np.ones((3, 8), dtype=bool)
    masks[1, [0, 5]] = False
    masks[2, :] = False                      # all-straggler row: no renorm
    W = ar.weights_for_masks(masks, "onestep", renorm=True)
    single = DecodeEngine(code)
    for b, mask in enumerate(masks):
        w = single.decode(mask, "onestep").copy()
        if w.any():
            tot = float((code.G @ w).sum())
            if tot > 1e-6:
                w = w * code.k / tot
        np.testing.assert_allclose(W[b], w, atol=1e-12)


@pytest.mark.parametrize("decoder", ["onestep", "optimal"])
def test_run_distributed_matches_analytic_frontier(decoder):
    """E11 validation: the decode errors measured on real devices (basis
    task gradients through the shard_map message path) equal the
    engine's analytic errors — and the whole run is ONE decode_batch."""
    code = CODES.bgc(k=16, n=16, s=4, rng=np.random.default_rng(0))
    trace = make_trace("pareto", steps=40, n=16, seed=3)
    sim = ClusterSim(code, trace, "deadline", decoder=decoder, deadline=1.5)
    res = sim.run_distributed()
    np.testing.assert_allclose(res.errors, res.extras["analytic_errors"],
                               rtol=1e-4, atol=1e-6)
    assert sim.engine.batch_calls == 1
    assert res.steps == 40 and res.extras["n_devices"] >= 1


def test_trainer_trace_schedule_one_decode_batch():
    """dist_mode + trace: the trainer decodes the whole trace in one
    decode_batch at build time (the ClusterSim invariant on the
    distributed path) and per-step weights are row lookups."""
    import types

    import jax
    import jax.numpy as jnp

    from repro.training import CodedTrainConfig, CodedTrainer

    class ToyModel:
        cfg = types.SimpleNamespace(vocab=32, schedule="cosine")

        def init(self, key):
            return {"w": jax.random.normal(key, (16,)) * 0.1}

        def loss_fn(self, params, batch):
            x = batch["tokens"].astype(jnp.float32)
            y = batch["labels"].astype(jnp.float32).mean(-1)
            row = (x @ params["w"] - y) ** 2
            wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
            return wloss, {"loss": wloss, "mean_ce": row.mean()}

    trace = make_trace("pareto", steps=12, n=8, seed=7)
    tr = CodedTrainer(ToyModel(), CodedTrainConfig(
        code="frc", n_workers=8, s=2, decoder="onestep", rows_per_slot=1,
        seq_len=16, steps=6, seed=0, log_every=1,
        dist_mode="coded_allreduce"), trace=trace, sync_policy="deadline")
    assert tr.engine.batch_calls == 1          # whole trace, already decoded
    assert tr._trace_weights.shape == (12, 8)
    out = tr.run()
    assert tr.engine.batch_calls == 1          # no per-step decodes appeared
    assert all(np.isfinite(h["mean_ce"]) for h in out["history"])
    assert out["history"][-1]["sim_time"] > 0


# ==========================================================================
# THE differential suite: fp64, 8 forced host devices, subprocess
# ==========================================================================


def _run_subprocess(body: str, timeout: int = 560, x64: bool = True,
                    prelude: str = "") -> dict:
    """Run `body` under 8 host devices (and x64 when asked); it must
    print one JSON line starting with RESULT:."""
    prog = textwrap.dedent("""
        import os, types, json
        import numpy as np
        import jax
        import jax.numpy as jnp
        assert jax.device_count() == 8, jax.devices()
    """) + textwrap.dedent(prelude) + textwrap.dedent(body)
    # override=True: the child asserts device_count == 8, so the forced
    # cpu-host world must win even when the caller env pins its own
    # XLA_FLAGS / JAX_PLATFORMS
    env = subprocess_env(platform="cpu", host_devices=8,
                         x64=True if x64 else None, override=True)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", prog], cwd=REPO, env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("RESULT:")]
    assert line, f"no RESULT in stdout:\n{out.stdout[-2000:]}"
    return json.loads(line[-1][len("RESULT:"):])


_TOY_MODEL = """
    class ToyModel:
        cfg = types.SimpleNamespace(vocab=32, schedule="cosine")
        def init(self, key):
            k1, k2 = jax.random.split(key)
            return {"w": jax.random.normal(k1, (16,), jnp.float64) * 0.1,
                    "b": jax.random.normal(k2, (), jnp.float64)}
        def loss_fn(self, params, batch):
            x = batch["tokens"].astype(jnp.float64)
            y = batch["labels"].astype(jnp.float64).mean(-1)
            pred = jnp.tanh(x @ params["w"]) + params["b"]
            row = (pred - y) ** 2
            wloss = (row * batch["loss_weight"].astype(jnp.float64)).sum()
            return wloss, {"loss": wloss, "mean_ce": row.mean()}

    def flat(tree):
        return np.concatenate([np.asarray(g).reshape(-1)
                               for g in jax.tree_util.tree_leaves(tree)])
"""


def test_differential_shard_map_vs_master_oracle_fp64():
    """shard_map aggregation == explicit_master_decode_grads to 1e-10
    (fp64) for every registry family in DIFF_SCHEMES x {onestep,
    optimal} x {all-alive, deadline-policy mask}, on a real 8-device
    worker mesh; the decode weight streams of the two paths agree to
    1e-12."""
    res = _run_subprocess(prelude=_TOY_MODEL, body=f"""
        SCHEMES = {DIFF_SCHEMES!r}
    """ + """
        from repro.training import CodedTrainConfig, CodedTrainer
        from repro.training.train_loop import explicit_master_decode_grads
        from repro.sim.cluster import DeadlinePolicy
        from repro.sim.traces import make_trace

        model = ToyModel()
        trace = make_trace("pareto", steps=4, n=8, seed=11)
        mask_dead = DeadlinePolicy(1.5).step(trace.latencies[0])[0]
        cells = []
        for scheme in SCHEMES:
            for decoder in ("onestep", "optimal"):
                tr = CodedTrainer(model, CodedTrainConfig(
                    code=scheme, n_workers=8, s=2, decoder=decoder,
                    rows_per_slot=1, seq_len=16, seed=0,
                    dist_mode="coded_allreduce"))
                params = model.init(jax.random.PRNGKey(0))
                vg = tr.allreduce.value_and_grad(model.loss_fn)
                for mname, mask in (("alive", np.ones(8, bool)),
                                    ("deadline", mask_dead)):
                    oracle, w = explicit_master_decode_grads(
                        model, params, tr, 0, mask)
                    oracle = np.asarray(oracle)
                    w2 = tr.allreduce.weights_for_masks(
                        mask[None], method=decoder)[0]
                    dw = float(np.abs(np.asarray(w) - w2).max())
                    db = tr.pipeline.device_batch_for_step(
                        0, w, tr.allreduce.partition)
                    (_, _), grads = vg(params, tr.allreduce.shard_batch(db))
                    diff = float(np.abs(flat(grads) - oracle).max())
                    scale = float(np.abs(oracle).max())
                    cells.append({"scheme": scheme, "decoder": decoder,
                                  "mask": mname, "absdiff": diff,
                                  "scale": scale, "wdiff": dw})
        print("RESULT:" + json.dumps({
            "n_devices": jax.device_count(), "cells": cells}))
    """)
    assert res["n_devices"] == 8
    # sbm and expander genuinely ride the 8-device lane, not just the
    # seed trio
    assert {"sbm", "expander"} <= set(DIFF_SCHEMES)
    assert len(res["cells"]) == len(DIFF_SCHEMES) * 2 * 2
    for c in res["cells"]:
        tol = 1e-10 * max(c["scale"], 1.0) + 1e-12
        assert c["absdiff"] < tol, c
        assert c["wdiff"] < 1e-12, c


def test_differential_all_alive_equals_uncoded_gradient_fp64():
    """With every worker alive and an exact decode (frc/cyclic +
    optimal: G @ w == 1), the coded shard_map gradient equals the plain
    uncoded gradient over the unique examples — to fp64."""
    res = _run_subprocess(prelude=_TOY_MODEL, body="""
        from repro.training import CodedTrainConfig, CodedTrainer

        model = ToyModel()
        out = []
        for scheme in ("frc", "cyclic"):
            tr = CodedTrainer(model, CodedTrainConfig(
                code=scheme, n_workers=8, s=2, decoder="optimal",
                rows_per_slot=1, seq_len=16, seed=0,
                dist_mode="coded_allreduce"))
            params = model.init(jax.random.PRNGKey(2))
            mask = np.ones(8, bool)
            w = tr.decode_weights_for(mask)
            exact = float(np.abs(tr.code.G @ w - 1.0).max())
            db = tr.pipeline.device_batch_for_step(0, w,
                                                   tr.allreduce.partition)
            vg = tr.allreduce.value_and_grad(model.loss_fn)
            (_, _), g_coded = vg(params, tr.allreduce.shard_batch(db))
            ub = tr.pipeline.uncoded_batch_for_step(0)
            g_ref = jax.grad(lambda p: model.loss_fn(
                p, {k: jnp.asarray(v) for k, v in ub.items()})[0])(params)
            diff = float(np.abs(flat(g_coded) - flat(g_ref)).max())
            scale = float(np.abs(flat(g_ref)).max())
            out.append({"scheme": scheme, "exact": exact, "absdiff": diff,
                        "scale": scale})
        print("RESULT:" + json.dumps(out))
    """)
    for c in res:
        assert c["exact"] < 1e-9, c            # the decode really is exact
        assert c["absdiff"] < 1e-10 * max(c["scale"], 1.0) + 1e-12, c


def test_differential_fused_aggregation_vs_weights_then_psum_fp64():
    """Fused decode-apply aggregation == the weights-then-psum
    composition AND the host oracle W @ msgs to 1e-10, fp64 on a real
    8-device worker mesh with 2 lanes per device, renorm on and off.
    The fused path spends onestep_scales calls, never decode_batch."""
    res = _run_subprocess(body="""
        from repro.core import codes as CODES
        from repro.core.engine import DecodeEngine
        from repro.dist.coded_allreduce import CodedAllReduce

        rng = np.random.default_rng(17)
        code = CODES.bgc(k=16, n=16, s=4, rng=rng)
        engine = DecodeEngine(code)
        ar = CodedAllReduce(code, engine=engine)
        masks = rng.random((6, 16)) < 0.75
        masks[0] = True
        masks[1] = False
        msgs = rng.normal(size=(16, 48))          # fp64 under x64
        cells = []
        for renorm in (False, True):
            W = ar.weights_for_masks(masks, "onestep", renorm=renorm)
            ref = W @ msgs
            psum = np.asarray(ar.aggregate_messages_batch(msgs, W))
            fused = np.asarray(ar.aggregate_messages_fused(
                msgs, masks, renorm=renorm))
            cells.append({
                "renorm": renorm,
                "psum": float(np.abs(psum - ref).max()),
                "fused": float(np.abs(fused - ref).max()),
                "scale": float(np.abs(ref).max())})
        print("RESULT:" + json.dumps({
            "n_devices": jax.device_count(),
            "lanes": ar.partition.lanes, "cells": cells,
            "fused_calls": engine.fused_calls,
            "batch_calls": engine.batch_calls}))
    """)
    assert res["n_devices"] == 8 and res["lanes"] == 2
    assert res["fused_calls"] == 2        # one onestep_scales per fused call
    assert res["batch_calls"] == 2        # only the W references decoded
    for c in res["cells"]:
        tol = 1e-10 * max(c["scale"], 1.0) + 1e-12
        assert c["psum"] < tol, c
        assert c["fused"] < tol, c


def test_differential_2d_mesh_vs_worker_mesh_fp64():
    """CodedAllReduce on a workers x model mesh (4 x 2 over 8 devices)
    matches the host oracle to 1e-10 fp64 on the message path, the
    fused path, AND the value_and_grad gradient path (vs
    explicit_master_decode_grads) — the worker axis composes with an
    automatic model axis instead of owning the whole mesh."""
    res = _run_subprocess(prelude=_TOY_MODEL, body="""
        from repro.core import codes as CODES
        from repro.core.engine import DecodeEngine
        from repro.dist.coded_allreduce import CodedAllReduce
        from repro.dist.sharding import make_coded_mesh
        from repro.training import CodedTrainConfig, CodedTrainer
        from repro.training.train_loop import explicit_master_decode_grads

        mesh2d = make_coded_mesh(4)               # 4 workers x 2 model
        assert dict(mesh2d.shape) == {"workers": 4, "model": 2}

        rng = np.random.default_rng(23)
        code = CODES.bgc(k=8, n=8, s=2, rng=rng)
        ar2 = CodedAllReduce(code, engine=DecodeEngine(code), mesh=mesh2d)
        assert ar2.n_devices == 4                 # worker-axis extent only
        masks = rng.random((5, 8)) < 0.7
        masks[0] = True
        msgs = rng.normal(size=(8, 40))
        W = ar2.weights_for_masks(masks, "optimal", renorm=False)
        agg = float(np.abs(np.asarray(
            ar2.aggregate_messages_batch(msgs, W)) - W @ msgs).max())
        Wf = ar2.weights_for_masks(masks, "onestep", renorm=True)
        fus = float(np.abs(np.asarray(ar2.aggregate_messages_fused(
            msgs, masks, renorm=True)) - Wf @ msgs).max())
        mscale = float(max(np.abs(W @ msgs).max(),
                           np.abs(Wf @ msgs).max()))

        # gradient path: trainer pinned to the 2-D mesh vs the oracle
        model = ToyModel()
        tr = CodedTrainer(model, CodedTrainConfig(
            code="frc", n_workers=4, s=2, decoder="onestep",
            rows_per_slot=1, seq_len=16, seed=0,
            dist_mode="coded_allreduce"), mesh=mesh2d)
        params = model.init(jax.random.PRNGKey(0))
        mask = np.array([True, False, True, True])
        oracle, w = explicit_master_decode_grads(model, params, tr, 0,
                                                 mask)
        db = tr.pipeline.device_batch_for_step(0, w,
                                               tr.allreduce.partition)
        vg = tr.allreduce.value_and_grad(model.loss_fn)
        (loss, aux), grads = vg(params, tr.allreduce.shard_batch(db))
        gdiff = float(np.abs(flat(grads) - np.asarray(oracle)).max())
        gscale = float(np.abs(np.asarray(oracle)).max())
        print("RESULT:" + json.dumps({
            "n_devices": jax.device_count(), "agg": agg, "fused": fus,
            "mscale": mscale, "gdiff": gdiff, "gscale": gscale,
            "loss_finite": bool(np.isfinite(float(loss)))}))
    """)
    assert res["n_devices"] == 8
    assert res["agg"] < 1e-10 * max(res["mscale"], 1.0) + 1e-12
    assert res["fused"] < 1e-10 * max(res["mscale"], 1.0) + 1e-12
    assert res["gdiff"] < 1e-10 * max(res["gscale"], 1.0) + 1e-12
    assert res["loss_finite"]


def test_adaptive_recode_metrics_match_fused_fp64():
    """ISSUE-5 acceptance: a mid-run controller re-code (set_s at step
    0 AND mid-run, plus decoder/deadline switches) preserves mean_ce
    parity between dist_mode='coded_allreduce' and the fused path to
    1e-10, fp64 on a real 8-device mesh.  Both trainers share one
    scripted action plan — identical observations take identical
    action sequences, the control-loop SPMD property."""
    res = _run_subprocess(prelude=_TOY_MODEL, body="""
        from repro.control import Action, ScriptedController
        from repro.sim.traces import make_trace
        from repro.training import CodedTrainConfig, CodedTrainer

        model = ToyModel()
        trace = make_trace("pareto", steps=12, n=8, seed=7)
        out = {}
        for mode in ("fused", "coded_allreduce"):
            plan = {0: Action("set_s", 4),        # re-code at step 0
                    3: Action("set_decoder", "optimal"),
                    6: Action("set_s", 2),        # mid-run re-code
                    9: Action("set_deadline", 1.2)}
            tr = CodedTrainer(model, CodedTrainConfig(
                code="frc", n_workers=8, s=2, decoder="onestep",
                rows_per_slot=1, seq_len=16, steps=12, seed=0,
                log_every=1, dist_mode=mode),
                trace=trace, sync_policy="deadline",
                controller=ScriptedController(plan))
            hist = tr.run()["history"]
            out[mode] = {"mean_ce": [h["mean_ce"] for h in hist],
                         "loss": [h["loss"] for h in hist],
                         "s": [h["s"] for h in hist],
                         "decoder": [h["decoder"] for h in hist]}
        print("RESULT:" + json.dumps(dict(out,
                                          n_devices=jax.device_count())))
    """)
    assert res["n_devices"] == 8
    fused, dist = res["fused"], res["coded_allreduce"]
    assert fused["s"] == dist["s"] == [4] * 6 + [2] * 6
    assert fused["decoder"] == dist["decoder"] \
        == ["onestep"] * 3 + ["optimal"] * 9
    a = np.asarray(fused["mean_ce"])
    b = np.asarray(dist["mean_ce"])
    scale = np.abs(a).max()
    assert np.abs(a - b).max() < 1e-10 * max(scale, 1.0), (a - b)
    np.testing.assert_allclose(dist["loss"], fused["loss"],
                               rtol=1e-10, atol=1e-12)


def test_ragged_workers_metrics_match_fused_8_devices():
    """n=7 workers on 8 devices (one padding lane): the dist trainer's
    loss AND mean_ce equal the fused trainer's — padding rows are masked
    out of the CE and the padded_n/n rescale undoes the row-count
    dilution."""
    res = _run_subprocess(x64=False, body="""
        from repro.training import CodedTrainConfig, CodedTrainer

        class ToyModel:
            cfg = types.SimpleNamespace(vocab=32, schedule="cosine")
            def init(self, key):
                return {"w": jax.random.normal(key, (16,)) * 0.1}
            def loss_fn(self, params, batch):
                x = batch["tokens"].astype(jnp.float32)
                y = batch["labels"].astype(jnp.float32).mean(-1)
                row = (x @ params["w"] - y) ** 2
                lm = batch.get("loss_mask")
                if lm is not None:           # zero padding rows out of CE
                    row = row * lm.astype(jnp.float32).mean(-1)
                wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
                return wloss, {"loss": wloss, "mean_ce": row.mean()}

        from repro.runtime import FaultInjector
        from repro.runtime.faults import FaultPlan

        model = ToyModel()
        out = {}
        for mode in ("fused", "coded_allreduce"):
            tr = CodedTrainer(model, CodedTrainConfig(
                code="bgc", n_workers=7, s=2, decoder="onestep",
                rows_per_slot=1, seq_len=16, steps=2, seed=0, log_every=1,
                dist_mode=mode))
            hist = tr.run()["history"]
            out[mode] = {"loss": [h["loss"] for h in hist],
                         "mean_ce": [h["mean_ce"] for h in hist]}
        # elastic re-code mid-run: 8 workers -> 7 at step 1 makes the
        # partition ragged AFTER __init__ — the rebuilt step_fn must pick
        # up the new ce_fix (stale-closure regression)
        for mode in ("fused", "coded_allreduce"):
            tr = CodedTrainer(model, CodedTrainConfig(
                code="bgc", n_workers=8, s=2, decoder="onestep",
                rows_per_slot=1, seq_len=16, steps=3, seed=0, log_every=1,
                dist_mode=mode),
                fault_injector=FaultInjector(
                    [FaultPlan(step=1, workers=(7,))]))
            hist = tr.run()["history"]
            out[mode + "_fault"] = {
                "mean_ce": [h["mean_ce"] for h in hist],
                "workers": [h["n_workers"] for h in hist]}
        print("RESULT:" + json.dumps(dict(out,
                                          n_devices=jax.device_count())))
    """)
    assert res["n_devices"] == 8
    np.testing.assert_allclose(res["coded_allreduce"]["loss"],
                               res["fused"]["loss"], rtol=1e-5)
    np.testing.assert_allclose(res["coded_allreduce"]["mean_ce"],
                               res["fused"]["mean_ce"], rtol=1e-5)
    assert res["coded_allreduce_fault"]["workers"] == [8, 7, 7]
    np.testing.assert_allclose(res["coded_allreduce_fault"]["mean_ce"],
                               res["fused_fault"]["mean_ce"], rtol=1e-5)


# ==========================================================================
# golden convergence pin + 8-device trainer (slow lane)
# ==========================================================================

# Golden mean_ce curve for the dist_mode="coded_allreduce" trainer:
# minicpm-2b smoke model, frc n=8 s=2, onestep decoder, deadline policy
# over make_trace("pareto", steps=10, n=8, seed=41), trainer seed 1234.
# Bit-deterministic on one host device given the seed; the rtol absorbs
# BLAS/platform reduction-order wobble only.
#
# RE-PIN PROCEDURE: if a deliberate change moves the coded statistical
# or training core (verify first against test_golden_mc.py and the fp64
# differential tests above!), regenerate with
#   PYTHONPATH=src python -m pytest tests/test_coded_allreduce.py \
#       -k golden_convergence -q  # prints got-vs-want on failure
# or run the trainer snippet from this test and paste the new values.
# (Re-pinned when code builds moved to the counter-derived rng stream
# default_rng([seed, 0xC0DE, builds]) for checkpoint-exact rebuilds:
# frc's column permutation drew differently — permutation-invariant
# statistically, verified against the fp64 differentials.)
GOLDEN_DIST_MEAN_CE = [
    6.23709774017334, 6.216646194458008, 6.194518566131592,
    6.189853668212891, 6.147739410400391, 6.091350078582764,
    6.030529022216797, 6.0014448165893555, 5.978209495544434,
    5.885657787322998,
]
GOLDEN_DIST_SIM_TIME = 14.617005584431038


@pytest.mark.slow
def test_golden_convergence_pinned_dist_trainer():
    from repro import configs as CFG
    from repro.models import build_model
    from repro.optim import OptConfig
    from repro.training import CodedTrainConfig, CodedTrainer

    model = build_model(CFG.get_config("minicpm-2b", smoke=True))
    trace = make_trace("pareto", steps=10, n=8, seed=41)
    tr = CodedTrainer(model, CodedTrainConfig(
        code="frc", n_workers=8, s=2, decoder="onestep", rows_per_slot=1,
        seq_len=16, steps=10, seed=1234, log_every=1,
        dist_mode="coded_allreduce",
        opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)),
        trace=trace, sync_policy="deadline")
    out = tr.run()
    got = [h["mean_ce"] for h in out["history"]]
    assert len(got) == len(GOLDEN_DIST_MEAN_CE)
    np.testing.assert_allclose(
        got, GOLDEN_DIST_MEAN_CE, rtol=2e-4,
        err_msg="coded_allreduce loss curve moved from the golden pin — if "
                "the change is intentional, follow the re-pin procedure "
                f"above (got: {got!r})")
    assert out["history"][-1]["sim_time"] == pytest.approx(
        GOLDEN_DIST_SIM_TIME, rel=1e-9)
    assert got[-1] < got[0]                     # it still learns


@pytest.mark.slow
def test_dist_trainer_8_devices_subprocess():
    """The real-model coded_allreduce trainer on a true 8-device worker
    mesh: losses finite and decreasing, one decode_batch per trace."""
    res = _run_subprocess("""
        from repro import configs as CFG
        from repro.models import build_model
        from repro.optim import OptConfig
        from repro.training import CodedTrainConfig, CodedTrainer
        from repro.sim.traces import make_trace

        model = build_model(CFG.get_config("minicpm-2b", smoke=True))
        trace = make_trace("pareto", steps=8, n=8, seed=3)
        tr = CodedTrainer(model, CodedTrainConfig(
            code="frc", n_workers=8, s=2, decoder="onestep",
            rows_per_slot=1, seq_len=16, steps=8, seed=0, log_every=1,
            dist_mode="coded_allreduce",
            opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)),
            trace=trace, sync_policy="deadline")
        out = tr.run()

        # MoE aux parity at a RAGGED partition (n=7 on 8 devices, one
        # padding-only device): the dist loss's load-balance regularizer
        # must stay O(1), not O(D), and the padding device's garbage
        # router statistics must not contribute
        moe = build_model(CFG.get_config("granite-moe-3b-a800m",
                                         smoke=True))
        mtr = CodedTrainer(moe, CodedTrainConfig(
            code="bgc", n_workers=7, s=2, decoder="onestep",
            rows_per_slot=1, seq_len=16, steps=1, seed=0,
            dist_mode="coded_allreduce"))
        params = moe.init(jax.random.PRNGKey(0))
        w = mtr.decode_weights_for(np.ones(7, bool))
        fb = {k: jnp.asarray(v)
              for k, v in mtr.pipeline.batch_for_step(0, w).items()}
        fused_loss, fused_m = moe.loss_fn(params, fb)
        db = mtr.pipeline.device_batch_for_step(0, w,
                                                mtr.allreduce.partition)
        vg = mtr.allreduce.value_and_grad(moe.loss_fn)
        (dist_loss, dist_m), _ = vg(params, mtr.allreduce.shard_batch(db))
        aux_fused = float(fused_loss - fused_m["loss"])
        aux_dist = float(dist_loss - dist_m["loss"])

        print("RESULT:" + json.dumps({
            "n_devices": jax.device_count(),
            "mean_ce": [h["mean_ce"] for h in out["history"]],
            "batch_calls": tr.engine.batch_calls,
            "wloss_fused": float(fused_m["loss"]),
            "wloss_dist": float(dist_m["loss"]),
            "aux_fused": aux_fused, "aux_dist": aux_dist,
        }))
    """, x64=False)
    assert res["n_devices"] == 8
    ce = res["mean_ce"]
    assert all(np.isfinite(v) for v in ce)
    assert ce[-1] < ce[0]
    assert res["batch_calls"] == 1
    # weighted loss identical; the MoE aux regularizer O(1) not O(D)
    assert res["wloss_dist"] == pytest.approx(res["wloss_fused"], rel=1e-4)
    assert res["aux_fused"] > 0
    assert 0.3 < res["aux_dist"] / res["aux_fused"] < 3.0
