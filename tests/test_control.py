"""AdaptiveCoder subsystem tests (docs/adaptive.md).

Covers the ISSUE-5 edge cases: a re-code event at step 0, convergence
to minimum redundancy + one-step decoding on an all-alive trace,
hysteresis bounding re-code churn on an alternating bimodal trace, and
the estimator / policy / runner unit surfaces.  The fused == dist
metric parity across a mid-run re-code lives with the other 8-device
differentials in tests/test_coded_allreduce.py.
"""

import numpy as np
import pytest

from repro.control import (Action, AdaptiveCoder, ControlConfig,
                           ScriptedController, StragglerEstimator,
                           error_band, run_adaptive_sim)
from repro.core import registry
from repro.sim.frontier import sweep_adaptive, sweep_frontier
from repro.sim.traces import LatencyTrace, make_trace


# ------------------------------ estimator -----------------------------------

def test_estimator_erasure_rates_converge():
    est = StragglerEstimator(8, alpha=0.2)
    mask = np.ones(8, dtype=bool)
    mask[[2, 5]] = False                      # workers 2, 5 always erased
    for _ in range(100):
        est.update(mask)
    st = est.state()
    assert st.erasure[2] == pytest.approx(1.0, abs=1e-6)
    assert st.erasure[0] == pytest.approx(0.0, abs=1e-6)
    assert st.mean_erasure == pytest.approx(0.25, abs=1e-6)


def test_estimator_bias_correction_early_steps():
    """One observation must already estimate the observed rate (Adam
    debias), not a zero-diluted value."""
    est = StragglerEstimator(4, alpha=0.1)
    est.update(np.array([True, True, False, False]))
    assert est.state().mean_erasure == pytest.approx(0.5)


def test_estimator_block_correlation_signs():
    # block-aligned erasures -> score ~ 1
    est = StragglerEstimator(16, alpha=0.3, blocks=4)
    mask = np.ones(16, dtype=bool)
    mask[0:4] = False                         # exactly block 0
    for _ in range(50):
        est.update(mask)
    assert est.state().block_corr > 0.9
    # placement-independent erasures -> score ~ 0
    est2 = StragglerEstimator(16, alpha=0.3, blocks=4)
    rng = np.random.default_rng(0)
    for _ in range(400):
        m = np.ones(16, dtype=bool)
        m[rng.choice(16, 4, replace=False)] = False
        est2.update(m)
    assert abs(est2.state().block_corr) < 0.2


def test_estimator_latency_window_lookups():
    est = StragglerEstimator(4, window=10)
    for t in range(25):
        est.update(np.ones(4, dtype=bool),
                   latencies=np.array([1.0, 1.0, 1.0, 3.0]))
    st = est.state()
    assert st.lat_rows.shape == (10, 4)       # window bound respected
    assert st.erasure_at(2.0) == pytest.approx(0.25)
    assert st.step_time_at(2.0) == pytest.approx(2.0)
    assert st.step_time_at(5.0) == pytest.approx(3.0)
    assert st.latency_quantile(0.5) == pytest.approx(1.0)


def test_estimator_validation():
    with pytest.raises(ValueError):
        StragglerEstimator(0)
    est = StragglerEstimator(4)
    with pytest.raises(ValueError):
        est.update(np.ones(5, dtype=bool))
    with pytest.raises(ValueError):
        est.update(np.ones(4, dtype=bool), latencies=np.ones(3))


# ------------------------------ error bands ---------------------------------

def test_error_band_shapes():
    # more stragglers -> more predicted error, for both decoders
    for dec in ("onestep", "optimal"):
        bands = [error_band("bgc", 64, 8, d, dec) for d in (0.0, 0.2, 0.4)]
        assert bands == sorted(bands)
    # optimal never above one-step at equal (s, delta) for the families
    # with uncovered-task estimates
    for fam in ("bgc", "expander", "frc"):
        s = 8
        assert error_band(fam, 64, s, 0.2, "optimal") \
            <= error_band(fam, 64, s, 0.2, "onestep") + 1e-12
    # frc one-step at delta=0 decodes exactly
    assert error_band("frc", 64, 8, 0.0, "onestep") == pytest.approx(0.0)
    # full erasure (r = 0) saturates at total error
    assert error_band("bgc", 8, 4, 0.95, "onestep") == 1.0


def test_certified_band_corridor():
    """PR 10: the policy band is the calibrated estimate clamped into
    [fundamental lower bound, spectral-certificate upper bound]."""
    from repro.control.policy import AdaptivePolicy
    from repro.core import theory

    pol = AdaptivePolicy(
        registry.get("sregular"), 256, 256, ControlConfig(error_budget=0.1),
        s=8, decoder="onestep",
    )
    for s in (4, 8):
        for delta in (0.1, 0.3):
            band, certified = pol._banded(s, delta, "onestep")
            r = int(round((1 - delta) * 256))
            lb = theory.fundamental_err_lower_bound(256, s, r, 256) / 256
            assert band >= lb - 1e-12
    # blow the calibration sky-high: the certificate must cap the band
    pol._calib["onestep"] = 1e3
    band_hi, certified = pol._banded(8, 0.1, "onestep")
    from repro.core.certify import certified_err_frac

    ub = certified_err_frac("sregular", 256, 256, 8, 0.1)
    assert band_hi <= ub + 1e-12
    assert certified  # the certificate alone fits the 0.1 budget


def test_certified_flag_surfaced_in_action_history():
    """A family whose spectral certificate fits the budget (sregular at
    n = 256) emits certified=True actions; bgc's certificate is vacuous
    at this size (degree irregularity), so its actions stay False."""
    rng = np.random.default_rng(0)
    flags = {}
    for fam in ("sregular", "bgc"):
        coder = AdaptiveCoder(fam, 256, ControlConfig(error_budget=0.1), s=8)
        for t in range(120):
            lat = rng.exponential(0.3, size=256) + 1.0
            mask = lat <= coder.deadline
            coder.observe(
                t, mask=mask, latencies=lat,
                decode_err=0.03 + 0.01 * rng.random(),
            )
            coder.decide(t)
        acts = coder.policy.actions
        assert acts, f"{fam}: controller never acted"
        flags[fam] = [a.certified for _, a in acts]
    assert any(flags["sregular"])
    assert not any(flags["bgc"])


def test_action_certified_roundtrips_through_state_dict():
    coder = AdaptiveCoder("sregular", 64, ControlConfig(), s=4)
    coder.policy._apply(0, Action("set_s", 6, "test", certified=True))
    # the runner serializes its own action log; the policy Action
    # dataclass itself must round-trip the new field
    import dataclasses

    a = coder.policy.actions[0][1]
    assert Action(**dataclasses.asdict(a)) == a


# ------------------------------ actions / config ----------------------------

def test_action_and_config_validation():
    with pytest.raises(ValueError):
        Action("set_gain", 1.0)
    with pytest.raises(ValueError):
        ControlConfig(error_budget=0.0)
    with pytest.raises(ValueError):
        ControlConfig(improve_margin=1.5)
    with pytest.raises(KeyError):
        AdaptiveCoder("nope", 8, s=2)         # registry unknown-scheme
    with pytest.raises(ValueError):
        AdaptiveCoder("frc", 8, s=2, decoder="nope")


def test_scripted_controller_plan():
    ctrl = ScriptedController({3: Action("set_s", 4)})
    assert ctrl.decide(0) is None
    act = ctrl.decide(3)
    assert act.kind == "set_s" and act.value == 4
    assert ctrl.actions == [(3, act)]


# ------------------------------ controller edge cases -----------------------

def test_all_alive_trace_converges_to_min_s_onestep():
    """ISSUE-5 edge case: an all-alive fleet needs no redundancy — the
    controller must walk s down the legal ladder to its minimum and
    keep the cheap one-step decoder."""
    tr = make_trace("none", steps=200, n=32, base=1.0, slow=1.0)
    cfg = ControlConfig(error_budget=0.05, warmup=5, cooldown=10)
    res = run_adaptive_sim("frc", tr, cfg, s=8, seed=0)
    assert res.s_traj[-1] == 1
    assert res.decoder_traj[-1] == "onestep"
    assert res.errors.max() == pytest.approx(0.0, abs=1e-12)
    # monotone descent, one rung at a time
    assert (np.diff(res.s_traj) <= 0).all()
    # and the shed compute shows up as modelled wall-clock
    assert res.step_times[-1] < res.step_times[0] / 4


def test_recode_event_at_step_zero():
    """A controller may re-code before the first decode (warm-start
    action at step 0): the run must use the new s from the very first
    mask."""
    tr = make_trace("pareto", steps=20, n=16, seed=3)

    class Step0Coder(AdaptiveCoder):
        def decide(self, step):
            if step == 0:
                return self.policy._apply(0, Action("set_s", 4))
            return None

    coder = Step0Coder("bgc", 16, ControlConfig(), s=8)
    # drive the sim loop manually through the same protocol
    res = run_adaptive_sim("bgc", tr, ControlConfig(warmup=10**9), s=8,
                           seed=0)
    assert (res.s_traj == 8).all()            # inert controller: no change
    act = coder.decide(0)
    assert act.kind == "set_s" and coder.s == 4


def test_hysteresis_no_oscillation_on_alternating_bimodal():
    """ISSUE-5 edge case: a trace alternating between an all-fast and a
    20%-slow regime every few steps must not make the controller flip
    s / decoder back and forth — EW smoothing + cooldown + the improve
    margin bound the re-code count."""
    rng = np.random.default_rng(7)
    S, n = 300, 32
    lat = np.full((S, n), 1.0) * np.exp(0.05 * rng.standard_normal((S, n)))
    slow = rng.choice(n, round(0.2 * n), replace=False)
    for t in range(S):
        if (t // 4) % 2 == 1:                 # slow regime every other 4
            lat[t, slow] *= 3.0
    tr = LatencyTrace(lat, source="alternating-bimodal")
    cfg = ControlConfig(error_budget=0.1, warmup=5, cooldown=10)
    res = run_adaptive_sim("bgc", tr, cfg, s=8, seed=0)
    assert res.recodes <= 8                   # bounded churn, no thrash
    # and s never ping-pongs: at most recodes sign changes in the traj
    flips = np.sum(np.abs(np.diff(np.sign(np.diff(
        res.s_traj[res.s_traj != np.roll(res.s_traj, 1)])))) > 0)
    assert flips <= 3


def test_adaptive_sim_batched_decode_budget():
    """Decoding stays batched: ~S / feedback_every calls, not S."""
    tr = make_trace("bimodal", steps=200, n=32, seed=0)
    cfg = ControlConfig(error_budget=0.1, warmup=5, cooldown=10)
    res = run_adaptive_sim("bgc", tr, cfg, s=8, seed=0, feedback_every=10)
    assert res.batch_calls <= 200 // 10 + res.recodes + 1
    assert res.batch_calls >= 2


def test_adaptive_dominates_static_cells_bimodal():
    """The E11 acceptance shape, at test scale: the adaptive cell beats
    every static (policy, decoder) cell's time-to-target on a bimodal
    trace."""
    tr = make_trace("bimodal", steps=300, n=64, seed=0)
    static = sweep_frontier(("bgc",), ("sync", "deadline", "backup",
                                       "adaptive"), tr, s=8,
                            decoders=("onestep", "optimal"))
    apt = sweep_adaptive(("bgc",), tr, s=8, error_budget=0.1, seed=0)[0]
    assert apt.policy == "adaptive_coder"
    assert all(apt.time_to_target < p.time_to_target for p in static)


# ------------------------------ trainer integration -------------------------

def _toy_model():
    """Tiny fp32 model with the repo's loss_fn contract (loss_weight
    per row, (loss, aux) return) — shared by the trainer-integration
    tests below."""
    import types

    import jax
    import jax.numpy as jnp

    class ToyModel:
        cfg = types.SimpleNamespace(vocab=32, schedule="cosine")

        def init(self, key):
            return {"w": jax.random.normal(key, (16,)) * 0.1}

        def loss_fn(self, params, batch):
            x = batch["tokens"].astype(jnp.float32)
            y = batch["labels"].astype(jnp.float32).mean(-1)
            row = (x @ params["w"] - y) ** 2
            wloss = (row * batch["loss_weight"].astype(jnp.float32)).sum()
            return wloss, {"loss": wloss, "mean_ce": row.mean()}

    return ToyModel()


def test_trainer_rejects_controller_with_non_deadline_policy():
    """With a trace attached the controller emits set_deadline actions;
    a sync policy that cannot apply them (backup/sync/adaptive) must be
    rejected up front instead of silently desyncing the controller's
    tracked operating point."""
    from repro.training import CodedTrainConfig, CodedTrainer

    tr = make_trace("pareto", steps=4, n=8, seed=0)
    coder = AdaptiveCoder("bgc", 8, s=2)
    with pytest.raises(ValueError, match="DeadlinePolicy"):
        CodedTrainer(_toy_model(), CodedTrainConfig(n_workers=8, s=2),
                     trace=tr, sync_policy="backup", controller=coder)
    # deadline policy is fine
    t = CodedTrainer(_toy_model(), CodedTrainConfig(n_workers=8, s=2),
                     trace=tr, sync_policy="deadline", controller=coder)
    assert t.controller is coder


@pytest.mark.slow
def test_trainer_applies_controller_actions():
    """CodedTrainer + AdaptiveCoder protocol: scripted actions re-code
    mid-run (including step 0) and history records the live (s,
    decoder); the engine/assignment/pipeline are rebuilt."""
    from repro.training import CodedTrainConfig, CodedTrainer

    trace = make_trace("pareto", steps=8, n=8, seed=7)
    plan = {0: Action("set_s", 4), 3: Action("set_decoder", "optimal"),
            5: Action("set_deadline", 1.2)}
    tr = CodedTrainer(_toy_model(), CodedTrainConfig(
        code="frc", n_workers=8, s=2, decoder="onestep", rows_per_slot=1,
        seq_len=16, steps=8, seed=0, log_every=1),
        trace=trace, sync_policy="deadline",
        controller=ScriptedController(plan))
    hist = tr.run()["history"]
    assert [h["s"] for h in hist] == [4] * 8   # step-0 re-code took effect
    assert [h["decoder"] for h in hist] == ["onestep"] * 3 + ["optimal"] * 5
    assert tr.code.s == 4 and tr.tcfg.decoder == "optimal"
    assert tr.sync_policy.deadline == pytest.approx(1.2)
    # post-deadline-change masks come from the new 1.2s cutoff
    assert hist[-1]["stragglers"] == int(
        (trace.latencies[7] > 1.2).sum())


@pytest.mark.slow
def test_trainer_adaptive_coder_closed_loop():
    """A real AdaptiveCoder in the trainer loop stays inside the legal
    ladder and produces finite metrics (smoke of the closed loop)."""
    from repro.training import CodedTrainConfig, CodedTrainer

    trace = make_trace("bimodal", steps=30, n=16, seed=1)
    coder = AdaptiveCoder("bgc", 16,
                          ControlConfig(error_budget=0.1, warmup=4,
                                        cooldown=6),
                          s=4)
    tr = CodedTrainer(_toy_model(), CodedTrainConfig(
        code="bgc", n_workers=16, s=4, decoder="onestep", rows_per_slot=1,
        seq_len=16, steps=30, seed=0, log_every=1),
        trace=trace, sync_policy="deadline", controller=coder)
    hist = tr.run()["history"]
    fam = registry.get("bgc")
    assert all(np.isfinite(h["mean_ce"]) for h in hist)
    assert all(1 <= h["s"] <= 16 for h in hist)
    assert all(fam.supports_decoder(h["decoder"]) for h in hist)
