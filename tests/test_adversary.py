"""Adversarial straggler selection tests (paper Sec. 4)."""

import numpy as np
import pytest

from repro.core import adversary as ADV
from repro.core import codes as C
from repro.core import decoding as D
from repro.core import simulate as S
from repro.core import theory as T


RNG = lambda seed=0: np.random.default_rng(seed)


class TestFRCAdversary:
    @pytest.mark.parametrize("permuted", [False, True])
    def test_achieves_worst_case(self, permuted):
        """Thm 10: the adversary forces err(A) = k - r on an FRC."""
        k, s = 24, 4
        code = C.frc(k=k, n=k, s=s, rng=RNG(3) if permuted else None)
        for num_stragglers in [4, 8, 12]:
            mask = ADV.frc_adversarial_mask(code.G, num_stragglers)
            assert (~mask).sum() == num_stragglers
            r = k - num_stragglers
            e = D.err(code.G[:, mask])
            assert e == pytest.approx(T.thm10_frc_worstcase_err(k, r), abs=1e-9)

    def test_beats_random_stragglers(self):
        k, s = 100, 10
        code = C.frc(k=k, n=k, s=s, rng=RNG(5))
        num = 30
        adv_mask = ADV.frc_adversarial_mask(code.G, num)
        adv_err = D.err(code.G[:, adv_mask])
        rng = RNG(6)
        rand_errs = []
        for _ in range(50):
            mask = S.sample_straggler_mask(k, num, rng)
            rand_errs.append(D.err(code.G[:, mask]))
        assert adv_err > np.mean(rand_errs) * 2

    def test_budget_below_block_size_harmless(self):
        """With budget < s the adversary cannot kill any block: err = 0."""
        code = C.frc(k=20, n=20, s=5, rng=RNG(1))
        mask = ADV.frc_adversarial_mask(code.G, 4)
        assert D.err(code.G[:, mask]) == pytest.approx(0.0, abs=1e-9)


class TestGreedyAdversary:
    def test_at_least_as_bad_as_random(self):
        k, s = 40, 5
        num = 12
        code = C.bgc(k=k, n=k, s=s, rng=RNG(2))
        greedy = ADV.greedy_adversarial_mask(code.G, num)
        greedy_err = D.err(code.G[:, greedy])
        rng = RNG(3)
        rand = ADV.random_search_adversarial_mask(code.G, num, trials=30, rng=rng)
        rand_err = D.err(code.G[:, rand])
        assert greedy_err >= rand_err * 0.9  # greedy ~dominates best-of-30

    def test_bgc_more_adversary_resistant_than_frc(self):
        """The paper's qualitative claim: poly-time adversaries hurt FRC
        (linear-time worst case) far more than random codes."""
        k, s, num = 60, 6, 18
        frc_code = C.frc(k=k, n=k, s=s, rng=RNG(4))
        frc_err = D.err(frc_code.G[:, ADV.frc_adversarial_mask(frc_code.G, num)])
        bgc_errs = []
        for seed in range(3):
            bgc_code = C.bgc(k=k, n=k, s=s, rng=RNG(seed))
            m = ADV.greedy_adversarial_mask(bgc_code.G, num, objective="onestep")
            bgc_errs.append(D.err(bgc_code.G[:, m]))
        # FRC adversarial error = num (=k-r); BGC greedy typically below
        assert frc_err == pytest.approx(num, abs=1e-9)
        assert np.mean(bgc_errs) < frc_err


class TestDkSReduction:
    def _ring(self, nv):
        M = np.zeros((nv, nv))
        for i in range(nv):
            M[i, (i + 1) % nv] = M[(i + 1) % nv, i] = 1
        return M

    def test_gram_identity(self):
        """B^T B = M + d I (the linchpin of the Thm-11 proof)."""
        M = self._ring(8)
        red = ADV.build_dks_reduction(M, kq=3)
        B = red.C[:, : red.nv]
        np.testing.assert_allclose(B.T @ B, M + 2 * np.eye(8))

    def test_objective_matches_closed_form(self):
        """Eq. 4.2: ||rho C x - 1||^2 = 2 rho^2 e(S) + d rho^2 a - 2 rho d a + |E|."""
        import networkx as nx

        g = nx.random_regular_graph(3, 10, seed=0)
        M = nx.to_numpy_array(g)
        red = ADV.build_dks_reduction(M, kq=4, rho=0.5)
        rng = RNG(8)
        for _ in range(10):
            a = int(rng.integers(1, 6))
            verts = rng.choice(red.nv, size=a, replace=False)
            y = np.zeros(red.nv)
            y[verts] = 1
            x = np.concatenate([y, np.zeros(red.ne - red.nv)])
            e_s = int(M[np.ix_(verts, verts)].sum() // 2)
            assert red.objective(x) == pytest.approx(
                red.predicted_objective(e_s, a), rel=1e-12)

    def test_denser_subgraph_higher_objective(self):
        """At fixed |S|, the reduction's objective is increasing in e(S) —
        solving r-ASP solves DkS (the hardness direction)."""
        M = self._ring(12)
        # add a dense clump
        for i in [0, 1, 2, 3]:
            for j in [0, 1, 2, 3]:
                if i != j:
                    M[i, j] = 1
        # regularize: pad to 5-regular by adding a matching where needed
        # (skip regularity check by building objective manually)
        rho = 0.5
        dummy = ADV.DkSReduction(C=np.zeros((1, 1)), adjacency=M, d=5, kq=4, rho=rho)
        dense = dummy.predicted_objective(edges_in_s=6, a=4)
        sparse = dummy.predicted_objective(edges_in_s=2, a=4)
        assert dense > sparse

    def test_greedy_dks_finds_planted_clique(self):
        rng = RNG(10)
        nv, kq = 30, 6
        M = (rng.random((nv, nv)) < 0.08).astype(float)
        M = np.triu(M, 1)
        M = M + M.T
        clique = rng.choice(nv, size=kq, replace=False)
        for i in clique:
            for j in clique:
                if i != j:
                    M[i, j] = 1
        found = ADV.densest_k_subgraph_greedy(M, kq)
        overlap = len(set(found) & set(clique))
        assert overlap >= kq - 1
