"""Unit tests for model internals: sequence-impl equivalences and the MoE
dispatch against its dense oracle."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig, MoEConfig
from repro.models.layers import attention
from repro.models.rglru import rglru_scan_ref
from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref
from repro.models import moe as moe_lib
from repro.models.spec import init_params


RNG = np.random.default_rng


class TestWKV:
    def _inputs(self, B=2, T=32, H=3, dh=8, seed=0):
        r = RNG(seed)
        mk = lambda: jnp.asarray(r.normal(size=(B, T, H, dh)) * 0.5, jnp.float32)
        w = jnp.asarray(r.uniform(0.2, 0.98, size=(B, T, H, dh)), jnp.float32)
        u = jnp.asarray(r.normal(size=(H, dh)) * 0.3, jnp.float32)
        return mk(), mk(), mk(), w, u

    @pytest.mark.parametrize("T,chunk", [(32, 16), (64, 16), (48, 16)])
    def test_chunked_matches_scan(self, T, chunk):
        r, k, v, w, u = self._inputs(T=T)
        o_ref, s_ref = wkv_scan_ref(r, k, v, w, u)
        o_chk, s_chk = wkv_chunked(r, k, v, w, u, chunk=chunk)
        np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_chunked_with_initial_state(self):
        r, k, v, w, u = self._inputs(T=32, seed=1)
        s0 = jnp.asarray(RNG(2).normal(size=(2, 3, 8, 8)), jnp.float32)
        o_ref, s_ref = wkv_scan_ref(r, k, v, w, u, s0=s0)
        o_chk, s_chk = wkv_chunked(r, k, v, w, u, s0=s0, chunk=16)
        np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_strong_decay_no_overflow(self):
        """Clamped decay range keeps the chunked factorization finite."""
        r, k, v, _, u = self._inputs(T=32, seed=3)
        w = jnp.full(r.shape, np.exp(-5.0), jnp.float32)  # strongest decay
        o_chk, s_chk = wkv_chunked(r, k, v, w, u, chunk=16)
        assert np.isfinite(np.asarray(o_chk)).all()
        o_ref, _ = wkv_scan_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                                   rtol=1e-3, atol=1e-4)

    def test_state_continuation(self):
        """Running two halves with carried state == one full pass."""
        r, k, v, w, u = self._inputs(T=32, seed=4)
        o_full, s_full = wkv_scan_ref(r, k, v, w, u)
        o1, s1 = wkv_chunked(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u)
        o2, s2 = wkv_chunked(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s0=s1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                                   np.asarray(o_full), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   rtol=1e-4, atol=1e-4)


class TestRGLRU:
    def test_assoc_scan_matches_loop(self):
        r = RNG(0)
        B, S, D = 2, 17, 5
        log_a = jnp.asarray(-r.uniform(0.01, 2.0, (B, S, D)), jnp.float32)
        u = jnp.asarray(r.normal(size=(B, S, D)), jnp.float32)
        got = rglru_scan_ref(u, log_a)
        a = np.exp(np.asarray(log_a))
        un = np.asarray(u)
        h = np.zeros((B, D))
        want = np.zeros((B, S, D))
        for t in range(S):
            h = a[:, t] * h + un[:, t]
            want[:, t] = h
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

    def test_initial_state_fold(self):
        r = RNG(1)
        B, S, D = 1, 9, 4
        log_a = jnp.asarray(-r.uniform(0.01, 1.0, (B, S, D)), jnp.float32)
        u = jnp.asarray(r.normal(size=(B, S, D)), jnp.float32)
        h0 = jnp.asarray(r.normal(size=(B, D)), jnp.float32)
        full = rglru_scan_ref(jnp.concatenate([h0[:, None], u], 1),
                              jnp.concatenate([jnp.zeros((B, 1, D)), log_a], 1))
        got = rglru_scan_ref(u, log_a, h0=h0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, 1:]),
                                   rtol=1e-5, atol=1e-5)


class TestChunkedAttention:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
    def test_matches_naive(self, causal, window):
        r = RNG(5)
        B, S, H, Kv, dh = 2, 40, 4, 2, 8
        q = jnp.asarray(r.normal(size=(B, S, H, dh)), jnp.float32)
        k = jnp.asarray(r.normal(size=(B, S, Kv, dh)), jnp.float32)
        v = jnp.asarray(r.normal(size=(B, S, Kv, dh)), jnp.float32)
        naive = attention(q, k, v, causal=causal, window=window,
                          impl="xla_naive")
        chunked = attention(q, k, v, causal=causal, window=window,
                            impl="xla_chunked", q_block=16, kv_block=8)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap_and_offset(self):
        r = RNG(6)
        B, S, T, H, dh = 1, 24, 48, 2, 8
        q = jnp.asarray(r.normal(size=(B, S, H, dh)), jnp.float32)
        k = jnp.asarray(r.normal(size=(B, T, H, dh)), jnp.float32)
        v = jnp.asarray(r.normal(size=(B, T, H, dh)), jnp.float32)
        naive = attention(q, k, v, causal=True, softcap=20.0, q_offset=24,
                          impl="xla_naive")
        chunked = attention(q, k, v, causal=True, softcap=20.0, q_offset=24,
                            impl="xla_chunked", q_block=8, kv_block=16)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.slow  # dense-oracle comparisons: full MoE forwards
class TestMoE:
    def _cfg(self, E=4, K=2, cf=8.0, shared=0):
        return ArchConfig(
            name="t", family="moe", n_layers=2, d_model=16, n_heads=2,
            n_kv=2, d_head=8, d_ff=32, vocab=64,
            moe=MoEConfig(num_experts=E, top_k=K, d_ff_expert=24,
                          capacity_factor=cf, num_shared=shared),
            compute_dtype="float32")

    def test_gather_matches_dense_oracle(self):
        cfg = self._cfg()
        specs = moe_lib.moe_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(0))
        x = jnp.asarray(RNG(7).normal(size=(2, 6, 16)), jnp.float32)
        y_fast, aux_fast = moe_lib.moe_apply(params, x, cfg)
        y_ref, aux_ref = moe_lib.moe_apply_dense(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(aux_fast), float(aux_ref), rtol=1e-5)

    def test_shared_experts(self):
        cfg = self._cfg(shared=1)
        specs = moe_lib.moe_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(1))
        x = jnp.asarray(RNG(8).normal(size=(1, 5, 16)), jnp.float32)
        y_fast, _ = moe_lib.moe_apply(params, x, cfg)
        y_ref, _ = moe_lib.moe_apply_dense(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_capacity_drops_tokens(self):
        """With tiny capacity the outputs differ from the oracle (tokens
        dropped) but stay finite — the documented overflow behavior."""
        cfg = self._cfg(cf=0.25)
        specs = moe_lib.moe_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(2))
        x = jnp.asarray(RNG(9).normal(size=(2, 8, 16)), jnp.float32)
        y, aux = moe_lib.moe_apply(params, x, cfg)
        assert np.isfinite(np.asarray(y)).all()

    def test_aux_loss_uniform_router_is_one(self):
        """Balanced routing gives aux ~= 1 (E * sum_e (1/E)*(1/E) * E)."""
        cfg = self._cfg(E=8, K=2)
        specs = moe_lib.moe_specs(cfg)
        params = init_params(specs, jax.random.PRNGKey(3))
        params = dict(params)
        params["router"] = jnp.zeros_like(params["router"])  # uniform
        x = jnp.asarray(RNG(10).normal(size=(4, 16, 16)), jnp.float32)
        _, aux = moe_lib.moe_apply(params, x, cfg)
        assert 0.9 <= float(aux) <= 1.1
