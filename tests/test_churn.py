"""Membership churn: fault coalescing, scenario generation/replay,
external-trace ingestion, simulate_churn recovery modes, and the
trainer's churn + restart-recovery paths (docs/architecture.md §11)."""

from pathlib import Path

import numpy as np
import pytest

from repro.core.codes import block_ids
from repro.runtime import FaultInjector, FaultPlan
from repro.sim import (ChurnEvent, ChurnScenario, RECOVERY_MODES,
                       ingest_machine_events, make_churn_scenario,
                       simulate_churn, time_to_target_error)

SAMPLE_CSV = (Path(__file__).resolve().parent.parent
              / "benchmarks" / "data" / "machine_events_sample.csv")


# ==========================================================================
# FaultInjector.check: co-scheduled plans coalesce (regression)
# ==========================================================================


class TestFaultCoalescing:
    def test_two_plans_same_step_merge(self):
        # the old check() returned the first match and silently dropped
        # the second plan scheduled for the same step
        fi = FaultInjector([FaultPlan(step=3, workers=(1,)),
                            FaultPlan(step=3, workers=(4, 5))])
        plan = fi.check(3)
        assert plan is not None
        assert plan.workers == (1, 4, 5)
        assert fi.dead == {1, 4, 5}
        assert fi.alive_count(8) == 5

    def test_already_dead_filtered(self):
        fi = FaultInjector([FaultPlan(step=1, workers=(2,)),
                            FaultPlan(step=5, workers=(2, 3))])
        assert fi.check(1).workers == (2,)
        # worker 2 is already dead at step 5: only the NEW death reports
        assert fi.check(5).workers == (3,)

    def test_none_when_nothing_new(self):
        fi = FaultInjector([FaultPlan(step=2, workers=(0,))])
        assert fi.check(1) is None
        assert fi.check(2).workers == (0,)
        # fully-duplicate plan at a later step coalesces to nothing
        fi.plans.append(FaultPlan(step=7, workers=(0,)))
        assert fi.check(7) is None


# ==========================================================================
# ChurnEvent / ChurnScenario
# ==========================================================================


class TestChurnScenario:
    def test_event_validation(self):
        with pytest.raises(ValueError):
            ChurnEvent(step=0, kind="nope")
        with pytest.raises(ValueError):
            ChurnEvent(step=-1, kind="preempt", workers=(0,))
        with pytest.raises(ValueError):
            ChurnEvent(step=0, kind="preempt", workers=())
        with pytest.raises(ValueError):
            ChurnEvent(step=0, kind="scale_up", count=0)

    def test_generator_deterministic_in_seed(self):
        a = make_churn_scenario("bimodal", steps=120, n0=16, seed=9,
                                preempt_rate=0.1, scaleup_rate=0.05)
        b = make_churn_scenario("bimodal", steps=120, n0=16, seed=9,
                                preempt_rate=0.1, scaleup_rate=0.05)
        c = make_churn_scenario("bimodal", steps=120, n0=16, seed=10,
                                preempt_rate=0.1, scaleup_rate=0.05)
        assert a.events == b.events
        assert np.array_equal(a.speed, b.speed)
        assert np.array_equal(a.trace.latencies, b.trace.latencies)
        assert a.events != c.events  # and the process actually varies

    def test_generator_bounds(self):
        scn = make_churn_scenario("bimodal", steps=300, n0=16, seed=3,
                                  preempt_rate=0.2, preempt_max=4,
                                  scaleup_rate=0.1, scaleup_max=4,
                                  min_workers=6)
        counts = scn.membership().sum(axis=1)
        assert counts.min() >= 6
        assert counts.max() <= scn.n_max
        # at most one event per step by construction
        steps = [e.step for e in scn.events]
        assert len(steps) == len(set(steps))
        assert scn.speed.min() > 0

    def test_block_preemption_aligns_to_block_ids(self):
        scn = make_churn_scenario("bimodal", steps=400, n0=16, seed=1,
                                  preempt_rate=0.0, block_rate=0.08,
                                  blocks=4, min_workers=4)
        blk_events = [e for e in scn.events if e.kind == "preempt_block"]
        assert blk_events, "block_rate=0.08 over 400 steps produced none"
        live = scn.initial_ids()
        for ev in scn.events:
            if ev.kind == "preempt_block":
                # victims are one block of the CURRENT live set under the
                # shared block_ids partition (the sbm/clustered one)
                assert set(ev.workers) <= set(int(x) for x in live)
                member = block_ids(live.size, 4)
                pos = np.searchsorted(live, sorted(ev.workers))
                assert len(set(member[pos])) == 1
            live = scn.apply_event(live, ev)

    def test_apply_event_semantics(self):
        scn = make_churn_scenario("bimodal", steps=10, n0=4, n_max=6, seed=0,
                                  preempt_rate=0.0, scaleup_rate=0.0)
        live = scn.initial_ids()
        # preempt ignores already-dead slots (replayed external traces
        # may double-report removals)
        live = scn.apply_event(live, ChurnEvent(0, "preempt", workers=(1, 5)))
        assert live.tolist() == [0, 2, 3]
        # scale_up takes the lowest inactive slots, clamped at capacity
        live = scn.apply_event(live, ChurnEvent(1, "scale_up", count=99))
        assert live.tolist() == [0, 1, 2, 3, 4, 5]

    def test_json_roundtrip(self, tmp_path):
        scn = make_churn_scenario("bimodal", steps=60, n0=8, seed=4,
                                  preempt_rate=0.1, scaleup_rate=0.05,
                                  speed_sigma=0.2)
        p = tmp_path / "scenario.json"
        scn.save(p)
        back = ChurnScenario.load(p)
        assert back.events == scn.events
        assert back.n0 == scn.n0
        assert np.array_equal(back.speed, scn.speed)
        assert np.array_equal(back.trace.latencies, scn.trace.latencies)
        assert np.array_equal(back.membership(), scn.membership())

    def test_latencies_at_speed_scaled(self):
        scn = make_churn_scenario("bimodal", steps=20, n0=8, seed=2,
                                  speed_sigma=0.5, preempt_rate=0.0)
        ids = np.array([1, 4, 6])
        lat = scn.latencies_at(3, ids)
        expect = scn.trace.latencies[3, ids] * scn.speed[ids]
        assert np.allclose(lat, expect)


# ==========================================================================
# External machine_events ingestion (the committed sample)
# ==========================================================================


class TestIngestion:
    def test_sample_ingests(self):
        scn = ingest_machine_events(SAMPLE_CSV, bin_seconds=300.0, seed=0)
        assert scn.n0 == 16          # ADDs at timestamp 0
        assert scn.n_max == 22       # + 6 machines added later
        assert len(scn.events) > 0
        kinds = {e.kind for e in scn.events}
        assert kinds <= {"preempt", "scale_up"}  # UPDATE rows ignored
        # REMOVEs never push the fleet below min_workers
        assert scn.membership().sum(axis=1).min() >= 2

    def test_sample_deterministic(self):
        a = ingest_machine_events(SAMPLE_CSV, seed=0)
        b = ingest_machine_events(SAMPLE_CSV, seed=0)
        assert a.events == b.events
        assert np.array_equal(a.trace.latencies, b.trace.latencies)

    def test_sample_replays_through_simulate_churn(self):
        scn = ingest_machine_events(SAMPLE_CSV, seed=0)
        res = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                             s=4, recovery="elastic")
        assert res.masks.shape == (scn.steps, scn.n_max)
        assert np.isfinite(time_to_target_error(res))


# ==========================================================================
# simulate_churn: the three recovery modes
# ==========================================================================


class TestSimulateChurn:
    def _storm(self, seed=7):
        # the E13 bench storm: long enough that oblivious's accumulated
        # dead fleet dominates restart's redo cost (at ~200 steps the
        # ordering's tail flips — benchmarks/elastic_churn.py uses 300)
        return make_churn_scenario("bimodal", steps=300, n0=32, seed=seed,
                                   preempt_rate=0.08, preempt_max=3,
                                   block_rate=0.02, scaleup_rate=0.03,
                                   speed_sigma=0.3, min_workers=8)

    def test_modes_run_and_order(self):
        scn = self._storm()
        tts = {}
        for mode in RECOVERY_MODES:
            res = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                                 s=6, recovery=mode, ckpt_every=10,
                                 restart_penalty=10.0)
            assert res.step_times.shape == (scn.steps,)
            tts[mode] = time_to_target_error(res)
        # the E13 gate's ordering on a storm heavy enough to matter
        assert tts["elastic"] <= tts["restart"] <= tts["oblivious"]

    def test_one_decode_per_epoch(self):
        scn = self._storm()
        res = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                             s=6, recovery="elastic")
        assert res.extras["decode_calls"] == res.extras["epochs"]
        assert res.extras["epochs"] == len(scn.events) + 1

    def test_oblivious_single_decode_and_monotone_death(self):
        scn = self._storm()
        res = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                             s=6, recovery="oblivious")
        assert res.extras["decode_calls"] == 1
        # once a worker departs it never returns under the fixed code:
        # the live count is non-increasing even though the scenario has
        # scale_up events (arrivals are ignored without a re-code)
        n_live = np.asarray(res.extras["n_live"])
        assert (np.diff(n_live) <= 0).all()
        assert any(e.kind == "scale_up" for e in scn.events)

    def test_restart_charges_redo(self):
        scn = self._storm()
        el = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                            s=6, recovery="elastic")
        rs = simulate_churn("bgc", scn, "deadline", decoder="onestep",
                            s=6, recovery="restart", ckpt_every=10,
                            restart_penalty=10.0)
        assert rs.extras["redo_time"] > 0
        assert rs.total_time > el.total_time
        # identical membership trajectory -> identical decode errors
        assert np.allclose(el.errors, rs.errors)

    def test_membership_cache_not_mutated(self):
        # regression: the oblivious branch must not write through the
        # scenario's cached membership() array
        scn = self._storm()
        before = scn.membership().copy()
        simulate_churn("bgc", scn, "deadline", decoder="onestep",
                       s=6, recovery="oblivious")
        assert np.array_equal(scn.membership(), before)

    def test_unknown_recovery_rejected(self):
        scn = self._storm()
        with pytest.raises(ValueError):
            simulate_churn("bgc", scn, "deadline", decoder="onestep",
                           s=6, recovery="magic")


# ==========================================================================
# Trainer: churn consumed end to end (slow: jitted training)
# ==========================================================================


@pytest.mark.slow
class TestTrainerChurn:
    def _setup(self, tmp_path=None, steps=24, recovery="elastic"):
        from repro import configs as CFG
        from repro.models import build_model
        from repro.optim import OptConfig
        from repro.training import CodedTrainConfig, CodedTrainer

        model = build_model(CFG.get_config("minicpm-2b", smoke=True))
        scn = make_churn_scenario("bimodal", steps=steps, n0=8,
                                  preempt_rate=0.12, scaleup_rate=0.06,
                                  min_workers=3, seed=11)
        kw = {}
        if tmp_path is not None:
            kw = dict(ckpt_dir=str(tmp_path), ckpt_every=6)
        tcfg = CodedTrainConfig(
            code="bgc", n_workers=8, s=2, steps=steps, seq_len=8, seed=0,
            opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
            log_every=1, **kw)
        return model, scn, tcfg, CodedTrainer(
            model, tcfg, churn=scn, recovery=recovery)

    def test_elastic_trains_through_events(self):
        _, scn, _, tr = self._setup()
        out = tr.run()
        assert len(tr.churn_log) == len(
            [e for e in scn.events if e.step < 24])
        assert tr.assignment.n == tr.churn_log[-1]["n_live"]
        assert all(np.isfinite(h["mean_ce"]) for h in out["history"])
        # the fleet the trainer ends on matches the scenario's replay
        assert tr.assignment.n == int(scn.membership()[23].sum())

    def test_restart_recovery_rewinds(self, tmp_path):
        _, scn, _, tr = self._setup(tmp_path, recovery="restart")
        out = tr.run()
        rewinds = [r for r in tr.churn_log if "restart_to" in r]
        assert rewinds, "no membership event triggered a restart"
        assert all(np.isfinite(h["mean_ce"]) for h in out["history"])

    def test_killed_then_restarted_equals_uninterrupted(self, tmp_path):
        from repro.training import CodedTrainer

        model, scn, tcfg, ref = self._setup(tmp_path / "ref")
        out_ref = ref.run()
        ce_ref = {r["step"]: r["mean_ce"] for r in out_ref["history"]}

        model2, scn2, tcfg2, first = self._setup(tmp_path / "kill")
        first.run(steps=15)  # "killed" mid-run; ckpts stay on disk
        resumed = CodedTrainer(model2, tcfg2, churn=scn2, recovery="elastic")
        out_res = resumed.run()  # fresh trainer resumes + finishes the job
        assert out_res["history"][0]["step"] == 12  # restored, not cold
        for r in out_res["history"]:
            assert ce_ref[r["step"]] == r["mean_ce"]

    def test_churn_excludes_trace(self):
        from repro import configs as CFG
        from repro.models import build_model
        from repro.training import CodedTrainConfig, CodedTrainer
        from repro.sim import make_trace

        model = build_model(CFG.get_config("minicpm-2b", smoke=True))
        scn = make_churn_scenario("bimodal", steps=8, n0=8, seed=0)
        trace = make_trace("bimodal", steps=8, n=8, seed=0)
        with pytest.raises(ValueError, match="exclusive"):
            CodedTrainer(model, CodedTrainConfig(n_workers=8, steps=8),
                         churn=scn, trace=trace)
        with pytest.raises(ValueError, match="restart"):
            CodedTrainer(model, CodedTrainConfig(n_workers=8, steps=8),
                         churn=scn, recovery="restart")  # no ckpt_dir
        with pytest.raises(ValueError, match="n0"):
            CodedTrainer(model, CodedTrainConfig(n_workers=4, steps=8),
                         churn=scn)
